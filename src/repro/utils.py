"""Small shared utilities."""
from __future__ import annotations

import jax


def match_vma(x, like):
    """Make ``x``'s varying-manual-axes match ``like``'s (shard_map scan
    carries initialized from constants must be cast to varying — see the
    shard_map VMA docs). No-op outside shard_map."""
    try:
        vma = jax.typeof(like).vma
    except AttributeError:
        return x
    if not vma:
        return x
    return jax.tree.map(
        lambda a: jax.lax.pcast(a, tuple(vma), to="varying"), x)
