"""Small shared utilities."""
from __future__ import annotations

import jax

# jax moved shard_map out of experimental in 0.6; the pinned 0.4.x only has
# the experimental spelling. Import it from here everywhere so the repo runs
# on both sides of the move.
try:
    from jax import shard_map  # type: ignore[attr-defined]  # jax >= 0.6
except ImportError:
    from jax.experimental.shard_map import shard_map  # noqa: F401


def axis_size(name) -> int:
    """Static size of a named mesh axis, on either side of the jax API move
    (``jax.lax.axis_size`` is jax ≥ 0.5; ``psum(1, name)`` constant-folds to
    the axis size everywhere)."""
    try:
        return jax.lax.axis_size(name)
    except AttributeError:
        return jax.lax.psum(1, name)


def match_vma(x, like):
    """Make ``x``'s varying-manual-axes match ``like``'s (shard_map scan
    carries initialized from constants must be cast to varying — see the
    shard_map VMA docs). No-op outside shard_map."""
    try:
        vma = jax.typeof(like).vma
    except AttributeError:
        return x
    if not vma:
        return x
    return jax.tree.map(
        lambda a: jax.lax.pcast(a, tuple(vma), to="varying"), x)
