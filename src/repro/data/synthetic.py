"""Deterministic synthetic LM data.

The batch for global step ``s`` is a pure function of (seed, s, arch) —
stateless, so a restarted/elastically-rescaled job resumes on exactly the
token stream it would have seen (the data half of the fault-tolerance
story; tests/test_data.py asserts restart-equivalence).

The token stream must be LEARNABLE fast on CPU-sized models (modular
arithmetic streams grok too slowly): each dataset seed fixes a length-P
token pattern; every row is that pattern at a random phase with a fraction
of tokens corrupted uniformly. The bigram map pattern[j] → pattern[j+1] is
near-deterministic, so CE drops from ln V toward
  (1-ρ)·(-ln(1-ρ)) + ρ·ln V   (ρ = corruption rate)
within tens of steps — the signal train-loop tests and examples assert.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


class SyntheticDataset:
    PATTERN_LEN = 16
    CORRUPT = 0.05

    def __init__(self, cfg: ModelConfig, seq_len: int, seed: int = 0):
        self.cfg = cfg
        self.seq_len = seq_len
        self.seed = seed
        rule = np.random.default_rng(np.random.SeedSequence([seed, 0xA11CE]))
        self.pattern = rule.integers(0, cfg.vocab_size, self.PATTERN_LEN)

    def _rng(self, step: int, row: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step, row]))

    def _row_tokens(self, rng, V, S):
        off = int(rng.integers(0, self.PATTERN_LEN))
        toks = self.pattern[(np.arange(S) + off) % self.PATTERN_LEN].copy()
        corrupt = rng.random(S) < self.CORRUPT
        toks[corrupt] = rng.integers(0, V, int(corrupt.sum()))
        return toks

    def sample(self, step: int, row: int) -> Dict[str, np.ndarray]:
        rng = self._rng(step, row)
        V, S = self.cfg.vocab_size, self.seq_len
        toks = self._row_tokens(rng, V, S)
        out = {"tokens": toks.astype(np.int32), "labels": toks.astype(np.int32)}
        if self.cfg.vision_tokens:
            out["vision_embeds"] = rng.standard_normal(
                (self.cfg.vision_tokens, self.cfg.vision_dim)).astype(np.float32) * 0.1
            lab = out["labels"].copy()
            lab[: self.cfg.vision_tokens] = -1
            out["labels"] = lab
        if self.cfg.enc_dec:
            out["frames"] = rng.standard_normal(
                (self.cfg.enc_ctx, self.cfg.d_model)).astype(np.float32) * 0.1
        return out

    def batch(self, step: int, global_batch: int) -> Dict[str, np.ndarray]:
        """Vectorized across rows; identical streams to per-row sample()
        (same per-row generator, same draw order — test_data.py asserts it)."""
        V, S, B = self.cfg.vocab_size, self.seq_len, global_batch
        rngs = [self._rng(step, r) for r in range(B)]
        toks = np.stack([self._row_tokens(r, V, S) for r in rngs])
        out = {"tokens": toks.astype(np.int32), "labels": toks.astype(np.int32)}
        if self.cfg.vision_tokens:
            out["vision_embeds"] = np.stack([
                r.standard_normal((self.cfg.vision_tokens, self.cfg.vision_dim))
                .astype(np.float32) * 0.1 for r in rngs])
            out["labels"] = out["labels"].copy()
            out["labels"][:, : self.cfg.vision_tokens] = -1
        if self.cfg.enc_dec:
            out["frames"] = np.stack([
                r.standard_normal((self.cfg.enc_ctx, self.cfg.d_model))
                .astype(np.float32) * 0.1 for r in rngs])
        return out
