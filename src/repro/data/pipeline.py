"""Host data pipeline: background prefetch + device placement.

A loader thread stays ``prefetch`` steps ahead of the training loop (compute
and host data prep overlap — on a real pod the per-host loader builds only
its local shard via ``jax.make_array_from_process_local_data``; on this
single-process container that call degenerates to a device_put with the
global sharding, same code path).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import jax
import numpy as np

from repro.data.synthetic import SyntheticDataset


class Prefetcher:
    def __init__(self, dataset: SyntheticDataset, global_batch: int,
                 start_step: int = 0, prefetch: int = 2,
                 sharding: Optional[jax.sharding.Sharding] = None):
        self.dataset = dataset
        self.global_batch = global_batch
        self.sharding = sharding
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _place(self, batch):
        if self.sharding is None:
            return batch
        out = {}
        for k, v in batch.items():
            out[k] = jax.device_put(v, self.sharding) if v.ndim <= 1 else \
                jax.device_put(v, self.sharding)
        return out

    def _fill(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.dataset.batch(step, self.global_batch)
            try:
                self._q.put((step, batch), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        step, batch = self._q.get()
        return step, self._place(batch)

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=5)
