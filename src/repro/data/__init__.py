from repro.data.pipeline import Prefetcher
from repro.data.synthetic import SyntheticDataset

__all__ = ["Prefetcher", "SyntheticDataset"]
