from repro.sharding.specs import (batch_specs, decode_state_specs,
                                  opt_state_specs, param_specs)

__all__ = ["batch_specs", "decode_state_specs", "opt_state_specs", "param_specs"]
