"""PartitionSpec policies: how every parameter, activation, batch field and
cache shards over the ("pod", "data", "model") production mesh.

Policies
--------
``tp``       Megatron-style tensor parallelism on the ``model`` axis
             (attention heads / FFN hidden / vocab), pure DP elsewhere.
``fsdp_tp``  ``tp`` plus parameters (and optimizer state) sharded over the
             data axes on a remaining dim — ZeRO-3-style per-layer
             all-gather under scan+remat. Required for grok-1-314b
             (628 GB bf16 > 16 GB × 16-way TP).

Divisibility-aware fallbacks (jax argument shardings must tile evenly):
  * attention heads shard over model when H % tp == 0, otherwise the
    head_dim shards (qwen3 40H, smollm 15H/5KV, whisper 6H, 8-KV GQA —
    every assigned head_dim ∈ {64, 80, 128} divides 16);
  * vocab shards over model when divisible (mamba2's 50280 and whisper's
    51865 are not → the d_model dim shards instead);
  * any fsdp dim that doesn't tile the data axes falls back to replicated.

The builders are rule-based over tree paths + shapes, so any new module
following the naming conventions shards correctly without new code.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def _axes_size(ax, sizes) -> int:
    if ax is None:
        return 1
    if isinstance(ax, tuple):
        n = 1
        for a in ax:
            n *= sizes[a]
        return n
    return sizes[ax]


def _fits(dim: int, ax, sizes) -> bool:
    return dim % _axes_size(ax, sizes) == 0


def _rule(path: str, shape: Tuple[int, ...], policy: str, dp, sizes):
    """→ spec entries for the *unstacked* param."""
    fsdp = dp if policy == "fsdp_tp" else None
    last = path.rsplit("/", 1)[-1]

    def f(dim_idx, ax=fsdp):
        """fsdp axis if it tiles this dim, else replicated."""
        return ax if (ax is not None and _fits(shape[dim_idx], ax, sizes)) else None

    def tp(dim_idx):
        return "model" if _fits(shape[dim_idx], "model", sizes) else None

    if path.endswith("embed/tok"):                       # (V, D)
        if _fits(shape[0], "model", sizes):
            return ("model", f(1))
        # non-divisible vocab (mamba2 50280, whisper 51865): replicate —
        # sharding D would make every logits matmul all-reduce a (B,S,V)
        return (f(0), None)
    if path.endswith("embed/head"):                      # (D, V)
        if _fits(shape[1], "model", sizes):
            return (f(0), "model")
        return (f(0), None)
    if path.endswith("vision_proj/w"):
        return (None, None)
    if last in ("wq", "wk", "wv"):                       # (D, H, Dh)
        if _fits(shape[1], "model", sizes):
            return (f(0), "model", None)
        # non-divisible heads (qwen3 40H, smollm 15/5, whisper 6, 8-KV GQA):
        # replicate over model — sharding Dh makes every attention dot
        # contract a sharded dim (an all-reduce per flash block: measured
        # 31 TB/step on smollm before this rule). Attention runs DP-only;
        # the idle model axis shows up in the roofline compute term and is
        # the explicit target of the seq-parallel hillclimb.
        return (f(0), None, None)
    if last == "wo":                                     # (H, Dh, D)
        if _fits(shape[0], "model", sizes):
            return ("model", None, f(2))
        return (None, None, f(2))
    if last in ("gate", "up"):
        if len(shape) == 3:                              # moe (E, D, F)
            return (None, f(1), tp(2))
        return (f(0), tp(1))                             # dense (D, F)
    if last == "down":
        if len(shape) == 3:                              # moe (E, F, D)
            return (None, tp(1), f(2))
        return (tp(0), f(1))                             # dense (F, D)
    if last == "router":                                 # (D, E)
        return (f(0), None)
    if last == "in_proj":                                # (D, PO)
        return (f(0), tp(1))
    if last == "conv_w":                                 # (cw, C)
        return (None, tp(1))
    if last in ("conv_b", "dt_bias", "A_log", "D", "gate_norm"):
        return (tp(0),)
    if last == "out_proj":                               # (di, D)
        return (tp(0), f(1))
    return (None,) * len(shape)                          # norms, scalars


def param_specs(cfg: ModelConfig, params_tree, *, policy: str = "tp",
                dp=("data",), mesh=None, axis_sizes=None):
    """params_tree: pytree of arrays or ShapeDtypeStructs → pytree of P."""
    sizes = axis_sizes or (dict(zip(mesh.axis_names, mesh.devices.shape))
                           if mesh is not None else
                           {"pod": 2, "data": 16, "model": 16})
    dp_entry = dp if len(dp) > 1 else dp[0]

    def spec_of(path, leaf):
        ps = _path_str(path)
        stacked = ps.split("/")[0] in ("blocks", "enc_blocks")
        shape = tuple(leaf.shape[1:]) if stacked else tuple(leaf.shape)
        entries = tuple(_rule(ps, shape, policy, dp_entry, sizes))[:len(shape)]
        entries = entries + (None,) * (len(shape) - len(entries))
        if stacked:
            entries = (None,) + entries
        return P(*entries)

    flat, treedef = jax.tree_util.tree_flatten_with_path(params_tree)
    return jax.tree_util.tree_unflatten(treedef, [spec_of(p, l) for p, l in flat])


def opt_state_specs(cfg: ModelConfig, params_tree, *, dp=("data",), mesh=None,
                    axis_sizes=None):
    """ZeRO-1: moments shard like fsdp_tp params (sharded over data axes on
    top of TP) regardless of the param policy; scalar step replicated."""
    ps = param_specs(cfg, params_tree, policy="fsdp_tp", dp=dp, mesh=mesh,
                     axis_sizes=axis_sizes)
    return {"m": ps, "v": ps, "step": P()}


def batch_specs(cfg: ModelConfig, *, dp=("data",)):
    dpe = dp if len(dp) > 1 else dp[0]
    specs = {"tokens": P(dpe, None), "labels": P(dpe, None)}
    if cfg.vision_tokens:
        specs["vision_embeds"] = P(dpe, None, None)
    if cfg.enc_dec:
        specs["frames"] = P(dpe, None, None)
    return specs


def decode_state_specs(cfg: ModelConfig, state_tree, *, dp=("data",),
                       batch: int = 0, seq_shard=("model",)):
    """Cache sharding, rule-based over the actual decode-state pytree
    (pass jax.eval_shape(init_decode_state, ...) output).

    KV-cache *sequence* dims shard over ``seq_shard`` — context parallelism,
    because KV head counts (5..32) never divide a 256-chip pod. When
    batch == 1 (long_500k) the data axes join the sequence shard so no mesh
    axis idles. SSM states shard heads over model (falling back to head_dim
    when heads don't divide); the conv tail shards channels over model."""
    dpe = dp if len(dp) > 1 else dp[0]
    sizes = {"pod": 2, "data": 16, "model": 16}
    if batch == 1:
        cache_b = None
        seq_axes = tuple(dp) + tuple(seq_shard)
        seq = seq_axes if len(seq_axes) > 1 else seq_axes[0]
    else:
        cache_b = dpe
        seq = seq_shard if len(seq_shard) > 1 else seq_shard[0]

    def spec_of(path, leaf):
        ps = _path_str(path)
        last = ps.rsplit("/", 1)[-1]
        if last == "pos":
            return P()
        if last == "slot_pos":                     # (L, W)
            return P(None, seq)
        if last == "ssd":                          # (L, B, H, P, N)
            h_ok = leaf.shape[2] % _axes_size("model", sizes) == 0
            return (P(None, cache_b, "model", None, None) if h_ok
                    else P(None, cache_b, None, "model", None))
        if last == "conv":                         # (L, B, cw-1, C)
            return P(None, cache_b, None, "model")
        if "cross" in ps:                          # (L, B, Se, Hkv, Dh) — small
            return P(None, cache_b, None, None, None)
        if last in ("k", "v"):                     # (L, B, S, Hkv, Dh)
            return P(None, cache_b, seq, None, None)
        return P(*([None] * leaf.ndim))

    flat, treedef = jax.tree_util.tree_flatten_with_path(state_tree)
    return jax.tree_util.tree_unflatten(treedef, [spec_of(p, l) for p, l in flat])
