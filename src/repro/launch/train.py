"""Training launcher.

On this CPU container it drives reduced (smoke) configs end-to-end through
the production Trainer — microbatching, checkpointing, failure injection,
straggler telemetry. On a real pod the same driver runs the full configs:
pass --full to lower the assigned architecture at its production size
(requires TPU devices; the 512-way compile-only path is launch/dryrun.py).

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --steps 50
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --steps 200 \\
      --ckpt-dir /tmp/ckpt --fail-at 80
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs import (ARCH_IDS, OptimizerConfig, TrainConfig, get_config,
                           get_reduced)
from repro.models.transformer import Impl
from repro.runtime import FailureInjector, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=list(ARCH_IDS))
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--micro", type=int, default=4)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--fail-at", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--full", action="store_true",
                    help="full production config (TPU pods; CPU smoke uses "
                         "the reduced twin)")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_reduced(args.arch)
    print(f"arch={cfg.name} params≈{cfg.param_count()/1e6:.1f}M "
          f"({'full' if args.full else 'reduced smoke'})")

    tcfg = TrainConfig(
        microbatch_size=args.micro, dtype="float32" if not args.full else "bfloat16",
        optimizer=OptimizerConfig(lr=args.lr, warmup_steps=max(2, args.steps // 20),
                                  total_steps=args.steps, weight_decay=0.01),
        log_every=max(1, args.steps // 20),
        checkpoint_every=max(10, args.steps // 5), seed=args.seed)

    injector = FailureInjector({args.fail_at: ["host1"]} if args.fail_at else {})
    trainer = Trainer(cfg, tcfg, global_batch=args.batch, seq_len=args.seq,
                      checkpoint_dir=args.ckpt_dir,
                      impl=Impl(attention="chunked", q_chunk=64, kv_chunk=64,
                                remat=False),
                      workers=[f"host{i}" for i in range(4)], injector=injector)
    report = trainer.run(args.steps)

    first = np.mean(report.losses[:5])
    last = np.mean(report.losses[-5:])
    print(f"\nloss {first:.4f} → {last:.4f} | steps {report.steps_run} | "
          f"restarts {report.restarts} | stragglers {report.stragglers} | "
          f"guard trips {report.guard_trips}")
    for e in report.events:
        print("event:", e)


if __name__ == "__main__":
    main()
