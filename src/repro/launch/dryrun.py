import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes, with NO device allocation (ShapeDtypeStruct inputs).

This is the proof that the distribution config is coherent: a sharding
mismatch, a collective XLA can't partition, or an OOM at compile time all
fail here. Outputs (memory_analysis, cost_analysis, the collective schedule
parsed from compiled HLO) are written to artifacts/dryrun/ and consumed by
EXPERIMENTS.md §Dry-run / §Roofline and benchmarks/roofline_report.py.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                    # all cells, 1 pod
  PYTHONPATH=src python -m repro.launch.dryrun --multi-pod        # all cells, 2 pods
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
"""
import argparse
import dataclasses
import json
import time
import traceback
from dataclasses import replace as dc_replace

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (ARCH_IDS, SHAPES_BY_NAME, TrainConfig, get_config,
                           shape_applicable)
from repro.configs.base import OptimizerConfig
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.models import init_decode_state, init_params
from repro.models.transformer import Impl
from repro.optim import init_opt_state
from repro.roofline import analyze, model_flops
from repro.runtime.steps import make_decode_step, make_prefill_step, make_train_step
from repro.sharding.specs import (batch_specs, decode_state_specs,
                                  opt_state_specs, param_specs)

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                         "artifacts", "dryrun")

# Per-arch distribution choices (see DESIGN.md §4 and configs/*.py docstrings)
TRAIN_POLICY = {"grok-1-314b": "fsdp_tp", "mixtral-8x7b": "fsdp_tp"}
SERVE_POLICY = {"grok-1-314b": "fsdp_tp"}
TRAIN_PARAM_DTYPE = {"grok-1-314b": jnp.bfloat16}
TRAIN_OPT_DTYPE = {"grok-1-314b": jnp.bfloat16}
ROWS_PER_DEVICE = {"whisper-tiny": 4, "smollm-360m": 2, "olmo-1b": 2,
                   "llama3.2-1b": 2, "mamba2-1.3b": 2}

IMPL = Impl(attention="chunked", decode_attention="naive", ssd="chunked",
            q_chunk=128, kv_chunk=128, remat=True)

# Head-padding targets for --opt-pad-heads (function-preserving; see
# configs/base.py). Constraint: kv_pad ≥ kv, g_pad ≥ g, (kv_pad·g_pad) % 16 == 0.
PAD_HEADS = {
    "qwen3-14b": dict(pad_q_heads=48, pad_kv_heads=8),     # g 5→6
    "smollm-360m": dict(pad_q_heads=32, pad_kv_heads=8),   # (5,3)→(8,4)
    "whisper-tiny": dict(pad_q_heads=16, pad_kv_heads=16), # (6,1)→(16,1)
}


def apply_opts(cfg, impl, opts, kind="train"):
    """Beyond-paper optimization knobs (§Perf hillclimb), composable.

    Head padding is primarily a train/prefill optimization. At decode it
    cuts replicated weight reads (qwen3: 1.3-1.5×) but padding the KV heads
    grows the cache — and decode is bound by cache reads (smollm 0.65×,
    whisper 0.47× before this rule). Policy: pad at decode only when the
    kv head count is unchanged; serving weights are repacked accordingly."""
    if opts.get("moe_group") and cfg.moe:
        g = opts["moe_group"] if isinstance(opts["moe_group"], int) and \
            opts["moe_group"] > 1 else 4096
        cfg = dc_replace(cfg, moe=dc_replace(cfg.moe, group_size=g))
    if opts.get("pad_heads") and cfg.name in PAD_HEADS:
        pads = PAD_HEADS[cfg.name]
        grows_kv = pads["pad_kv_heads"] > cfg.num_kv_heads
        if kind != "decode" or not grows_kv:
            cfg = dc_replace(cfg, **pads)
    if opts.get("kv_chunk"):
        impl = dataclasses.replace(impl, kv_chunk=opts["kv_chunk"])
    if opts.get("anchor"):
        impl = dataclasses.replace(impl, act_dp=opts["anchor"])
    return cfg, impl


def opts_tag(opts):
    parts = []
    if opts.get("moe_group"):
        g = opts["moe_group"] if isinstance(opts["moe_group"], int) and \
            opts["moe_group"] > 1 else 4096
        parts.append(f"moegrp{g}")
    if opts.get("pad_heads"):
        parts.append("padh")
    if opts.get("kv_chunk"):
        parts.append(f"kvc{opts['kv_chunk']}")
    if opts.get("zero_grads"):
        parts.append("zgrad")
    if opts.get("anchor"):
        parts.append("anchor")
    return "_".join(parts) if parts else "base"


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(arch: str, shape_name: str, *, dtype=jnp.bfloat16):
    """ShapeDtypeStruct stand-ins for every model input of a cell."""
    cfg = get_config(arch)
    shp = SHAPES_BY_NAME[shape_name]
    B, S = shp.global_batch, shp.seq_len
    if shp.kind == "decode":
        return {"token": _sds((B, 1), jnp.int32)}
    batch = {"tokens": _sds((B, S), jnp.int32),
             "labels": _sds((B, S), jnp.int32)}
    if cfg.vision_tokens:
        batch["vision_embeds"] = _sds((B, cfg.vision_tokens, cfg.vision_dim), dtype)
    if cfg.enc_dec:
        batch["frames"] = _sds((B, cfg.enc_ctx, cfg.d_model), dtype)
    return batch


def _cast_tree(sds_tree, dtype, only_float=True):
    def cast(x):
        if only_float and not jnp.issubdtype(x.dtype, jnp.floating):
            return x
        return jax.ShapeDtypeStruct(x.shape, dtype)
    return jax.tree.map(cast, sds_tree)


def _shardings(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def build_cell(arch: str, shape_name: str, mesh, multi_pod: bool, opts=None):
    """→ (jitted_fn, arg ShapeDtypeStructs with shardings attached)."""
    opts = opts or {}
    cfg = get_config(arch)
    shp = SHAPES_BY_NAME[shape_name]
    dp = dp_axes(multi_pod)
    dp_total = 32 if multi_pod else 16
    impl = IMPL
    cfg, impl = apply_opts(cfg, impl, opts, kind=shp.kind)

    params_sds = jax.eval_shape(lambda k: init_params(cfg, k),
                                jax.random.PRNGKey(0))

    if shp.kind == "train":
        policy = TRAIN_POLICY.get(arch, "tp")
        pdt = TRAIN_PARAM_DTYPE.get(arch, jnp.float32)
        odt = TRAIN_OPT_DTYPE.get(arch, jnp.float32)
        params_sds = _cast_tree(params_sds, pdt)
        opt_sds = jax.eval_shape(lambda p: init_opt_state(p, odt), params_sds)
        pspecs = param_specs(cfg, params_sds, policy=policy, dp=dp, mesh=mesh)
        ospecs = opt_state_specs(cfg, params_sds, dp=dp, mesh=mesh)
        bspecs = batch_specs(cfg, dp=dp)
        micro = dp_total * ROWS_PER_DEVICE.get(arch, 1)
        tcfg = TrainConfig(microbatch_size=micro,
                           optimizer=OptimizerConfig(total_steps=10_000))
        gspecs = (param_specs(cfg, params_sds, policy="fsdp_tp", dp=dp, mesh=mesh)
                  if opts.get("zero_grads") else None)
        fn = make_train_step(cfg, tcfg, impl, dp=dp, grad_specs=gspecs)
        in_shard = (_shardings(mesh, pspecs), _shardings(mesh, ospecs),
                    _shardings(mesh, bspecs))
        out_shard = (_shardings(mesh, pspecs), _shardings(mesh, ospecs), None)
        args = (params_sds, opt_sds, input_specs(arch, shape_name))
        jfn = jax.jit(fn, in_shardings=in_shard, out_shardings=out_shard,
                      donate_argnums=(0, 1))
        return jfn, args

    # serving cells: bf16 params
    params_sds = _cast_tree(params_sds, jnp.bfloat16)
    policy = SERVE_POLICY.get(arch, "tp")
    pspecs = param_specs(cfg, params_sds, policy=policy, dp=dp, mesh=mesh)

    if shp.kind == "prefill":
        bspecs = batch_specs(cfg, dp=dp)
        bspecs.pop("labels")
        fn = make_prefill_step(cfg, impl)
        args_batch = input_specs(arch, shape_name)
        args_batch.pop("labels")
        jfn = jax.jit(fn, in_shardings=(_shardings(mesh, pspecs),
                                        _shardings(mesh, bspecs)))
        return jfn, (params_sds, args_batch)

    # decode
    B, S = shp.global_batch, shp.seq_len
    enc_sds = (_sds((B, cfg.enc_ctx, cfg.d_model), jnp.bfloat16)
               if cfg.enc_dec else None)
    state_sds = jax.eval_shape(
        lambda p, e: init_decode_state(cfg, p, B, S, dtype=jnp.bfloat16,
                                       impl=impl, enc_out=e),
        params_sds, enc_sds)
    sspecs = decode_state_specs(cfg, state_sds, dp=dp, batch=B)
    tspec = {"token": P(dp if len(dp) > 1 else dp[0], None)} if B > 1 \
        else {"token": P(None, None)}
    fn = make_decode_step(cfg, impl)
    jfn = jax.jit(fn,
                  in_shardings=(_shardings(mesh, pspecs),
                                _shardings(mesh, sspecs),
                                _shardings(mesh, tspec["token"])),
                  out_shardings=(None, _shardings(mesh, sspecs)),
                  donate_argnums=(1,))
    token_sds = input_specs(arch, shape_name)["token"]
    return jfn, (params_sds, state_sds, token_sds)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             save: bool = True, opts=None) -> dict:
    opts = opts or {}
    cfg = get_config(arch)
    shp = SHAPES_BY_NAME[shape_name]
    ok, why = shape_applicable(cfg, shp)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "kind": shp.kind, "status": "skip", "skip_reason": why,
              "opts": opts_tag(opts)}
    if not ok:
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    t0 = time.perf_counter()
    with mesh:
        jfn, args = build_cell(arch, shape_name, mesh, multi_pod, opts)
        lowered = jfn.lower(*args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    roof = analyze(cost, hlo, n_dev)

    mfl = model_flops(cfg.active_param_count(),
                      shp.tokens if shp.kind != "decode" else shp.global_batch,
                      shp.kind)
    result.update({
        "status": "ok",
        "devices": int(n_dev),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        },
        "roofline": roof.to_dict(),
        "model_flops_global": mfl,
        "model_flops_per_device": mfl / n_dev,
        "useful_flops_ratio": (mfl / n_dev) / roof.flops if roof.flops else None,
    })
    if save:
        os.makedirs(ARTIFACTS, exist_ok=True)
        tag = opts_tag(opts)
        suffix = "" if tag == "base" else f"__{tag}"
        fname = f"{arch}__{shape_name}__{mesh_name}{suffix}.json"
        with open(os.path.join(ARTIFACTS, fname), "w") as f:
            json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_IDS))
    ap.add_argument("--shape", default=None, choices=list(SHAPES_BY_NAME))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--opt-moe-group", type=int, nargs="?", const=4096,
                    default=0)
    ap.add_argument("--opt-pad-heads", action="store_true")
    ap.add_argument("--opt-kv-chunk", type=int, default=0)
    ap.add_argument("--opt-zero-grads", action="store_true")
    ap.add_argument("--opt-anchor-acts", action="store_true")
    args = ap.parse_args()
    opts = {"moe_group": args.opt_moe_group, "pad_heads": args.opt_pad_heads,
            "kv_chunk": args.opt_kv_chunk, "zero_grads": args.opt_zero_grads}

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPES_BY_NAME)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = 0
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                tag = f"{arch:24s} {shape:12s} {'2x16x16' if mp else '16x16':8s}"
                try:
                    cell_opts = dict(opts)
                    if args.opt_anchor_acts:
                        cell_opts["anchor"] = dp_axes(mp)
                    r = run_cell(arch, shape, multi_pod=mp, opts=cell_opts)
                except Exception as e:
                    failures += 1
                    print(f"FAIL {tag} {type(e).__name__}: {e}")
                    traceback.print_exc()
                    continue
                if r["status"] == "skip":
                    print(f"SKIP {tag} {r['skip_reason']}")
                    continue
                roof = r["roofline"]
                mem = r["memory"]
                peak = mem["peak_bytes"] or \
                    (mem["argument_bytes"] or 0) + (mem["temp_bytes"] or 0)
                print(f"OK   {tag} compile={r['compile_s']:7.1f}s "
                      f"mem/dev={(peak)/2**30:6.2f}GiB "
                      f"flops/dev={roof['flops']:.3e} "
                      f"coll={roof['collective_bytes']/2**20:9.1f}MiB "
                      f"bound={roof['bottleneck']}")
    print(f"\ndone; failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
