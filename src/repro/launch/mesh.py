"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod (TPU v5e pod slice); 2 pods = 512 chips when
    multi_pod. Axes: data-parallel replicas × model(tensor) parallelism,
    with the leading ``pod`` axis as the cross-DCI data-parallel dimension."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for CPU tests (8 forced host devices)."""
    return jax.make_mesh(shape, axes)
