"""Serving launcher: continuous-batching decode over a fixed slot grid.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-1.3b --requests 12
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.models import init_params
from repro.models.transformer import Impl
from repro.runtime import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=list(ARCH_IDS))
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=96)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--greedy", action="store_true", default=True)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_reduced(args.arch)
    if cfg.swa_window is not None and args.max_seq > cfg.swa_window:
        args.max_seq = cfg.swa_window
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, max_batch=args.max_batch,
                        max_seq=args.max_seq,
                        impl=Impl(attention="naive", ssd="chunked", remat=False))

    t0 = time.perf_counter()
    for i in range(args.requests):
        prompt = [(13 * i + j) % cfg.vocab_size for j in range(3 + i % 4)]
        eng.submit(Request(rid=i, prompt=prompt, max_new=args.max_new))
    done = eng.run_until_drained()
    wall = time.perf_counter() - t0

    total = sum(len(r.generated) for r in done)
    for r in sorted(done, key=lambda r: r.rid)[:8]:
        print(f"req {r.rid:2d}: prompt={len(r.prompt)} new={len(r.generated)} "
              f"latency={(r.finished_at - r.submitted_at)*1e3:7.1f} ms")
    print(f"\n{len(done)} requests | {total} tokens | {eng.ticks} ticks | "
          f"{wall:.2f}s | {total/wall:.1f} tok/s")


if __name__ == "__main__":
    main()
