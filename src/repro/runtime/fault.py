"""Fault tolerance primitives: heartbeats, failure injection, straggler
detection.

This container has one real device, so *detection/decision logic* is what
runs and is unit-tested here; the actuation path (rebuild mesh, restore
checkpoint, resume) is exercised end-to-end by runtime/train_loop.py with
injected failures. On a real pod the same monitor consumes per-host
heartbeats from the coordination service instead of thread pings.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set


@dataclass
class WorkerState:
    last_beat: float
    alive: bool = True


class HeartbeatMonitor:
    """Workers beat every ``interval``; silence > ``timeout`` → failed."""

    def __init__(self, workers: List[str], timeout: float = 5.0):
        now = time.monotonic()
        self.timeout = timeout
        self._workers: Dict[str, WorkerState] = {
            w: WorkerState(now) for w in workers}
        self._lock = threading.Lock()

    def beat(self, worker: str, at: Optional[float] = None):
        with self._lock:
            st = self._workers.get(worker)
            if st is not None:
                st.last_beat = at if at is not None else time.monotonic()

    def ensure(self, worker: str):
        """Start tracking a late-arriving worker (no-op if known)."""
        with self._lock:
            if worker not in self._workers:
                self._workers[worker] = WorkerState(time.monotonic())

    def revive(self, worker: str, at: Optional[float] = None):
        """A recovered worker beats AND is marked alive again (a plain beat
        does not resurrect: check() latches failure)."""
        with self._lock:
            st = self._workers.get(worker)
            if st is not None:
                st.alive = True
                st.last_beat = at if at is not None else time.monotonic()

    def mark_failed(self, worker: str):
        """Explicit failure injection (tests / external signal)."""
        with self._lock:
            if worker in self._workers:
                self._workers[worker].alive = False

    def check(self, at: Optional[float] = None) -> Set[str]:
        """→ set of failed workers as of ``at``."""
        now = at if at is not None else time.monotonic()
        failed = set()
        with self._lock:
            for name, st in self._workers.items():
                if not st.alive or (now - st.last_beat) > self.timeout:
                    st.alive = False
                    failed.add(name)
        return failed

    def alive(self) -> List[str]:
        with self._lock:
            return [w for w, st in self._workers.items() if st.alive]


class StragglerDetector:
    """Deadline-based: a worker whose step time exceeds ``factor`` × the
    rolling median is a straggler. Mitigation at pod scale = drop its
    gradient contribution for the step (DP redundancy) or re-dispatch; the
    decision is returned to the caller, the training loop records it."""

    def __init__(self, window: int = 32, factor: float = 2.0):
        self.window = window
        self.factor = factor
        self._times: deque = deque(maxlen=window)

    def observe(self, step_time: float) -> bool:
        """→ True if this step was a straggler vs the rolling median."""
        times = sorted(self._times)
        self._times.append(step_time)
        if len(times) < 8:
            return False
        median = times[len(times) // 2]
        return step_time > self.factor * median

    @property
    def median(self) -> Optional[float]:
        if not self._times:
            return None
        t = sorted(self._times)
        return t[len(t) // 2]


@dataclass
class FailureInjector:
    """Deterministic failure schedule for tests/examples:
    {step: [worker, ...]} — at that step the monitor marks them failed."""
    schedule: Dict[int, List[str]] = field(default_factory=dict)

    def fire(self, step: int, monitor: HeartbeatMonitor) -> List[str]:
        failed = self.schedule.get(step, [])
        for w in failed:
            monitor.mark_failed(w)
        return failed


@dataclass
class GuardTripError(RuntimeError):
    """A fabric channel MAC verification failed — corrupted exchange.
    The training loop catches this and retries the step from the last
    known-good state (the paper's tamper-detection, actioned)."""
    step: int
    detail: str = ""


class GatewaySupervisor:
    """Service-level incarnation of the worker heartbeat loop: feeds a
    :class:`HeartbeatMonitor` from a gateway's per-service health and
    actuates the recovery plan (restart / shed / leave-open) that
    :func:`repro.runtime.elastic.plan_gateway_recovery` decides.

    The gateway already self-heals inline for services registered with a
    ``factory``; the supervisor is the out-of-band sweep that (a) restarts
    factory-less services an operator has since given a factory, (b) keeps
    the monitor's alive/failed view consistent for dashboards, and (c) is
    the single place a control loop calls on its cadence."""

    def __init__(self, gateway, timeout: float = 5.0):
        self.gateway = gateway
        self.monitor = HeartbeatMonitor(list(gateway._services), timeout)
        self.log: list = []            # (tick, action, service) audit trail
        self._tick = 0

    def observe(self) -> Dict[str, Dict[str, object]]:
        """Pull the gateway health snapshot into the heartbeat view."""
        snap = self.gateway.health()
        for name, h in snap.items():
            self.monitor.ensure(name)               # late-registered service
            if h["state"] == "closed":
                self.monitor.revive(name)
            else:
                self.monitor.mark_failed(name)
        return snap

    def heal(self) -> list:
        """One supervision sweep: observe, plan, actuate. → actions taken."""
        from repro.runtime.elastic import plan_gateway_recovery
        snap = self.observe()
        restartable = {n for n, s in self.gateway._services.items()
                       if s.factory is not None}
        actions = plan_gateway_recovery(snap, restartable)
        self._tick += 1
        for action, name in actions:
            if action == "restart":
                self.gateway.restart_service(name)
            self.log.append((self._tick, action, name))
        return actions
