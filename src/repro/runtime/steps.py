"""Step functions: train (microbatched grad accumulation + AdamW), prefill,
decode. Shared by the real runtime (runtime/train_loop.py) and the dry-run
(launch/dryrun.py) so what we lower at 512 devices is exactly what runs.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, OptimizerConfig, TrainConfig
from repro.models import forward, loss_fn, decode_step as model_decode_step
from repro.models.transformer import Impl
from repro.optim import adamw_update


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, impl: Impl,
                    dp=("data",), grad_specs=None):
    """→ train_step(params, opt_state, batch) → (params, opt_state, metrics).

    The global batch is split into microbatches consumed by lax.scan;
    gradients accumulate in f32 (ZeRO-1 sharding comes from the opt-state
    PartitionSpecs, remat from Impl). One optimizer step per call.

    The (B,) → (n_micro, micro) reshape needs an explicit sharding
    constraint: without it GSPMD may shard the *scan* dimension (n_micro is
    usually smaller than the dp axis) and replicate the batch instead —
    measured as an 8× flops blow-up before the constraint.

    ``grad_specs`` (beyond-paper §Perf): PartitionSpecs for the gradient
    accumulator. Passing the fsdp_tp specs keeps the accumulating grads
    SHARDED over the data axes through the microbatch scan — each
    microbatch contributes via reduce-scatter instead of all-reduce (half
    the bytes), and the params all-gather once in the optimizer. This is
    ZeRO-1 done properly; None = the chatty per-microbatch-all-reduce
    baseline that GSPMD picks on its own."""
    from jax.sharding import PartitionSpec as P
    dtype = _dtype(tcfg.dtype)
    micro = tcfg.microbatch_size
    dpe = None if dp is None else (dp if len(dp) > 1 else dp[0])

    def train_step(params, opt_state, batch):
        B = batch["tokens"].shape[0]
        n_micro = max(1, B // micro)

        def to_micro(x):
            m = x.reshape((n_micro, B // n_micro) + x.shape[1:])
            if dpe is None:          # single-device / no-mesh runs
                return m
            return jax.lax.with_sharding_constraint(
                m, P(None, dpe, *([None] * (x.ndim - 1))))

        mbatches = jax.tree.map(to_micro, batch)
        gzero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def shard_grads(g):
            if grad_specs is None:
                return g
            return jax.tree.map(
                lambda x, s: jax.lax.with_sharding_constraint(x, s),
                g, grad_specs)

        gzero = shard_grads(gzero)

        def body(carry, mb):
            gsum, loss_sum = carry
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: loss_fn(cfg, p, mb, impl=impl, dtype=dtype),
                has_aux=True)(params)
            gsum = shard_grads(jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), gsum, grads))
            return (gsum, loss_sum + loss), None

        (gsum, loss_sum), _ = jax.lax.scan(body, (gzero, jnp.float32(0)), mbatches)
        grads = jax.tree.map(lambda g: g / n_micro, gsum)
        params, opt_state, om = adamw_update(params, grads, opt_state, tcfg.optimizer)
        metrics = {"loss": loss_sum / n_micro, **om}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, impl: Impl, dtype=jnp.bfloat16):
    """Serving prefill: full-context forward, next-token logits only."""
    def prefill_step(params, batch):
        logits, _ = forward(cfg, params, batch, impl=impl, dtype=dtype,
                            last_only=True)
        return logits
    return prefill_step


def make_decode_step(cfg: ModelConfig, impl: Impl, dtype=jnp.bfloat16):
    """Serving decode: one token through the cached stack."""
    def serve_step(params, state, token):
        return model_decode_step(cfg, params, state, token, impl=impl, dtype=dtype)
    return serve_step
