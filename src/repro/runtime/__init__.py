from repro.runtime.elastic import (elastic_restore, plan_gateway_recovery,
                                   plan_remesh, remesh)
from repro.runtime.fault import (FailureInjector, GatewaySupervisor,
                                 GuardTripError, HeartbeatMonitor,
                                 StragglerDetector)
from repro.runtime.serve import (EngineService, Request, ServingEngine,
                                 encode_prompt)
from repro.runtime.steps import (make_decode_step, make_prefill_step,
                                 make_train_step)
from repro.runtime.train_loop import Trainer, TrainReport

__all__ = ["elastic_restore", "plan_gateway_recovery", "plan_remesh",
           "remesh", "FailureInjector", "GatewaySupervisor", "GuardTripError",
           "HeartbeatMonitor", "StragglerDetector", "EngineService",
           "Request", "ServingEngine", "encode_prompt", "make_decode_step",
           "make_prefill_step", "make_train_step", "Trainer", "TrainReport"]
