"""Pipeline parallelism: GPipe schedule with MPKLink stage-handoff channels.

Layers are split into contiguous stages sharded over a mesh axis; at each
tick every stage runs its layer slice on one microbatch and pushes the
activation to its successor through a guarded neighbor channel — the
paper's "microservice interaction" at its most literal: stage s and stage
s+1 are co-located services exchanging one message per tick over a
pre-established protected channel instead of a compiler-scheduled
collective.

Schedule: n_micro + n_stages − 1 ticks, the classic GPipe bubble. The whole
pipeline is one differentiable scan (ppermute transposes cleanly), so
jax.grad through it yields the GPipe backward automatically.

Dense/VLM blocks only (MoE inside a stage would nest EP; compose
models/moe_ep.py per stage for that). Verified against the single-device
layer stack in tests/test_pipeline.py.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.domains import DomainKey
from repro.core.fabric import FabricChannel, MPKLinkFabric, neighbor_exchange
from repro.models.transformer import Impl, apply_block
from repro.utils import axis_size, match_vma


@partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def _broadcast_from(x, axis, src):
    """psum-broadcast ``x`` from shard ``src`` to every shard of ``axis``.

    Explicit VJP because the transpose of a masked psum is version-dependent:
    pre-0.5 shard_map transposes psum to psum, which multiplies the cotangent
    by the axis size when the downstream loss is computed redundantly on the
    replicated output. The true adjoint — cotangent masked back to the source
    shard — is spelled out here so gradients are right on every jax pin."""
    sid = jax.lax.axis_index(axis)
    return jax.lax.psum(jnp.where(sid == src, x, jnp.zeros_like(x)), axis)


def _broadcast_from_fwd(x, axis, src):
    return _broadcast_from(x, axis, src), None


def _broadcast_from_bwd(axis, src, _res, ct):
    sid = jax.lax.axis_index(axis)
    return (jnp.where(sid == src, ct, jnp.zeros_like(ct)),)


_broadcast_from.defvjp(_broadcast_from_fwd, _broadcast_from_bwd)


def pipeline_apply(cfg: ModelConfig, local_params, x_micro, *,
                   fabric: MPKLinkFabric, chan: FabricChannel, key: DomainKey,
                   impl: Impl) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Call inside shard_map over chan.axis (the stage axis).

    local_params: block stack sliced per stage — leading dims
    (1, L/n_stages, ...). x_micro (n_micro, mb, S, D) replicated (stage 0
    consumes it). → (outputs (n_micro, mb, S, D) — valid everywhere after a
    final broadcast from the last stage, ok flag)."""
    fabric.check(chan, key)
    assert not cfg.moe, "pipeline stages compose with moe_ep, not dense MoE"
    n = axis_size(chan.axis)
    sid = jax.lax.axis_index(chan.axis)
    params = jax.tree.map(lambda a: a[0], local_params)      # (L/n, ...)
    n_micro, mb, S, D = x_micro.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (mb, S))
    T = n_micro + n - 1

    def run_stage(h):
        def layer(hh, lp):
            out, _ = apply_block(cfg, lp, hh, positions=positions, impl=impl)
            return out, None
        h, _ = jax.lax.scan(layer, h, params)
        return h

    def tick(carry, t):
        held, ok = carry
        # stage 0 injects microbatch t (clipped; masked after n_micro)
        inject = x_micro[jnp.clip(t, 0, n_micro - 1)]
        h_in = jnp.where(sid == 0, inject, held)
        h_out = run_stage(h_in)
        # guarded push to the next stage (ring wrap: stage 0 ignores what
        # the last stage sends back — it injects instead)
        held_next, ok_i = neighbor_exchange(fabric, chan, key, h_out, shift=1)
        return (held_next, ok & ok_i), h_out

    # anchor the carry's varying axes on the stage-sharded params (x_micro is
    # replicated, so it carries no VMA)
    anchor = jax.tree.leaves(params)[0]
    held0 = match_vma(jnp.zeros((mb, S, D), x_micro.dtype), anchor)
    ok0 = match_vma(jnp.int32(1), anchor)
    (_, ok), emits = jax.lax.scan(tick, (held0, ok0), jnp.arange(T))

    # microbatch m exits the last stage at tick m + n - 1
    outs = emits[n - 1:]                                     # (n_micro, mb, S, D)
    outs = _broadcast_from(outs, chan.axis, n - 1)
    return outs, ok


def stage_split(stacked_params, n_stages: int):
    """Host helper: (L, ...) block stack → (n_stages, L/n, ...) for
    shard_map in_specs P("stage") on dim 0."""
    def split(a):
        L = a.shape[0]
        assert L % n_stages == 0, (L, n_stages)
        return a.reshape((n_stages, L // n_stages) + a.shape[1:])
    return jax.tree.map(split, stacked_params)
