"""Elastic scaling: re-mesh after failures and reshard state.

Policy: tensor parallelism (the ``model`` axis) is pinned — TP size is a
property of the model's memory footprint — and the data-parallel axis
shrinks to the surviving hosts. Losing any chip in a 16-chip TP row loses
the row, so the new dp size = floor(alive_rows). Checkpoint restore then
re-places the (host) arrays with the new mesh's NamedShardings; because
checkpoints store full logical arrays keyed by tree path, any mesh shape
that tiles the dims can load any checkpoint (tests/test_checkpoint.py
does 4×2 → 2×2 → 2×4 round trips).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import NamedSharding

from repro.checkpoint import Checkpointer


def plan_remesh(n_alive_chips: int, tp: int = 16,
                axes=("data", "model")) -> Optional[Tuple[Tuple[int, int], Tuple[str, str]]]:
    """→ ((dp, tp), axes) for the largest mesh the survivors support, or
    None if fewer than one TP row survives."""
    dp = n_alive_chips // tp
    if dp < 1:
        return None
    return (dp, tp), tuple(axes)


def remesh(n_alive_chips: int, tp: int = 16, axes=("data", "model")):
    plan = plan_remesh(n_alive_chips, tp, axes)
    if plan is None:
        raise RuntimeError(
            f"not enough chips ({n_alive_chips}) for one tp={tp} row")
    shape, names = plan
    return jax.make_mesh(shape, names)


def elastic_restore(ckpt: Checkpointer, like_tree, mesh, spec_tree,
                    step: Optional[int] = None):
    """Restore the latest checkpoint and place it on a (possibly different)
    mesh. → (step, placed_tree)."""
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                             is_leaf=lambda x: isinstance(
                                 x, jax.sharding.PartitionSpec))
    return ckpt.restore_placed(like_tree, shardings, step)


def plan_gateway_recovery(health: dict, restartable: set) -> list:
    """Service-level remesh policy (pure decision, no side effects): given
    a gateway health snapshot ({service: {"state", ...}}), decide per
    service what the supervisor should actuate.

      open circuit + restartable → ("restart", name)   epoch bump + re-key
      open circuit, no factory   → ("shed", name)      keep shedding typed
      half_open                  → ("probe", name)     a probe is in flight
      closed                     → no action

    Deterministic and order-stable (sorted by service name) so supervision
    sweeps are replayable in chaos tests."""
    actions = []
    for name in sorted(health):
        state = health[name]["state"]
        if state == "open":
            actions.append(("restart" if name in restartable else "shed",
                            name))
        elif state == "half_open":
            actions.append(("probe", name))
    return actions


def plan_fleet_scaling(snapshot: list, target: int) -> list:
    """Replica-fleet remesh policy (pure decision, no side effects): given
    one service's ``ServiceFleet.snapshot()`` (rid-ordered dicts with
    ``state``/``inflight``/``ewma_ms``), decide what the supervisor should
    actuate to hold ``target`` ACTIVE replicas:

      dead replica      → ("release", rid)   drain() it — trivially quiesced,
                                             frees segment + child bookkeeping
      active < target   → ("join", n)        register n fresh replicas; each
                                             join epoch-bumps the service once
      active > target   → ("drain", rid)     drain the least-loaded actives,
                                             newest first on ties

    DRAINING/QUIESCED replicas count as neither active nor reclaimable —
    a prior sweep already decided them. Deterministic and order-stable
    (releases by rid, drains by (inflight, ewma, -rid)) so supervision
    sweeps are replayable in chaos tests, mirroring
    :func:`plan_gateway_recovery`."""
    actions = []
    for r in sorted((r for r in snapshot if r["state"] == "dead"),
                    key=lambda r: r["rid"]):
        actions.append(("release", r["rid"]))
    active = [r for r in snapshot if r["state"] == "active"]
    deficit = target - len(active)
    if deficit > 0:
        actions.append(("join", deficit))
    elif deficit < 0:
        surplus = sorted(active,
                         key=lambda r: (r["inflight"], r["ewma_ms"] or 0.0,
                                        -r["rid"]))[:-deficit]
        actions.extend(("drain", r["rid"]) for r in surplus)
    return actions


def plan_outlier_ejection(snapshot: list, *, factor: float = 4.0,
                          min_peers: int = 3, min_served: int = 32) -> list:
    """EWMA-latency outlier ejection policy (pure decision, no side
    effects), the service-mesh guard against the wedged-but-alive replica
    a liveness probe cannot catch: given one service's
    ``ServiceFleet.snapshot()``, eject ACTIVE replicas whose EWMA service
    time exceeds ``factor`` × the peer median.

      eject candidate → ("eject", rid)    the supervisor drains it and lets
                                          plan_fleet_scaling respawn capacity

    Guard rails, so ejection can't thrash a small or cold fleet:

    * needs ``min_peers`` ACTIVE replicas with an observed EWMA — with
      fewer there is no meaningful peer population to be an outlier OF;
    * a replica must have ``min_served`` completions before it can be
      ejected (its EWMA must be signal, not warmup noise);
    * the median is computed over the OTHER replicas (peer median), so one
      giant outlier cannot drag the threshold up past itself.

    Deterministic and order-stable (ejections by rid ascending) so
    supervision sweeps are replayable, mirroring the other planners."""
    observed = [r for r in snapshot
                if r["state"] == "active" and r["ewma_ms"] is not None]
    if len(observed) < min_peers:
        return []
    actions = []
    for r in sorted(observed, key=lambda r: r["rid"]):
        if r["served"] < min_served:
            continue
        peers = sorted(p["ewma_ms"] for p in observed
                       if p["rid"] != r["rid"])
        med = peers[len(peers) // 2] if len(peers) % 2 else \
            0.5 * (peers[len(peers) // 2 - 1] + peers[len(peers) // 2])
        if med > 0.0 and r["ewma_ms"] > factor * med:
            actions.append(("eject", r["rid"]))
    return actions
