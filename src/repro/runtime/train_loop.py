"""Training driver: microbatched steps, async checkpointing, restart-on-
failure, straggler telemetry, elastic re-mesh hooks.

The loop is deliberately host-side-simple: every piece of cluster logic
(failure detection, restart decision, straggler mitigation, data-stream
determinism) is a small testable object, and the heavy lifting is one
jitted train_step. Restart semantics: state is (params, opt_state, step);
data is a pure function of step — so restore(step=k) reproduces the exact
trajectory a non-failed run would have taken (asserted by
tests/test_runtime.py::test_restart_equivalence).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs.base import ModelConfig, TrainConfig
from repro.data import SyntheticDataset
from repro.models import init_params
from repro.models.transformer import Impl
from repro.optim import init_opt_state
from repro.runtime.fault import (FailureInjector, GuardTripError,
                                 HeartbeatMonitor, StragglerDetector)
from repro.runtime.steps import make_train_step


@dataclass
class TrainReport:
    steps_run: int = 0
    restarts: int = 0
    stragglers: int = 0
    guard_trips: int = 0
    losses: List[float] = field(default_factory=list)
    events: List[str] = field(default_factory=list)


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig, *,
                 global_batch: int, seq_len: int,
                 checkpoint_dir: Optional[str] = None,
                 impl: Impl = Impl(remat=False),
                 workers: Optional[List[str]] = None,
                 injector: Optional[FailureInjector] = None,
                 mesh=None, dp=None):
        self.cfg, self.tcfg = cfg, tcfg
        self.global_batch, self.seq_len = global_batch, seq_len
        self.impl = impl
        self.mesh = mesh
        self.dataset = SyntheticDataset(cfg, seq_len, seed=tcfg.seed)
        self.ckpt = (Checkpointer(checkpoint_dir, keep=tcfg.keep_checkpoints)
                     if checkpoint_dir else None)
        self.monitor = HeartbeatMonitor(workers or ["w0"], timeout=1e9)
        self.injector = injector or FailureInjector()
        self.straggler = StragglerDetector()
        self._step_fn = None
        self.dp = dp

    # -- state ------------------------------------------------------------
    def init_state(self, seed: int = 0):
        params = init_params(self.cfg, jax.random.PRNGKey(seed))
        return {"params": params, "opt": init_opt_state(params)}

    def _fn(self):
        if self._step_fn is None:
            step = make_train_step(self.cfg, self.tcfg, self.impl, dp=self.dp)
            self._step_fn = jax.jit(step, donate_argnums=(0, 1))
        return self._step_fn

    # -- checkpoint/restart -------------------------------------------------
    def save(self, step: int, state, blocking=False):
        if self.ckpt:
            self.ckpt.save(step, {"params": state["params"], "opt": state["opt"]},
                           blocking=blocking)

    def restore_or_init(self):
        state = self.init_state(self.tcfg.seed)
        start = 0
        if self.ckpt and self.ckpt.latest_step() is not None:
            start, host = self.ckpt.restore(
                {"params": state["params"], "opt": state["opt"]})
            state = jax.tree.map(jax.numpy.asarray, host)
        return start, state

    # -- main loop ------------------------------------------------------------
    def run(self, num_steps: int, state=None, start_step: int = 0,
            report: Optional[TrainReport] = None) -> TrainReport:
        report = report or TrainReport()
        if state is None:
            start_step, state = self.restore_or_init()
            if start_step:
                report.events.append(f"resumed from checkpoint step {start_step}")
        fn = self._fn()
        step = start_step
        import contextlib
        ctx = self.mesh if self.mesh is not None else contextlib.nullcontext()
        with ctx:
            while step < num_steps:
                # -- failure detection / restart -------------------------------
                failed = self.injector.fire(step, self.monitor)
                if failed or self.monitor.check():
                    report.restarts += 1
                    report.events.append(
                        f"step {step}: workers failed {sorted(failed)}; "
                        f"restarting from last checkpoint")
                    for w in failed:            # replacement joins
                        self.monitor._workers[w].alive = True
                        self.monitor.beat(w)
                    self.injector.schedule.pop(step, None)
                    if self.ckpt:
                        self.ckpt.wait()
                        step, state = self.restore_or_init()
                    continue

                batch = self.dataset.batch(step, self.global_batch)
                t0 = time.perf_counter()
                try:
                    params, opt, metrics = fn(state["params"], state["opt"], batch)
                except GuardTripError as e:
                    report.guard_trips += 1
                    report.events.append(f"step {step}: guard trip — retry ({e.detail})")
                    continue
                # fabric-guarded steps surface MAC verification as a metric;
                # a trip means a corrupted exchange — the step result is
                # untrusted, so recover from the last checkpoint (donated
                # buffers preclude in-place retry)
                if float(metrics.get("guard_ok", 1)) == 0:
                    report.guard_trips += 1
                    report.events.append(
                        f"step {step}: channel guard tripped — restoring "
                        f"last checkpoint")
                    if self.ckpt:
                        self.ckpt.wait()
                        step, state = self.restore_or_init()
                    else:
                        state = self.init_state(self.tcfg.seed)
                        step = 0
                    continue
                state = {"params": params, "opt": opt}
                dt = time.perf_counter() - t0
                if self.straggler.observe(dt):
                    report.stragglers += 1
                    report.events.append(
                        f"step {step}: straggler ({dt:.3f}s vs median "
                        f"{self.straggler.median:.3f}s)")
                loss = float(metrics["loss"])
                report.losses.append(loss)
                report.steps_run += 1
                step += 1
                if self.tcfg.log_every and step % self.tcfg.log_every == 0:
                    print(f"step {step:5d} loss {loss:8.4f} "
                          f"lr {float(metrics['lr']):.2e} "
                          f"gnorm {float(metrics['grad_norm']):.3f} "
                          f"({dt*1e3:.0f} ms)")
                if self.ckpt and step % self.tcfg.checkpoint_every == 0:
                    self.save(step, state)
            if self.ckpt:
                self.save(num_steps, state, blocking=True)
        return report
