"""Serving engine: continuous batching over a fixed slot grid.

Requests (prompts) occupy slots of a size-B decode batch; every engine tick
runs ONE jitted decode_step for all slots with per-slot positions (the
per-slot KV insert is kvcache.dense_cache_insert_rows). New requests join
as slots free up — no batch-wide barrier, the production pattern for
high-throughput decode. Prompt tokens are fed incrementally through the
same decode path (teacher-forced), then generation continues from the
model's samples until EOS/max_new.

Works for dense and SSM families (per-slot positions; ring caches need
uniform positions and are served by the batch path / dry-run cells).
"""
from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.transports import ServiceCrashed
from repro.models import decode_step, init_decode_state
from repro.models.transformer import Impl


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int = 16
    eos_id: Optional[int] = None
    # lane-12 QoS class (framing.PRIO_*): urgent requests are admitted to
    # freed decode slots ahead of older bulk work (docs/protocol.md §10)
    priority: int = 0
    # filled by the engine
    generated: List[int] = field(default_factory=list)
    slot: int = -1
    done: bool = False
    submitted_at: float = 0.0
    finished_at: float = 0.0


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 8,
                 max_seq: int = 256, impl: Impl = Impl(remat=False),
                 dtype=jnp.float32, greedy: bool = True, seed: int = 0):
        assert cfg.swa_window is None or max_seq <= cfg.swa_window, \
            "ring caches need uniform positions; lower max_seq or use dense"
        self.cfg, self.params = cfg, params
        self.B, self.max_seq = max_batch, max_seq
        self.impl, self.dtype = impl, dtype
        self.greedy = greedy
        self.key = jax.random.PRNGKey(seed)

        state = init_decode_state(cfg, params, max_batch, max_seq,
                                  dtype=dtype, impl=impl)
        state["pos"] = jnp.zeros((max_batch,), jnp.int32)
        self.state = state
        self._step = jax.jit(
            lambda p, s, t: decode_step(cfg, p, s, t, impl=impl, dtype=dtype))

        self.slots: List[Optional[Request]] = [None] * max_batch
        self.queue: List[Request] = []
        self.current_token = np.zeros((max_batch, 1), np.int32)
        self.prompt_cursor = np.zeros(max_batch, np.int64)
        self.completed: List[Request] = []
        self.ticks = 0

    # -- request management -----------------------------------------------
    def submit(self, req: Request):
        req.submitted_at = time.perf_counter()
        self.queue.append(req)

    def _admit(self):
        from repro.core.gateway import priority_rank    # lazy: no cycle
        for b in range(self.B):
            if self.slots[b] is None and self.queue:
                # priority-aware admission (docs/protocol.md §10): the
                # most urgent class boards first, FIFO within a class —
                # the stable (rank, arrival) key means pure-FIFO behavior
                # is unchanged when every request is PRIO_NORMAL
                i = min(range(len(self.queue)),
                        key=lambda k: (priority_rank(self.queue[k].priority),
                                       k))
                req = self.queue.pop(i)
                req.slot = b
                self.slots[b] = req
                # reset slot: zero its cache rows + position
                self.state["caches"] = jax.tree.map(
                    lambda c: c.at[:, b].set(0) if c.ndim >= 2 else c,
                    self.state["caches"])
                self.state["pos"] = self.state["pos"].at[b].set(0)
                self.current_token[b, 0] = req.prompt[0]
                self.prompt_cursor[b] = 1

    def _retire(self, b: int):
        req = self.slots[b]
        req.done = True
        req.finished_at = time.perf_counter()
        self.completed.append(req)
        self.slots[b] = None

    # -- engine tick ---------------------------------------------------------
    def tick(self):
        self._admit()
        if all(s is None for s in self.slots):
            return False
        logits, self.state = self._step(self.params, self.state,
                                        jnp.asarray(self.current_token))
        if self.greedy:
            nxt = np.asarray(jnp.argmax(logits[:, -1], -1), np.int32)
        else:
            self.key, k = jax.random.split(self.key)
            nxt = np.asarray(jax.random.categorical(k, logits[:, -1]), np.int32)
        self.ticks += 1

        for b, req in enumerate(self.slots):
            if req is None:
                continue
            cur = int(self.prompt_cursor[b])
            if cur < len(req.prompt):              # still feeding the prompt
                self.current_token[b, 0] = req.prompt[cur]
                self.prompt_cursor[b] = cur + 1
                continue
            tok = int(nxt[b])
            req.generated.append(tok)
            self.current_token[b, 0] = tok
            pos = int(self.state["pos"][b])
            if (len(req.generated) >= req.max_new
                    or (req.eos_id is not None and tok == req.eos_id)
                    or pos >= self.max_seq - 1):
                self._retire(b)
        return True

    def run_until_drained(self, max_ticks: int = 10_000):
        while (self.queue or any(s is not None for s in self.slots)) \
                and self.ticks < max_ticks:
            self.tick()
        return self.completed

    def reset(self) -> List[Request]:
        """Crash recovery: drop all in-flight work and return to an empty
        slot grid (caches/positions are re-zeroed per slot on admit).
        → the requests that were lost (queued + slotted)."""
        lost = [r for r in self.slots if r is not None] + list(self.queue)
        self.slots = [None] * self.B
        self.queue = []
        self.current_token[:] = 0
        self.prompt_cursor[:] = 0
        self.state["pos"] = jnp.zeros((self.B,), jnp.int32)
        return lost


# ---------------------------------------------------------------------------
# gateway-facing front-end
# ---------------------------------------------------------------------------

def encode_prompt(prompt: List[int], max_new: int = 16) -> np.ndarray:
    """Gateway wire format for EngineService: int32 [max_new, *prompt]."""
    return np.asarray([max_new, *prompt], np.int32)


class EngineService:
    """Thread-safe inference service over a :class:`ServingEngine`.

    The engine itself is single-threaded (one jitted decode step over the
    slot grid). This wrapper runs the tick loop on ONE background thread and
    lets N concurrent callers (gateway service threads) submit prompts and
    block until their request retires — continuous batching absorbs the
    concurrency: all admitted prompts share every decode step, so aggregate
    throughput scales with occupancy, not callers.

    ``handler`` is the gateway/transport service handler: request payload is
    int32 ``[max_new, tok0, tok1, ...]`` (see :func:`encode_prompt`),
    response is the int32 generated-token array.

    Self-healing: if the tick loop dies mid-decode (a crashed engine
    worker), the loop marks every in-flight request failed with a typed
    :class:`ServiceCrashed` (so gateway retry layers fail over immediately
    instead of waiting out the deadline), resets the slot grid, and keeps
    serving — the next submit decodes on the recovered engine.
    """

    def __init__(self, engine: ServingEngine, *, timeout: float = 300.0,
                 idle_wait: float = 0.02):
        self.engine = engine
        self.timeout = timeout
        self._idle_wait = idle_wait
        self._lock = threading.Lock()           # guards engine + tables
        self._events: Dict[int, threading.Event] = {}
        self._done: Dict[int, Request] = {}
        self._failed: Dict[int, BaseException] = {}
        self._abandoned: set = set()            # timed-out rids: drop results
        self._rid = itertools.count()
        self._consumed = 0                      # engine.completed drained so far
        self._work = threading.Event()          # submit signal for idle loop
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.crashes = 0                        # tick-loop crashes survived
        self.cohorts: List[int] = []            # batch-submission sizes seen
        self._inject_crash = False              # test hook: die on next tick

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> "EngineService":
        if self._thread is None:
            self._thread = threading.Thread(target=self._run, daemon=True,
                                            name="engine-service")
            self._thread.start()
        return self

    def close(self):
        self._stop.set()
        self._work.set()
        if self._thread is not None:
            self._thread.join(timeout=10)
        # fail every still-blocked caller fast instead of letting them sit
        # out the full timeout against a dead tick loop
        with self._lock:
            pending = list(self._events.values())
            self._events.clear()
        for ev in pending:
            ev.set()

    # -- tick loop (one thread owns the engine) -----------------------------
    def inject_crash(self):
        """Chaos hook: make the next engine tick die (deterministically)."""
        self._inject_crash = True
        self._work.set()

    def _recover(self, cause: BaseException):
        """Crash containment: deliver anything that finished during the
        dying tick, fail every truly in-flight request with a typed
        ServiceCrashed NOW (no deadline stall), reset the engine, keep
        serving."""
        with self._lock:
            self.crashes += 1
            events = []
            # requests the crashing tick already retired completed honestly
            # — deliver them, don't strand their callers for the deadline
            for req in self.engine.completed[self._consumed:]:
                if req.rid in self._abandoned:
                    self._abandoned.discard(req.rid)
                    continue
                self._done[req.rid] = req
                events.append(self._events.pop(req.rid, None))
            del self.engine.completed[:]
            self._consumed = 0
            lost = self.engine.reset()
            exc = ServiceCrashed(
                f"engine worker crashed mid-decode ({type(cause).__name__}: "
                f"{cause}); request lost — safe to retry")
            for req in lost:
                if req.rid in self._abandoned:
                    self._abandoned.discard(req.rid)
                    continue
                self._failed[req.rid] = exc
                events.append(self._events.pop(req.rid, None))
        for ev in events:
            if ev is not None:
                ev.set()

    def _run(self):
        while not self._stop.is_set():
            try:
                with self._lock:
                    if self._inject_crash:
                        self._inject_crash = False
                        raise RuntimeError("injected engine crash")
                    progressed = self.engine.tick()
                    fresh = self.engine.completed[self._consumed:]
                    # drain: the service owns the engine, and an unbounded
                    # completed list is a leak at serving timescales
                    del self.engine.completed[:]
                    self._consumed = 0
                    for req in fresh:
                        if req.rid in self._abandoned:  # caller timed out
                            self._abandoned.discard(req.rid)
                            continue
                        self._done[req.rid] = req
                    events = [self._events.pop(r.rid, None) for r in fresh]
            except Exception as e:      # a dead tick loop strands callers —
                self._recover(e)        # heal and keep serving instead
                continue
            for ev in events:
                if ev is not None:
                    ev.set()
            if not progressed:
                self._work.wait(timeout=self._idle_wait)
                self._work.clear()

    # -- service handler (called from N transport/gateway threads) ----------
    @staticmethod
    def _parse_req(req: np.ndarray):
        """Wire payload int32 ``[max_new, tok0, ...]`` → (max_new, prompt).

        The zero-copy data plane hands requests in as read-only views of a
        transport region/arena slot; a contiguous whole-word payload is
        reinterpreted in place (no tobytes() snapshot — the prompt ints are
        consumed before the handler returns, within the view's lifetime)."""
        arr = np.asarray(req)
        if arr.dtype != np.int32:
            if arr.flags.c_contiguous and arr.nbytes % 4 == 0:
                arr = arr.reshape(-1).view(np.uint8).view(np.int32)
            else:
                arr = np.frombuffer(np.ascontiguousarray(arr).tobytes(),
                                    np.int32)
        arr = arr.reshape(-1)
        if arr.size < 2:
            raise ValueError("inference request needs [max_new, tok0, ...]")
        return int(arr[0]), [int(t) for t in arr[1:]]

    def _cancel(self, rid: int):
        """Forget an in-flight request: already finished → drop its result;
        still queued → remove outright; already decoding in a slot → mark
        abandoned so its result is dropped at retirement instead of leaking
        into the done table."""
        self._events.pop(rid, None)
        if self._done.pop(rid, None) is not None \
                or self._failed.pop(rid, None) is not None:
            return                      # retired already — nothing to abandon
        before = len(self.engine.queue)
        self.engine.queue = [r for r in self.engine.queue if r.rid != rid]
        if len(self.engine.queue) == before:
            self._abandoned.add(rid)

    def _await(self, rid: int, ev: threading.Event,
               deadline: float) -> np.ndarray:
        """Block until ``rid`` retires (bounded by ``deadline``); return its
        generated tokens or raise its typed failure."""
        ev.wait(timeout=max(0.0, deadline - time.monotonic()))
        with self._lock:
            done = self._done.pop(rid, None)
            failed = self._failed.pop(rid, None)
        if done is not None:
            return np.asarray(done.generated, np.int32)
        if failed is not None:          # engine crashed mid-decode: typed,
            raise failed                # immediate — retry layers fail over
        if self._stop.is_set():
            raise RuntimeError(
                f"EngineService closed while request {rid} was in flight")
        with self._lock:
            self._cancel(rid)
        from repro.core import gateway as _gw     # no import cycle: lazy
        from repro.core.transports import DeadlineExpired
        remaining = _gw.remaining_budget()
        if remaining is not None and remaining <= 0:
            raise DeadlineExpired(
                f"inference request {rid}: caller's propagated deadline "
                "expired while decoding — request cancelled")
        raise TimeoutError(f"inference request {rid} timed out "
                           f"after {self.timeout}s")

    def _deadline(self) -> float:
        """This request's retirement deadline: the service's configured
        bound, TIGHTENED by the caller's propagated budget when the request
        arrived through the gateway with a deadline word (docs/protocol.md
        §9) — a 1 s caller budget bounds the decode wait at ~1 s instead of
        the service-wide default."""
        from repro.core import gateway as _gw     # no import cycle: lazy
        remaining = _gw.remaining_budget()
        bound = self.timeout if remaining is None \
            else min(self.timeout, max(0.0, remaining))
        return time.monotonic() + bound

    def handler(self, req: np.ndarray) -> np.ndarray:
        """One prompt in, one int32 token array out (the gateway/transport
        service handler). Blocks until the request retires from the shared
        decode batch or the service deadline expires."""
        max_new, prompt = self._parse_req(req)
        if self._stop.is_set():
            raise RuntimeError("EngineService is closed")
        # the caller's MAC-covered lane-12 class, published thread-locally
        # by the gateway's execution core — urgent prompts board freed
        # decode slots ahead of queued bulk work (docs/protocol.md §10)
        from repro.core import gateway as _gw     # no import cycle: lazy
        prio = _gw.current_priority()
        ev = threading.Event()
        with self._lock:
            rid = next(self._rid)
            self._events[rid] = ev
            self.engine.submit(Request(rid=rid, prompt=prompt,
                                       max_new=max_new, priority=prio))
        self._work.set()
        return self._await(rid, ev, self._deadline())

    def handler_batch(self, reqs) -> List[np.ndarray]:
        """Batched prompt submission (the gateway's ``batch_handler``).

        All N prompts enter the engine queue under ONE lock acquisition and
        one wake signal, so they join the decode slot grid as a cohort and
        share every decode step from the first tick — continuous batching
        absorbs the whole batch instead of trickling it in per call. Both
        the explicit batch envelope AND an auto-coalesced cohort of inline
        calls (the gateway mux's scatter group) land here, so transparent
        coalescing reaches the decode grid as one admission unit
        (``cohorts`` records each submission's size for observability).
        Returns the N generated-token arrays in request order; if any
        request fails (engine crash mid-decode, timeout) its typed error is
        raised and the rest of the cohort is cancelled — the gateway turns
        that into per-item typed errors for the whole batch."""
        parsed = [self._parse_req(r) for r in reqs]
        if self._stop.is_set():
            raise RuntimeError("EngineService is closed")
        from repro.core import gateway as _gw     # no import cycle: lazy
        prio = _gw.current_priority()   # the cohort's most-urgent class
        waits = []
        with self._lock:
            self.cohorts.append(len(parsed))
            for max_new, prompt in parsed:
                rid = next(self._rid)
                ev = threading.Event()
                self._events[rid] = ev
                self.engine.submit(
                    Request(rid=rid, prompt=prompt, max_new=max_new,
                            priority=prio))
                waits.append((rid, ev))
        self._work.set()
        deadline = self._deadline()
        outs: List[np.ndarray] = []
        for k, (rid, ev) in enumerate(waits):
            try:
                outs.append(self._await(rid, ev, deadline))
            except BaseException:
                with self._lock:        # don't strand the rest of the cohort
                    for later_rid, _ in waits[k + 1:]:
                        self._cancel(later_rid)
                raise
        return outs

    __call__ = handler


# ---------------------------------------------------------------------------
# replica fleets (N engines behind one service name)
# ---------------------------------------------------------------------------

def fleet_handler(engine_factory: Callable[[], ServingEngine], *,
                  timeout: float = 300.0):
    """Service handler for one proc-backed engine replica.

    The EngineService — engine, slot grid, AND its tick-loop thread — is
    constructed lazily inside the replica's forked child on first request:
    threads do not survive ``fork``, so an EngineService started in the
    gateway process would reach the child as a dead tick loop and every
    submit would stall out its deadline. Lazy construction also keeps
    replica registration cheap (the fork itself is already lazy in
    procwire) and gives each replica a fully private engine.
    """
    state: Dict[str, EngineService] = {}

    def handler(req: np.ndarray) -> np.ndarray:
        svc = state.get("svc")
        if svc is None:
            svc = state["svc"] = EngineService(
                engine_factory(), timeout=timeout).start()
        return svc.handler(req)

    return handler


def register_engine_fleet(gw, name: str,
                          engine_factory: Callable[[], ServingEngine],
                          replicas: int, *,
                          transport: str = "mpklink_opt_proc",
                          transport_kwargs: Optional[dict] = None,
                          timeout: float = 300.0) -> List[int]:
    """Register ``replicas`` independent engine replicas behind one service
    name on ``gw`` (a :class:`repro.core.gateway.ServiceGateway`). Each
    replica is its own transport instance — own protection domain, epoch,
    shm segment, and (for proc transports) its own child process running a
    private engine via :func:`fleet_handler`. → the replica ids, in join
    order."""
    return [gw.register_replica(name, fleet_handler(engine_factory,
                                                    timeout=timeout),
                                transport=transport,
                                transport_kwargs=transport_kwargs)
            for _ in range(replicas)]
