from repro.configs.base import (
    ModelConfig,
    MoEConfig,
    SSMConfig,
    ShapeConfig,
    OptimizerConfig,
    ShardingConfig,
    TrainConfig,
    ServeConfig,
    SHAPES,
    SHAPES_BY_NAME,
    shape_applicable,
    replace,
)
from repro.configs.registry import ARCH_IDS, get_config, get_reduced, all_cells

__all__ = [
    "ModelConfig", "MoEConfig", "SSMConfig", "ShapeConfig", "OptimizerConfig",
    "ShardingConfig", "TrainConfig", "ServeConfig", "SHAPES", "SHAPES_BY_NAME",
    "shape_applicable", "replace", "ARCH_IDS", "get_config", "get_reduced", "all_cells",
]
