"""grok-1-314b — 8-expert top-2 MoE, the memory-pressure stress arch. [hf:xai-org/grok-1]

64 layers, d_model 6144, 48 query heads (head_dim 128), 8 KV heads,
8 experts x d_ff 32768 top-2, vocab 131072. 314B params → bf16 weights alone
are 628 GB: requires the fsdp_tp sharding policy (params sharded over data and
model axes, per-layer all-gather under remat+scan). Pure full attention →
long_500k skipped.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    moe=MoEConfig(num_experts=8, top_k=2),
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b-smoke",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=96,
        vocab_size=256,
        moe=MoEConfig(num_experts=4, top_k=2),
    )
