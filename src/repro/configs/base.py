"""Configuration dataclasses for the repro framework.

Plain dataclasses (no external deps) so configs are hashable-ish, printable and
trivially serializable. One ``ModelConfig`` per assigned architecture lives in
``repro.configs.<arch>``; the registry maps ``--arch`` ids to them.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model architecture
# ---------------------------------------------------------------------------

FAMILIES = ("dense", "moe", "ssm", "hybrid", "vlm", "audio")


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block hyper-parameters."""

    d_state: int = 128          # N — state dimension per head
    head_dim: int = 64          # P — channels per SSM head
    expand: int = 2             # d_inner = expand * d_model
    chunk_size: int = 128       # SSD chunk length (MXU-aligned)
    n_groups: int = 1           # B/C groups (GVA-style)
    conv_width: int = 4         # depthwise causal conv width
    dt_min: float = 1e-3
    dt_max: float = 1e-1


@dataclass(frozen=True)
class MoEConfig:
    """Top-k routed mixture-of-experts FFN.

    ``group_size``: tokens are routed in independent groups of this size
    (GShard "groups"). None = one global group — the naive baseline whose
    dispatch einsums are QUADRATIC in tokens (recorded as such in
    EXPERIMENTS.md §Perf; the grouped variant is hillclimb iteration 1)."""

    num_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    load_balance_loss: float = 1e-2
    group_size: Optional[int] = None


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # one of FAMILIES
    num_layers: int
    d_model: int
    num_heads: int                    # query heads (0 for attention-free)
    num_kv_heads: int
    d_ff: int                         # FFN hidden (per expert when MoE)
    vocab_size: int
    head_dim: int = 0                 # 0 → d_model // num_heads
    # attention variants
    qk_norm: bool = False
    swa_window: Optional[int] = None  # sliding-window attention width
    rope_theta: float = 10_000.0
    # norms / activations
    norm_type: str = "rmsnorm"        # rmsnorm | np_layernorm | layernorm
    norm_eps: float = 1e-5
    act: str = "silu"
    mlp_type: str = "glu"             # glu (gate/up/down) | mlp (up/down)
    tie_embeddings: bool = False
    # family extensions
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    attn_every: int = 0               # hybrid: one (shared) attn block every N ssm blocks
    shared_attn: bool = False         # hybrid: attention weights shared across insertions
    # encoder-decoder (audio family)
    enc_dec: bool = False
    enc_layers: int = 0
    enc_ctx: int = 0                  # encoder context length (e.g. whisper 1500 frames)
    # modality frontend stubs: precomputed embeddings prepended to the token sequence
    vision_tokens: int = 0            # vlm: number of patch-embedding tokens
    vision_dim: int = 0               # vlm: patch-embedding feature dim (projected to d_model)
    frontend_note: str = ""
    # head padding (beyond-paper perf knob): grow q/kv head counts with
    # ZERO-weight heads so they tile the TP axis. Function-preserving: pad q
    # rows of wq and pad output rows of wo are zero, so pad heads contribute
    # exactly 0. None = the paper-faithful baseline (non-divisible heads are
    # replicated over the model axis instead — see sharding/specs.py).
    pad_q_heads: Optional[int] = None
    pad_kv_heads: Optional[int] = None

    def __post_init__(self):
        assert self.family in FAMILIES, self.family
        if self.num_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # -- derived ------------------------------------------------------------
    @property
    def q_heads_eff(self) -> int:
        return self.pad_q_heads or self.num_heads

    @property
    def kv_heads_eff(self) -> int:
        return self.pad_kv_heads or self.num_kv_heads

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True when the arch can serve 500k-token contexts (SSM state or SWA ring)."""
        return self.family in ("ssm", "hybrid") or self.swa_window is not None

    @property
    def d_inner(self) -> int:
        return (self.ssm.expand * self.d_model) if self.ssm else 0

    @property
    def ssm_heads(self) -> int:
        return (self.d_inner // self.ssm.head_dim) if self.ssm else 0

    def param_count(self) -> int:
        """Approximate parameter count (exact for what we instantiate)."""
        c, D = self, self.d_model
        n = c.vocab_size * D                      # embed
        if not c.tie_embeddings:
            n += c.vocab_size * D                 # lm head
        per_attn = (
            c.num_heads * c.head_dim * D          # q
            + 2 * c.num_kv_heads * c.head_dim * D  # k, v
            + c.num_heads * c.head_dim * D        # o
        )
        per_ffn = (3 if c.mlp_type == "glu" else 2) * D * c.d_ff  # (gate,) up, down
        if c.moe:
            per_ffn = c.moe.num_experts * per_ffn + D * c.moe.num_experts
        per_ssm = 0
        if c.ssm:
            di, s = c.d_inner, c.ssm
            per_ssm = (
                D * (2 * di + 2 * s.n_groups * s.d_state + self.ssm_heads)  # in_proj(zx) + BC + dt
                + s.conv_width * (di + 2 * s.n_groups * s.d_state)           # conv
                + self.ssm_heads * 2                                          # A_log, D
                + di * D                                                      # out_proj
                + di                                                          # gate norm
            )
        norm_p = 0 if c.norm_type == "np_layernorm" else D
        if c.family == "ssm":
            n += c.num_layers * (per_ssm + 2 * norm_p)
        elif c.family == "hybrid":
            n_attn = 1 if c.shared_attn else max(1, c.num_layers // max(1, c.attn_every))
            n += c.num_layers * (per_ssm + 2 * norm_p) + n_attn * (per_attn + norm_p)
        elif c.enc_dec:
            n += c.enc_layers * (per_attn + per_ffn + 3 * norm_p)             # enc self+ffn
            n += c.num_layers * (2 * per_attn + per_ffn + 4 * norm_p)         # dec self+cross+ffn
        else:
            n += c.num_layers * (per_attn + per_ffn + 2 * norm_p)
        if c.vision_tokens:
            n += c.vision_dim * D + D
        return int(n)

    def active_param_count(self) -> int:
        """Activated params per token (MoE counts top_k experts only)."""
        if not self.moe:
            return self.param_count()
        c = self
        dense_ffn = 3 * c.d_model * c.d_ff
        unused = (c.moe.num_experts - c.moe.top_k) * dense_ffn * c.num_layers
        return int(self.param_count() - unused)


# ---------------------------------------------------------------------------
# Input shapes (the assigned grid)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)
SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runs?, reason) for an (arch, shape) cell. Skips are recorded, never silent."""
    if shape.name == "long_500k" and not model.sub_quadratic:
        return False, "long_500k needs sub-quadratic attention; %s is pure full-attention" % model.name
    return True, ""


# ---------------------------------------------------------------------------
# Training / serving / sharding knobs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


@dataclass(frozen=True)
class ShardingConfig:
    policy: str = "tp"            # tp | fsdp_tp
    # MPKLink fabric switches (beyond-paper explicit-collective paths)
    fabric_tp: bool = False       # explicit shard_map TP exchange instead of GSPMD
    fabric_guard: bool = False    # tag+MAC guard on fabric channels
    grad_compression: bool = False  # int8+EF on cross-pod gradient reduce
    remat: str = "block"          # none | block | full
    scan_layers: bool = True


@dataclass(frozen=True)
class TrainConfig:
    microbatch_size: int = 8      # per-step microbatch (grad accumulation over global/micro)
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    sharding: ShardingConfig = field(default_factory=ShardingConfig)
    seed: int = 0
    log_every: int = 10
    checkpoint_every: int = 100
    keep_checkpoints: int = 3


@dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 128
    max_seq: int = 32_768
    dtype: str = "bfloat16"
    sharding: ShardingConfig = field(default_factory=ShardingConfig)
    decode_steps: int = 32


def replace(cfg, **kw):
    return dataclasses.replace(cfg, **kw)
