"""olmo-1b — dense with non-parametric LayerNorm. [arXiv:2402.00838; hf]

16 layers, d_model 2048, 16 heads (MHA, kv=16, head_dim 128), d_ff 8192,
vocab 50304. OLMo's norms carry no scale/bias (non-parametric) — exercised as
norm_type="np_layernorm". Pure full attention → long_500k skipped.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=8192,
    vocab_size=50304,
    norm_type="np_layernorm",
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        norm_type="np_layernorm",
        tie_embeddings=True,
    )
