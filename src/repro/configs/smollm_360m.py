"""smollm-360m — llama-architecture small model. [hf:HuggingFaceTB/SmolLM-360M; hf]

32 layers, d_model 960, 15 query heads (head_dim 64), 5 KV heads, d_ff 2560,
vocab 49152. The 15-head count deliberately exercises GSPMD padded sharding
on the 16-way model axis. Pure full attention → long_500k skipped.
Also the end-to-end training example target (~360M params ≈ the "~100M-class"
driver once reduced; examples/train_smollm.py trains a width-reduced variant).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    num_layers=32,
    d_model=960,
    num_heads=15,
    num_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab_size=49152,
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m-smoke",
        family="dense",
        num_layers=2,
        d_model=60,
        num_heads=3,
        num_kv_heads=1,
        head_dim=20,
        d_ff=160,
        vocab_size=256,
        tie_embeddings=True,
    )
