"""whisper-tiny — encoder-decoder with conv audio frontend (stub). [arXiv:2212.04356]

4 encoder + 4 decoder layers, d_model 384, 6 heads (MHA, head_dim 64),
d_ff 1536, vocab 51865. The conv1d/mel frontend is a STUB per the assignment:
input_specs() supplies precomputed frame embeddings (batch, 1500, 384).
Decoder self-attention is full attention → long_500k skipped; decode shapes
run against the decoder with encoder context cross-attended.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,            # decoder layers
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    norm_type="layernorm",
    act="gelu",
    mlp_type="mlp",
    enc_dec=True,
    enc_layers=4,
    enc_ctx=1500,
    frontend_note="conv+mel frontend stub: input_specs() supplies (batch, 1500, 384) "
                  "precomputed frame embeddings fed to the encoder.",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny-smoke",
        family="audio",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        norm_type="layernorm",
        act="gelu",
    mlp_type="mlp",
        enc_dec=True,
        enc_layers=2,
        enc_ctx=24,
    )
