"""Architecture registry: ``--arch <id>`` → ModelConfig (full + reduced smoke)."""
from __future__ import annotations

import importlib
from typing import Callable, Dict, List, Tuple

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, shape_applicable

_ARCH_MODULES: Dict[str, str] = {
    "llava-next-mistral-7b": "repro.configs.llava_next_mistral_7b",
    "zamba2-2.7b": "repro.configs.zamba2_2p7b",
    "mamba2-1.3b": "repro.configs.mamba2_1p3b",
    "qwen3-14b": "repro.configs.qwen3_14b",
    "smollm-360m": "repro.configs.smollm_360m",
    "llama3.2-1b": "repro.configs.llama3p2_1b",
    "olmo-1b": "repro.configs.olmo_1b",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "grok-1-314b": "repro.configs.grok1_314b",
    "whisper-tiny": "repro.configs.whisper_tiny",
}

ARCH_IDS: Tuple[str, ...] = tuple(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[arch]).CONFIG


def get_reduced(arch: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return importlib.import_module(_ARCH_MODULES[arch]).reduced()


def all_cells() -> List[Tuple[str, ShapeConfig, bool, str]]:
    """Every (arch, shape) cell with (runs?, skip_reason)."""
    cells = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES:
            ok, why = shape_applicable(cfg, shape)
            cells.append((arch, shape, ok, why))
    return cells
