"""llama3.2-1b — small llama3. [hf:meta-llama/Llama-3.2-1B; unverified]

16 layers, d_model 2048, 32 query heads (head_dim 64), 8 KV heads, d_ff 8192,
vocab 128256, rope_theta 500000. Pure full attention → long_500k skipped.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=128256,
    rope_theta=500_000.0,
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-1b-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        tie_embeddings=True,
    )
