"""qwen3-14b — dense, qk_norm + GQA. [hf:Qwen/Qwen3-8B family; hf]

40 layers, d_model 5120, 40 query heads (head_dim 128), 8 KV heads, d_ff 17408,
vocab 151936. RMSNorm on q/k per head (qk_norm). Pure full attention →
long_500k is skipped (documented).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1e6,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen3-14b-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        qk_norm=True,
    )
