"""llava-next-mistral-7b — Mistral-7B backbone + anyres vision frontend (stub).

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
Backbone only per the assignment; the anyres tiling frontend is a stub that
supplies precomputed patch embeddings (CLIP-ViT-L/14 336px → 576 tokens/tile,
anyres up to 5 tiles → 2880 vision tokens projected 1024 → 4096).
Mistral-7B uses sliding-window attention (window 4096) → sub-quadratic,
so the long_500k cell runs with an SWA ring cache.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    swa_window=4096,
    rope_theta=1e6,
    vision_tokens=2880,
    vision_dim=1024,
    frontend_note="anyres tiling stub: input_specs() supplies (batch, 2880, 1024) "
                  "precomputed patch embeddings; backbone projects to d_model.",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="llava-next-mistral-7b-smoke",
        family="vlm",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        swa_window=32,
        vision_tokens=8,
        vision_dim=24,
    )
