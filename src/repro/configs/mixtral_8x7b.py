"""mixtral-8x7b — 8-expert top-2 MoE with sliding-window attention. [arXiv:2401.04088; hf]

32 layers, d_model 4096, 32 query heads (head_dim 128), 8 KV heads,
8 experts x d_ff 14336 with top-2 routing, vocab 32000, SWA window 4096.
SWA → sub-quadratic → long_500k runs with a ring KV cache.
The EP all_to_all dispatch is the paper-representative MPKLink channel.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    swa_window=4096,
    rope_theta=1e6,
    moe=MoEConfig(num_experts=8, top_k=2),
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b-smoke",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=96,
        vocab_size=256,
        swa_window=32,
        moe=MoEConfig(num_experts=4, top_k=2),
    )
