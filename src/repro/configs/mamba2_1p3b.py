"""mamba2-1.3b — pure SSD (state-space duality) stack, attention-free. [arXiv:2405.21060]

48 layers, d_model 2048, d_inner 4096 (expand 2), 64 SSM heads of dim 64,
d_state 128, chunked SSD scan. vocab 50280. No attention anywhere →
long_500k runs on pure recurrent state (O(1) memory per token at decode).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk_size=128),
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b-smoke",
        family="ssm",
        num_layers=3,
        d_model=64,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=256,
        ssm=SSMConfig(d_state=16, head_dim=16, expand=2, chunk_size=16),
        tie_embeddings=True,
    )
