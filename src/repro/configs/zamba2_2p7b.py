"""zamba2-2.7b — Mamba2 backbone with a shared attention block. [arXiv:2411.15242; hf]

54 Mamba2 layers, d_model 2560; one *shared-weight* full-attention block (32H MHA,
kv=32) interleaved every 6 SSM layers (9 insertions). ssm_state=64.
Hybrid → sub-quadratic → long_500k runs (SSM state + one full-attn block whose
KV cache is the only quadratic-ish structure; at decode it is O(L) per token).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, chunk_size=128),
    attn_every=6,
    shared_attn=True,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b-smoke",
        family="hybrid",
        num_layers=4,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        ssm=SSMConfig(d_state=16, head_dim=16, expand=2, chunk_size=16),
        attn_every=2,
        shared_attn=True,
    )
