"""MPKLink-TPU: protected shared-buffer communication for JAX training &
serving — a production-grade reproduction + TPU adaptation of
"Optimizing Intra-Container Communication with Memory Protection Keys"
(CS.DC 2025). See DESIGN.md for the architecture and EXPERIMENTS.md for
the measured results."""

__version__ = "1.0.0"
