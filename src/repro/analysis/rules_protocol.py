"""Protocol-discipline rules: verify-before-use, view lifetime, clock
discipline, timeout plumbing, and swallowed typed errors.

docs/protocol.md is normative: every payload read is preceded by a MAC
verify, arena-slot views carry a finalizer guard so recycling can never
alias live data, deadlines are computed on the monotonic clock, and a
caller's ``timeout=`` reaches every blocking callee.  These rules encode
the spec clauses the type system cannot.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional, Set

from repro.analysis.engine import (Finding, ModuleContext, Rule, ancestors,
                                   expr_text)

_VERIFY_NAMES = re.compile(r"(verify|parse_frame|check_meta|precheck)")
_DEADLINE_ID = re.compile(r"(deadline|timeout|remaining|expir|budget|"
                          r"elapsed)", re.IGNORECASE)


def _func_name(node: ast.Call) -> str:
    if isinstance(node.func, ast.Attribute):
        return node.func.attr
    if isinstance(node.func, ast.Name):
        return node.func.id
    return ""


def _enclosing_function(node: ast.AST) -> Optional[ast.FunctionDef]:
    for anc in ancestors(node):
        if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return anc
    return None


def _functions(tree: ast.AST):
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


class UnverifiedPayloadRule(Rule):
    """MPK101: frame payload rows read before any ``verify*`` call
    dominates the read.

    A name bound from a receive-side source (a ``recv``-ish call or a
    ``.resp_frame``/``.frame`` slot attribute) whose payload rows
    (``frame[1:...]``) are indexed in a function with no earlier
    ``verify*``/``parse_frame`` call is a read of unauthenticated bytes —
    the §2 guard must dominate every payload use.  The module that
    *defines* ``verify_view`` (framing) is the trusted implementation and
    is exempt."""

    id = "MPK101"
    severity = "error"
    hint = "call framing.verify_view/parse_frame before touching payload rows"

    _SOURCE_CALL = re.compile(r"(recv|read_frame|raw_frame)")
    _SOURCE_ATTR = re.compile(r"(^frame$|_frame$|^resp_frame$)")

    def check_module(self, ctx: ModuleContext) -> List[Finding]:
        # trusted implementation module: it defines the verifier itself
        for fn in _functions(ctx.tree):
            if fn.name in ("verify_view", "verify_batch", "parse_frame"):
                return []
        out: List[Finding] = []
        for fn in _functions(ctx.tree):
            out.extend(self._check_fn(ctx, fn))
        return out

    def _check_fn(self, ctx: ModuleContext, fn) -> List[Finding]:
        tainted: Set[str] = set()
        verified_at: List[int] = []
        for node in ast.walk(fn):
            if isinstance(node, ast.Call) and \
                    _VERIFY_NAMES.search(_func_name(node)):
                verified_at.append(node.lineno)
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                src = node.value
                if isinstance(src, ast.Call) and \
                        self._SOURCE_CALL.search(_func_name(src)):
                    tainted.add(node.targets[0].id)
                elif isinstance(src, ast.Attribute) and \
                        self._SOURCE_ATTR.search(src.attr):
                    tainted.add(node.targets[0].id)
        if not tainted:
            return []
        out: List[Finding] = []
        for node in ast.walk(fn):
            if not (isinstance(node, ast.Subscript)
                    and isinstance(node.value, ast.Name)
                    and node.value.id in tainted
                    and isinstance(node.slice, ast.Slice)):
                continue
            lower = node.slice.lower
            if not (isinstance(lower, ast.Constant) and lower.value == 1):
                continue            # payload rows start at row 1
            if any(v <= node.lineno for v in verified_at):
                continue
            out.append(self.finding(
                ctx, node.lineno,
                f"payload rows of '{node.value.id}' read before any "
                f"verify* call dominates them in {fn.name}()"))
        return out


class ViewEscapeRule(Rule):
    """MPK102: an arena/slot ``verify_view`` result stored on ``self`` or
    returned without the finalizer-guard idiom.

    Ring ``poll()`` views alias recyclable arena storage; §4.3 requires
    ``arena.release_on_collect(view, buf)`` (or an owned ``.copy()``)
    before the view escapes, else a recycled slot aliases data the caller
    still holds.  Lockstep region views (``self._region_*``) have the
    until-next-exchange contract and are exempt."""

    id = "MPK102"
    severity = "error"
    hint = ("register arena.release_on_collect(view, buf) before the view "
            "escapes, or hand out an owned .copy()")

    _EXEMPT_ARG = re.compile(r"region")

    def check_module(self, ctx: ModuleContext) -> List[Finding]:
        out: List[Finding] = []
        for fn in _functions(ctx.tree):
            out.extend(self._check_fn(ctx, fn))
        return out

    def _check_fn(self, ctx: ModuleContext, fn) -> List[Finding]:
        guarded_fn = False
        views: Dict[str, int] = {}
        copied: Set[str] = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                name = _func_name(node)
                if name in ("release_on_collect", "finalize"):
                    guarded_fn = True
                elif name == "copy" and \
                        isinstance(node.func, ast.Attribute) and \
                        isinstance(node.func.value, ast.Name):
                    copied.add(node.func.value.id)
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name) \
                    and self._is_arena_view(node.value):
                views[node.targets[0].id] = node.lineno
        if guarded_fn:
            return []
        out: List[Finding] = []
        for node in ast.walk(fn):
            if isinstance(node, ast.Return) and node.value is not None:
                if isinstance(node.value, ast.Name) and \
                        node.value.id in views and \
                        node.value.id not in copied:
                    out.append(self.finding(
                        ctx, node.lineno,
                        f"arena-slot view '{node.value.id}' returned from "
                        f"{fn.name}() without a finalizer guard"))
                elif self._is_arena_view(node.value):
                    out.append(self.finding(
                        ctx, node.lineno,
                        f"arena-slot verify_view result returned from "
                        f"{fn.name}() without a finalizer guard"))
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Attribute) \
                    and isinstance(node.targets[0].value, ast.Name) \
                    and node.targets[0].value.id == "self":
                val = node.value
                stored = (isinstance(val, ast.Name) and val.id in views
                          and val.id not in copied) \
                    or self._is_arena_view(val)
                if stored:
                    out.append(self.finding(
                        ctx, node.lineno,
                        f"arena-slot verify_view result stored on "
                        f"self.{node.targets[0].attr} in {fn.name}() — "
                        f"outlives the slot with no finalizer guard"))
        return out

    def _is_arena_view(self, node: ast.AST) -> bool:
        if not (isinstance(node, ast.Call)
                and _func_name(node) == "verify_view" and node.args):
            return False
        return not self._EXEMPT_ARG.search(expr_text(node.args[0]))


class TimeTimeDeadlineRule(Rule):
    """MPK103: ``time.time()`` in a deadline/timeout/elapsed computation.

    Wall-clock time jumps under NTP slew; §4.4 requires every deadline on
    the monotonic clock.  Flagged when the call participates in
    arithmetic (an elapsed/deadline computation) or the enclosing
    function handles deadline-ish identifiers.  Bare timestamping
    (``{"ts": time.time()}``) is legitimate and not flagged."""

    id = "MPK103"
    severity = "error"
    hint = "use time.monotonic() (or time.perf_counter() for measurement)"

    def check_module(self, ctx: ModuleContext) -> List[Finding]:
        out: List[Finding] = []
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "time"
                    and isinstance(node.func.value, ast.Name)
                    and node.func.value.id == "time"):
                continue
            in_arith = any(isinstance(a, (ast.BinOp, ast.Compare, ast.AugAssign))
                           for a in ancestors(node))
            fn = _enclosing_function(node)
            deadline_ctx = False
            if fn is not None and not in_arith:
                for sub in ast.walk(fn):
                    if isinstance(sub, ast.Name) and \
                            _DEADLINE_ID.search(sub.id):
                        deadline_ctx = True
                        break
                    if isinstance(sub, ast.arg) and \
                            _DEADLINE_ID.search(sub.arg):
                        deadline_ctx = True
                        break
            if not (in_arith or deadline_ctx):
                continue
            where = f" in {fn.name}()" if fn is not None else ""
            out.append(self.finding(
                ctx, node.lineno,
                f"time.time() used in a deadline/elapsed computation"
                f"{where} — wall clock is not monotonic"))
        return out


_BLOCKING_FWD = ("wait", "wait_for", "poll", "request", "request_into",
                 "acquire", "join", "get", "recv", "call", "call_batch")


class TimeoutNotForwardedRule(Rule):
    """MPK104: a ``timeout`` parameter accepted but never read while the
    body makes blocking calls.

    A dead timeout parameter silently promises a bound the function does
    not honor — §4.4 requires a per-call timeout tighter than the
    transport deadline to be honored by every blocking callee."""

    id = "MPK104"
    severity = "warning"
    hint = ("forward the timeout (or a deadline derived from it) to the "
            "blocking callees")

    def check_module(self, ctx: ModuleContext) -> List[Finding]:
        out: List[Finding] = []
        for fn in _functions(ctx.tree):
            params = [a.arg for a in
                      list(fn.args.args) + list(fn.args.kwonlyargs)
                      if a.arg == "timeout" or a.arg.endswith("_timeout")]
            if not params:
                continue
            used = {n.id for n in ast.walk(fn)
                    if isinstance(n, ast.Name)
                    and isinstance(n.ctx, ast.Load)}
            dead = [p for p in params if p not in used]
            if not dead:
                continue
            blocking = [n for n in ast.walk(fn)
                        if isinstance(n, ast.Call)
                        and isinstance(n.func, ast.Attribute)
                        and n.func.attr in _BLOCKING_FWD]
            if not blocking:
                continue
            out.append(self.finding(
                ctx, fn.lineno,
                f"{fn.name}() accepts '{dead[0]}' but never uses it while "
                f"calling blocking operations "
                f"(line {blocking[0].lineno}: "
                f"{expr_text(blocking[0].func)})"))
        return out


class FreshConstantWaitRule(Rule):
    """MPK106: a deadline-accepting function computes a blocking wait
    from a fresh constant.

    docs/protocol.md §9: once a caller's budget is propagated, every hop
    computes its waits against the REMAINING budget — a handler or
    dispatch path that accepts a deadline/timeout parameter but passes a
    pure numeric literal as a blocking call's timeout re-introduces the
    fixed slack the deadline word was built to remove (the old
    ``+ 30.0`` coalescer bound). A wait expression that references any
    deadline-ish name (``min(remaining, bound)``, ``deadline - now``) is
    clean; a constant-only expression inside a function that was handed a
    budget is the bug."""

    id = "MPK106"
    severity = "warning"
    hint = ("derive the wait from the propagated deadline/remaining "
            "budget (e.g. min(remaining, bound)), not a fresh constant")

    def check_module(self, ctx: ModuleContext) -> List[Finding]:
        out: List[Finding] = []
        for fn in _functions(ctx.tree):
            params = [a.arg for a in (list(fn.args.args)
                                      + list(fn.args.kwonlyargs))
                      if _DEADLINE_ID.search(a.arg)]
            if not params:
                continue            # no budget handed in — out of scope
            for node in ast.walk(fn):
                if node is not fn and isinstance(
                        node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    continue
                if not (isinstance(node, ast.Call)
                        and _func_name(node) in _BLOCKING_FWD):
                    continue
                wait = next((kw.value for kw in node.keywords
                             if kw.arg == "timeout"), None)
                if wait is None and _func_name(node) in ("wait", "acquire") \
                        and len(node.args) == 1:
                    wait = node.args[0]
                if wait is None or not self._constant_only(wait):
                    continue
                out.append(self.finding(
                    ctx, node.lineno,
                    f"{fn.name}() accepts '{params[0]}' but "
                    f"{expr_text(node.func)} waits on the fresh constant "
                    f"{expr_text(wait)} instead of the remaining budget"))
        return out

    def _constant_only(self, node: ast.AST) -> bool:
        """True when the expression is built purely from numeric literals
        (constants, arithmetic over constants) — any Name/Attribute
        reference means the budget (or some state) participates."""
        if isinstance(node, ast.Constant):
            return isinstance(node.value, (int, float)) \
                and not isinstance(node.value, bool)
        if isinstance(node, ast.BinOp):
            return self._constant_only(node.left) \
                and self._constant_only(node.right)
        if isinstance(node, ast.UnaryOp):
            return self._constant_only(node.operand)
        return False


class SwallowedErrorRule(Rule):
    """MPK105: a ``pass``-only broad exception handler.

    ``except Exception: pass`` eats the typed error taxonomy (§7) — a
    ``FrameError`` security event or a ``ServiceCrashed`` disappears
    instead of reaching the caller.  Genuinely best-effort teardown paths
    carry an inline suppression naming the invariant that makes them
    safe."""

    id = "MPK105"
    severity = "warning"
    hint = ("narrow the except, re-raise, or suppress with the reason the "
            "swallow is safe")

    def check_module(self, ctx: ModuleContext) -> List[Finding]:
        out: List[Finding] = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            broad = node.type is None or (
                isinstance(node.type, ast.Name)
                and node.type.id in ("Exception", "BaseException"))
            if not broad:
                continue
            body_inert = all(
                isinstance(s, ast.Pass)
                or (isinstance(s, ast.Expr)
                    and isinstance(s.value, ast.Constant))
                for s in node.body)
            if not body_inert:
                continue
            what = "bare except" if node.type is None \
                else f"except {node.type.id}"
            out.append(self.finding(
                ctx, node.lineno,
                f"{what}: pass swallows every typed error on this path"))
        return out


_SHED_TYPES = {"TransportError", "ServiceUnavailable", "Overloaded",
               "RateLimited"}
_ADMISSION_FN = re.compile(r"(admit|dispatch|submit|call|invoke|acquire|"
                           r"route)", re.IGNORECASE)


class SwallowedShedRule(Rule):
    """MPK107: an admission-path handler eats a typed shed signal.

    docs/protocol.md §7/§10: ``RateLimited`` and ``Overloaded`` carry a
    ``retry_after`` hint the caller's backoff depends on, and counting a
    shed requires observing it.  An admission-path function (admit/
    dispatch/submit/call/invoke/acquire/route) that catches one of the
    shed types and neither re-raises nor touches the bound exception
    silently converts back-pressure into success — the client retries at
    full rate and the noisy-neighbor gate loses its signal.  Handlers
    that log, map, or wrap the error (any reference to the bound name)
    or re-raise are clean."""

    id = "MPK107"
    severity = "warning"
    hint = ("re-raise the shed (or map it via its bound name) so "
            "RateLimited/Overloaded back-pressure reaches the caller")

    def check_module(self, ctx: ModuleContext) -> List[Finding]:
        out: List[Finding] = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            caught = self._shed_names(node.type)
            if not caught:
                continue
            fn = _enclosing_function(node)
            if fn is None or not _ADMISSION_FN.search(fn.name):
                continue
            if any(isinstance(n, ast.Raise)
                   for s in node.body for n in ast.walk(s)):
                continue
            if node.name and any(
                    isinstance(n, ast.Name) and n.id == node.name
                    for s in node.body for n in ast.walk(s)):
                continue            # error is logged/mapped/wrapped
            out.append(self.finding(
                ctx, node.lineno,
                f"{fn.name}() catches {'/'.join(sorted(caught))} without "
                f"re-raising or mapping it — the shed signal dies here"))
        return out

    def _shed_names(self, type_node: Optional[ast.AST]) -> Set[str]:
        """Shed-taxonomy class names named by the except clause."""
        names: Set[str] = set()
        if type_node is None:
            return names
        nodes = type_node.elts if isinstance(type_node, ast.Tuple) \
            else [type_node]
        for n in nodes:
            if isinstance(n, ast.Name) and n.id in _SHED_TYPES:
                names.add(n.id)
            elif isinstance(n, ast.Attribute) and n.attr in _SHED_TYPES:
                names.add(n.attr)
        return names
