"""Concurrency rules: the FrameStats/CA bug class, locked blocking calls,
and the module-wide lock-acquisition-order graph.

These generalize the hand-fixed races of PRs 2-5: an unguarded
``self.x += 1`` touched by both a service thread and a client thread
drops counts under interleaving; a blocking wait made while holding an
unrelated lock serializes the data plane (or deadlocks it); two code
paths taking the same pair of locks in opposite orders deadlock under
exactly the load the benchmarks apply.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.engine import (Finding, ModuleContext, ProjectRule, Rule,
                                   enclosing_lock_withs, expr_text,
                                   is_lock_expr)


_OP_TEXT = {ast.Add: "+", ast.Sub: "-", ast.Mult: "*", ast.Div: "/",
            ast.FloorDiv: "//", ast.Mod: "%", ast.BitOr: "|",
            ast.BitAnd: "&", ast.BitXor: "^", ast.LShift: "<<",
            ast.RShift: ">>"}


def _self_attr(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Attribute) and \
            isinstance(node.value, ast.Name) and node.value.id == "self":
        return node.attr
    return None


def _class_methods(cls: ast.ClassDef) -> Dict[str, ast.FunctionDef]:
    return {n.name: n for n in cls.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}


def _thread_targets(cls: ast.ClassDef) -> Set[str]:
    """Methods handed to ``threading.Thread(target=self.X)`` (or Timer)
    anywhere in the class — the service-thread entry points."""
    targets: Set[str] = set()
    for node in ast.walk(cls):
        if not isinstance(node, ast.Call):
            continue
        fname = node.func.attr if isinstance(node.func, ast.Attribute) \
            else node.func.id if isinstance(node.func, ast.Name) else ""
        if fname not in ("Thread", "Timer"):
            continue
        for kw in node.keywords:
            if kw.arg == "target":
                attr = _self_attr(kw.value)
                if attr:
                    targets.add(attr)
    return targets


def _reachable(entries: Set[str], edges: Dict[str, Set[str]]) -> Set[str]:
    seen: Set[str] = set()
    stack = [e for e in entries if e in edges]
    while stack:
        m = stack.pop()
        if m in seen:
            continue
        seen.add(m)
        stack.extend(edges[m] - seen)
    return seen


def _is_thread_sharded(cls: ast.ClassDef) -> bool:
    """Classes that index state by thread identity (``threading.local`` /
    ``get_ident`` / ``current_thread``) are cross-thread by construction —
    every plain ``self.x`` on them is shared even with no ``Thread()`` in
    sight (the FrameStats shape)."""
    for node in ast.walk(cls):
        if isinstance(node, ast.Attribute) and \
                node.attr in ("local", "get_ident", "current_thread"):
            return True
        if isinstance(node, ast.Name) and \
                node.id in ("get_ident", "current_thread"):
            return True
    return False


class CrossThreadCounterRule(ProjectRule):
    """MPK001: read-modify-write (``self.x += ...``) on an attribute that
    two thread entry points reach, with no enclosing lock.

    Thread entry points are methods handed to ``threading.Thread(target=
    self.X)`` — resolved through base classes, since ``Session`` starts
    the thread that runs each subclass's ``_serve_loop`` — plus the
    class's public API (callable from client threads).  Plain flag
    assignments are deliberately NOT flagged: the doorbell protocol
    publishes booleans lock-free by design; only augmented assignments
    lose updates."""

    id = "MPK001"
    severity = "error"
    hint = ("guard the += with the owning lock, or shard the counter "
            "per thread like framing.FrameStats")

    def check_project(self, modules: List[ModuleContext],
                      root) -> List[Finding]:
        # class table across every analyzed module (name collisions: last
        # definition wins — good enough for one project's core modules)
        table: Dict[str, Tuple[ModuleContext, ast.ClassDef]] = {}
        for ctx in modules:
            for cls in ast.walk(ctx.tree):
                if isinstance(cls, ast.ClassDef):
                    table[cls.name] = (ctx, cls)

        out: List[Finding] = []
        seen_sites: Set[Tuple[str, int]] = set()
        for name in table:
            out.extend(self._check_class(name, table, seen_sites))
        return out

    def _mro(self, name: str, table) -> List[str]:
        """Derived-first chain of known classes (single inheritance walk —
        multiple bases are all visited, derived definitions win)."""
        chain, queue, seen = [], [name], set()
        while queue:
            n = queue.pop(0)
            if n in seen or n not in table:
                continue
            seen.add(n)
            chain.append(n)
            _, cls = table[n]
            for base in cls.bases:
                if isinstance(base, ast.Name):
                    queue.append(base.id)
        return chain

    def _check_class(self, name: str, table,
                     seen_sites: Set[Tuple[str, int]]) -> List[Finding]:
        chain = self._mro(name, table)
        # effective method set: most-derived definition of each name
        methods: Dict[str, Tuple[ModuleContext, str, ast.FunctionDef]] = {}
        targets: Set[str] = set()
        sharded = False
        for cname in chain:
            ctx, cls = table[cname]
            for mname, fn in _class_methods(cls).items():
                methods.setdefault(mname, (ctx, cname, fn))
            targets |= _thread_targets(cls)
            sharded = sharded or _is_thread_sharded(cls)
        if not methods or (not targets and not sharded):
            return []

        edges: Dict[str, Set[str]] = {m: set() for m in methods}
        for mname, (_, _, fn) in methods.items():
            for node in ast.walk(fn):
                if isinstance(node, ast.Call):
                    callee = _self_attr(node.func)
                    if callee in methods:
                        edges[mname].add(callee)

        service_set = _reachable(targets, edges)
        public = {m for m in methods if not m.startswith("_")}
        client_set = _reachable(public, edges)

        # every write site per attribute: (method, ctx, node, guarded, aug)
        writes: Dict[str, List[Tuple[str, ModuleContext, ast.AST,
                                     bool, bool]]] = {}
        for mname, (ctx, cname, fn) in methods.items():
            if mname == "__init__":       # single-threaded construction
                continue
            for node in ast.walk(fn):
                if isinstance(node, ast.AugAssign):
                    attr, aug = _self_attr(node.target), True
                elif isinstance(node, ast.Assign) and len(node.targets) == 1:
                    attr, aug = _self_attr(node.targets[0]), False
                else:
                    continue
                if attr is None:
                    continue
                guarded = bool(enclosing_lock_withs(node))
                writes.setdefault(attr, []).append(
                    (mname, ctx, node, guarded, aug))

        out: List[Finding] = []
        for attr, sites in writes.items():
            for mname, ctx, node, guarded, aug in sites:
                if not aug or guarded:
                    continue
                site_key = (ctx.rel, node.lineno)
                if site_key in seen_sites:
                    continue
                cross = sharded or (
                    mname in service_set and mname in client_set)
                if not cross:
                    for oname, _, _, _, _ in sites:
                        if oname == mname:
                            continue
                        if (mname in service_set and oname in client_set) \
                                or (mname in client_set
                                    and oname in service_set):
                            cross = True
                            break
                if cross:
                    seen_sites.add(site_key)
                    why = ("class shards state per thread"
                           if sharded and mname not in service_set
                           else "reached from both a Thread target and "
                                "the public API")
                    out.append(self.finding(
                        ctx, node.lineno,
                        f"unguarded 'self.{attr} "
                        f"{_OP_TEXT.get(type(node.op), '?')}= ...' "
                        f"in {name}.{mname} "
                        f"is a cross-thread read-modify-write ({why}); "
                        f"concurrent writers drop updates"))
        return out


_BLOCKING_ATTRS = ("sleep", "recv", "wait", "wait_for", "request",
                   "request_into", "poll")


class BlockingUnderLockRule(Rule):
    """MPK002: a blocking call (``sleep``/``recv``/``Event.wait``/ring
    ``poll``/``request``) made while holding a lock.

    Waiting on the *held* condition itself (``with cv: cv.wait()``) is the
    sanctioned park idiom and is not flagged — the wait releases that
    lock.  Anything else holds the lock for the full wait: every other
    thread needing it stalls for up to the timeout, and if the wakeup
    depends on that lock the wait never returns."""

    id = "MPK002"
    severity = "error"
    hint = ("move the blocking call outside the 'with', or park on the "
            "held condition itself")

    def check_module(self, ctx: ModuleContext) -> List[Finding]:
        out: List[Finding] = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            name, recv = self._call_name(node)
            if name not in _BLOCKING_ATTRS:
                continue
            if name == "wait" and recv is None:
                continue            # bare wait() — not a method call
            held = enclosing_lock_withs(node)
            if not held:
                continue
            if name in ("wait", "wait_for") and recv is not None:
                held_texts = {expr_text(i.context_expr) for i in held}
                if expr_text(recv) in held_texts:
                    continue        # condition-wait idiom: releases the lock
            locks = ", ".join(sorted(expr_text(i.context_expr)
                                     for i in held))
            out.append(self.finding(
                ctx, node.lineno,
                f"blocking call '{expr_text(node.func)}(...)' while "
                f"holding lock(s) {locks}"))
        return out

    @staticmethod
    def _call_name(node: ast.Call):
        if isinstance(node.func, ast.Attribute):
            return node.func.attr, node.func.value
        if isinstance(node.func, ast.Name):
            return node.func.id, None
        return "", None


class LockOrderCycleRule(ProjectRule):
    """MPK003: cycle in the project-wide lock-acquisition-order graph.

    Every nested ``with lockA: ... with lockB:`` adds the edge A -> B
    (lock names are canonicalized as ``ClassName.attr`` for ``self.X``).
    One level of intra-class call expansion is applied: a self-method
    called while holding a lock contributes the locks it takes at its own
    top level.  A cycle means two threads can each hold one lock of a
    pair while waiting for the other — the classic data-plane deadlock."""

    id = "MPK003"
    severity = "error"
    hint = "pick one global acquisition order for the cycle's locks"

    def check_project(self, modules: List[ModuleContext],
                      root) -> List[Finding]:
        # edges: (src, dst) -> (ctx, lineno) of one witness acquisition
        edges: Dict[Tuple[str, str], Tuple[ModuleContext, int]] = {}
        # locks acquired at a method's own top level, for call expansion
        method_locks: Dict[str, List[str]] = {}
        calls_under_lock: List[Tuple[str, str, ModuleContext, int]] = []

        for ctx in modules:
            for cls in ast.walk(ctx.tree):
                if not isinstance(cls, ast.ClassDef):
                    continue
                for fn in _class_methods(cls).values():
                    self._walk_fn(ctx, cls.name, fn, edges, method_locks,
                                  calls_under_lock)

        for held, callee, ctx, lineno in calls_under_lock:
            for inner in method_locks.get(callee, []):
                if inner != held:
                    edges.setdefault((held, inner), (ctx, lineno))

        return self._find_cycles(edges)

    def _walk_fn(self, ctx, cls_name, fn, edges, method_locks,
                 calls_under_lock):
        qual = f"{cls_name}.{fn.name}"
        acquired: List[str] = []

        def canon(expr) -> str:
            text = expr_text(expr)
            if text.startswith("self."):
                return f"{cls_name}.{text[5:]}"
            return text

        def visit(node, held: Tuple[str, ...]):
            if isinstance(node, (ast.With, ast.AsyncWith)):
                new_held = list(held)
                for item in node.items:
                    if is_lock_expr(item.context_expr):
                        name = canon(item.context_expr)
                        if not held:
                            acquired.append(name)
                        for h in new_held:
                            if h != name:
                                edges.setdefault((h, name),
                                                 (ctx, node.lineno))
                        new_held.append(name)
                for child in node.body:
                    visit(child, tuple(new_held))
                return
            if isinstance(node, ast.Call) and held:
                callee = _self_attr(node.func)
                if callee:
                    for h in held:
                        calls_under_lock.append(
                            (h, f"{cls_name}.{callee}", ctx, node.lineno))
            for child in ast.iter_child_nodes(node):
                visit(child, held)

        for stmt in fn.body:
            visit(stmt, ())
        method_locks[qual] = acquired

    def _find_cycles(self, edges) -> List[Finding]:
        graph: Dict[str, Set[str]] = {}
        for (a, b) in edges:
            graph.setdefault(a, set()).add(b)
            graph.setdefault(b, set())
        out: List[Finding] = []
        reported: Set[frozenset] = set()
        color: Dict[str, int] = {n: 0 for n in graph}
        stack: List[str] = []

        def witness(cycle: List[str]):
            for a, b in zip(cycle, cycle[1:]):
                if (a, b) in edges:
                    return edges[(a, b)]
            return next(iter(edges.values()))

        def dfs(n: str):
            color[n] = 1
            stack.append(n)
            for m in sorted(graph[n]):
                if color[m] == 1:
                    cycle = stack[stack.index(m):] + [m]
                    key = frozenset(cycle)
                    if key not in reported:
                        reported.add(key)
                        ctx, lineno = witness(cycle)
                        out.append(self.finding(
                            ctx, lineno,
                            "lock acquisition-order cycle: "
                            + " -> ".join(cycle)))
                elif color[m] == 0:
                    dfs(m)
            stack.pop()
            color[n] = 2

        for n in sorted(graph):
            if color[n] == 0:
                dfs(n)
        return out
