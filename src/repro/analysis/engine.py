"""Rule engine: findings, suppressions, baseline, file walking, reporting.

A *rule* is a small class with an ``id`` (``MPK001``...), a ``severity``
(``error`` | ``warning``) and a ``hint`` (how to fix).  File rules
implement ``check_module(ctx)`` and run once per analyzed module; project
rules implement ``check_project(modules, root)`` and run once per
analysis root (they see every module at once — the lock-order graph and
the docs/protocol.md cross-checks live there).

Findings can be silenced two ways:

* inline — ``# mpklint: disable=MPK001 reason=single-writer by design``
  on the offending line or on the line directly above it.  The reason is
  mandatory; a bare ``disable=`` is itself reported (``MPK000``).
* baseline — a committed JSON file of grandfathered findings keyed by
  (rule, path, stripped source line), so line-number drift does not
  resurrect them.  The analyzer exits nonzero on any NEW finding.
"""
from __future__ import annotations

import ast
import json
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

SUPPRESS_RE = re.compile(
    r"#\s*mpklint:\s*disable=(?P<ids>[A-Z0-9,\s]+?)"
    r"(?:\s+reason=(?P<reason>.+?))?\s*$")

SEVERITIES = ("error", "warning")


@dataclass
class Finding:
    rule: str
    severity: str
    path: str
    line: int
    message: str
    hint: str = ""
    context: str = ""          # stripped source line — the baseline key part
    suppressed: bool = False
    baselined: bool = False

    def key(self) -> Tuple[str, str, str]:
        return (self.rule, self.path, self.context)

    def to_dict(self) -> dict:
        return {"rule": self.rule, "severity": self.severity,
                "path": self.path, "line": self.line,
                "message": self.message, "hint": self.hint,
                "context": self.context, "suppressed": self.suppressed,
                "baselined": self.baselined}

    def render(self) -> str:
        tag = ""
        if self.suppressed:
            tag = " [suppressed]"
        elif self.baselined:
            tag = " [baselined]"
        hint = f"  (fix: {self.hint})" if self.hint else ""
        return (f"{self.path}:{self.line}: {self.rule} {self.severity}: "
                f"{self.message}{hint}{tag}")


@dataclass
class _Suppression:
    ids: Tuple[str, ...]
    reason: str
    line: int


class ModuleContext:
    """One parsed module: source, lines, AST (with parent links), path."""

    def __init__(self, path: Path, source: str, rel: str):
        self.path = path
        self.rel = rel                       # posix path used in findings
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=str(path))
        add_parents(self.tree)
        self.suppressions = _scan_suppressions(self.lines)

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""

    def suppressed(self, rule_id: str, lineno: int) -> Optional[_Suppression]:
        """A finding at ``lineno`` is silenced by a reasoned disable on the
        same line or on the line directly above."""
        for ln in (lineno, lineno - 1):
            sup = self.suppressions.get(ln)
            if sup is not None and rule_id in sup.ids and sup.reason:
                return sup
        return None


def add_parents(tree: ast.AST) -> None:
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            child.parent = node  # type: ignore[attr-defined]


def ancestors(node: ast.AST) -> Iterable[ast.AST]:
    cur = getattr(node, "parent", None)
    while cur is not None:
        yield cur
        cur = getattr(cur, "parent", None)


def expr_text(node: ast.AST) -> str:
    try:
        return ast.unparse(node)
    except Exception:  # pragma: no cover - unparse covers all real exprs
        return ""


_LOCK_TOKEN = re.compile(r"(lock|cond|mutex|slk|sem)", re.IGNORECASE)


def is_lock_expr(node: ast.AST) -> bool:
    """Heuristic: a ``with`` context whose dotted text names a lock-like
    object (``self._lock``, ``ring.cv``, ``self._glock``, ``done_lock``,
    ``self._cond``...).  ``cv`` must match as a whole token so ``recv``
    does not."""
    text = expr_text(node)
    tokens = re.split(r"[^A-Za-z0-9_]+", text)
    for tok in tokens:
        if not tok:
            continue
        if tok in ("cv", "cond", "slk", "slock", "glock"):
            return True
        if _LOCK_TOKEN.search(tok):
            return True
    return False


def enclosing_lock_withs(node: ast.AST) -> List[ast.withitem]:
    """Every lock-like ``with`` item an ancestor of ``node`` holds."""
    held = []
    for anc in ancestors(node):
        if isinstance(anc, (ast.With, ast.AsyncWith)):
            for item in anc.items:
                if is_lock_expr(item.context_expr):
                    held.append(item)
    return held


def _scan_suppressions(lines: Sequence[str]) -> Dict[int, _Suppression]:
    out: Dict[int, _Suppression] = {}
    for i, line in enumerate(lines, start=1):
        m = SUPPRESS_RE.search(line)
        if not m:
            continue
        ids = tuple(x.strip() for x in m.group("ids").split(",") if x.strip())
        reason = (m.group("reason") or "").strip()
        out[i] = _Suppression(ids=ids, reason=reason, line=i)
    return out


class Rule:
    """Base for per-module rules."""

    id = "MPK000"
    severity = "error"
    hint = ""

    def check_module(self, ctx: ModuleContext) -> List[Finding]:
        raise NotImplementedError

    def finding(self, ctx: ModuleContext, lineno: int, message: str,
                hint: Optional[str] = None) -> Finding:
        return Finding(rule=self.id, severity=self.severity, path=ctx.rel,
                       line=lineno, message=message,
                       hint=self.hint if hint is None else hint,
                       context=ctx.line_text(lineno))


class ProjectRule(Rule):
    """Base for whole-project rules (cross-module / docs cross-checks)."""

    def check_module(self, ctx: ModuleContext) -> List[Finding]:
        return []

    def check_project(self, modules: List[ModuleContext],
                      root: Optional[Path]) -> List[Finding]:
        raise NotImplementedError


class BadSuppressionRule(Rule):
    """MPK000: a ``# mpklint: disable=`` comment without a reason.

    A suppression is a claim that the invariant holds for a reason the
    analyzer cannot see — an unreasoned one is indistinguishable from
    silencing a real bug, so the reason is mandatory and reasonless
    disables never suppress anything."""

    id = "MPK000"
    severity = "error"
    hint = "append reason=<why this is safe> to the disable comment"

    def check_module(self, ctx: ModuleContext) -> List[Finding]:
        out = []
        for sup in ctx.suppressions.values():
            if not sup.reason:
                out.append(self.finding(
                    ctx, sup.line,
                    "mpklint suppression without a reason= clause "
                    f"(ids: {', '.join(sup.ids)})"))
        return out


class Baseline:
    """Committed grandfathered findings: (rule, path, context) triples."""

    def __init__(self, entries: Iterable[Tuple[str, str, str]] = ()):
        self.entries = set(entries)

    @classmethod
    def load(cls, path: Path) -> "Baseline":
        data = json.loads(path.read_text())
        return cls((e["rule"], e["path"], e.get("context", ""))
                   for e in data.get("findings", []))

    def contains(self, finding: Finding) -> bool:
        return finding.key() in self.entries

    @staticmethod
    def dump(findings: Iterable[Finding]) -> str:
        uniq = sorted({f.key() for f in findings})
        return json.dumps(
            {"version": 1,
             "findings": [{"rule": r, "path": p, "context": c}
                          for r, p, c in uniq]},
            indent=2) + "\n"


def all_rules() -> List[Rule]:
    from repro.analysis.rules_concurrency import (BlockingUnderLockRule,
                                                  CrossThreadCounterRule,
                                                  LockOrderCycleRule)
    from repro.analysis.rules_protocol import (FreshConstantWaitRule,
                                               SwallowedErrorRule,
                                               SwallowedShedRule,
                                               TimeTimeDeadlineRule,
                                               TimeoutNotForwardedRule,
                                               UnverifiedPayloadRule,
                                               ViewEscapeRule)
    from repro.analysis.rules_spec import (SpecConstantSyncRule,
                                           SpecTaxonomySyncRule)
    return [
        BadSuppressionRule(),
        CrossThreadCounterRule(),
        BlockingUnderLockRule(),
        LockOrderCycleRule(),
        UnverifiedPayloadRule(),
        ViewEscapeRule(),
        TimeTimeDeadlineRule(),
        TimeoutNotForwardedRule(),
        FreshConstantWaitRule(),
        SwallowedErrorRule(),
        SwallowedShedRule(),
        SpecConstantSyncRule(),
        SpecTaxonomySyncRule(),
    ]


def iter_py_files(paths: Sequence[Path]) -> List[Path]:
    files: List[Path] = []
    for p in paths:
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        elif p.suffix == ".py":
            files.append(p)
    seen = set()
    uniq = []
    for f in files:
        r = f.resolve()
        if r not in seen:
            seen.add(r)
            uniq.append(f)
    return uniq


def find_project_root(paths: Sequence[Path]) -> Optional[Path]:
    """Nearest ancestor of the analyzed paths holding docs/protocol.md —
    the normative spec the spec-sync rules check against."""
    for p in paths:
        cur = p.resolve()
        if cur.is_file():
            cur = cur.parent
        while True:
            if (cur / "docs" / "protocol.md").is_file():
                return cur
            if cur.parent == cur:
                break
            cur = cur.parent
    return None


def _rel(path: Path, root: Optional[Path]) -> str:
    r = path.resolve()
    for base in (root, Path.cwd()):
        if base is not None:
            try:
                return r.relative_to(base.resolve()).as_posix()
            except ValueError:
                continue
    return r.as_posix()


@dataclass
class Report:
    findings: List[Finding] = field(default_factory=list)
    parse_errors: List[str] = field(default_factory=list)

    @property
    def new(self) -> List[Finding]:
        return [f for f in self.findings
                if not f.suppressed and not f.baselined]

    def counts(self) -> dict:
        return {"total": len(self.findings),
                "new": len(self.new),
                "suppressed": sum(f.suppressed for f in self.findings),
                "baselined": sum(f.baselined for f in self.findings)}

    def to_dict(self) -> dict:
        return {"findings": [f.to_dict() for f in self.findings],
                "parse_errors": self.parse_errors,
                "counts": self.counts()}


def analyze_paths(paths: Sequence[Path],
                  baseline: Optional[Baseline] = None,
                  rules: Optional[Sequence[Rule]] = None,
                  root: Optional[Path] = None) -> Report:
    rules = list(rules) if rules is not None else all_rules()
    root = root or find_project_root(paths)
    report = Report()

    modules: List[ModuleContext] = []
    for f in iter_py_files(paths):
        try:
            source = f.read_text()
            modules.append(ModuleContext(f, source, _rel(f, root)))
        except (SyntaxError, UnicodeDecodeError, OSError) as e:
            report.parse_errors.append(f"{f}: {type(e).__name__}: {e}")

    raw: List[Finding] = []
    for rule in rules:
        if isinstance(rule, ProjectRule):
            raw.extend(rule.check_project(modules, root))
        else:
            for ctx in modules:
                raw.extend(rule.check_module(ctx))

    by_rel = {m.rel: m for m in modules}
    for f in sorted(raw, key=lambda x: (x.path, x.line, x.rule)):
        ctx = by_rel.get(f.path)
        if ctx is not None and f.rule != "MPK000" \
                and ctx.suppressed(f.rule, f.line):
            f.suppressed = True
        elif baseline is not None and baseline.contains(f):
            f.baselined = True
        report.findings.append(f)
    return report


def run(argv: Optional[Sequence[str]] = None) -> int:
    """CLI entry point.  Exit 0 = clean, 1 = new findings, 2 = bad usage
    or unparseable input."""
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="mpklint: concurrency & protocol-invariant analyzer "
                    "for the MPKLink data plane (docs/analysis.md)")
    ap.add_argument("paths", nargs="*", default=["src/repro"],
                    help="files or directories to analyze "
                         "(default: src/repro)")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable report on stdout")
    ap.add_argument("--baseline", default=None,
                    help="JSON baseline of grandfathered findings "
                         "(e.g. analysis/baseline.json)")
    ap.add_argument("--write-baseline", default=None, metavar="PATH",
                    help="write current findings as a new baseline and exit")
    args = ap.parse_args(argv)

    paths = [Path(p) for p in args.paths]
    missing = [str(p) for p in paths if not p.exists()]
    if missing:
        print(f"mpklint: no such path(s): {', '.join(missing)}")
        return 2

    baseline = None
    if args.baseline:
        bp = Path(args.baseline)
        if not bp.is_file():
            print(f"mpklint: baseline not found: {bp}")
            return 2
        baseline = Baseline.load(bp)

    report = analyze_paths(paths, baseline=baseline)

    if args.write_baseline:
        keep = [f for f in report.findings if not f.suppressed]
        Path(args.write_baseline).write_text(Baseline.dump(keep))
        print(f"mpklint: baseline written to {args.write_baseline} "
              f"({len(keep)} findings)")
        return 0

    if args.json:
        print(json.dumps(report.to_dict(), indent=2))
    else:
        for f in report.findings:
            print(f.render())
        for e in report.parse_errors:
            print(f"parse error: {e}")
        c = report.counts()
        print(f"mpklint: {c['new']} new finding(s), "
              f"{c['suppressed']} suppressed, {c['baselined']} baselined "
              f"in {len(paths)} path(s)")
    if report.parse_errors:
        return 2
    return 1 if report.new else 0
