"""Spec/constant-sync rules: docs/protocol.md is normative — keep it
honest against the code's wire constants and typed-error taxonomy.

These generalize the ad-hoc checks that lived in tests/test_docs.py:
instead of a hand-maintained list of asserts, the rules harvest the
constants and error classes from the analyzed modules' ASTs and check
the spec quotes each one.  Adding a wire magic or a typed error without
documenting it — or drifting a value in the spec — fails the analyzer
with the same rule ids CI reports.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from repro.analysis.engine import Finding, ModuleContext, ProjectRule

# module-level integer constants the spec must quote, and how the spec is
# expected to render them (any one acceptable form suffices)
_CONSTANT_FORMS = {
    "MAGIC": lambda v: [f"0x{v:08X}"],
    "GW_MAGIC": lambda v: [f"0x{v:08X}"],
    "GW_BATCH_MAGIC": lambda v: [f"0x{v:08X}"],
    "GW_SCAT_MAGIC": lambda v: [f"0x{v:08X}"],
    "LANES": lambda v: [f"LANES = {v}"],
    "MAC_PRIME": lambda v: [f"0x{v:08X}", f"0x{v:08x}"],
    "MAC_INIT": lambda v: [f"0x{v:08X}", f"0x{v:08x}"],
    "PROC_MAGIC": lambda v: [f"0x{v:08X}"],
    "PROC_CTRL_WORDS": lambda v: [f"PROC_CTRL_WORDS = {v}"],
    "PROC_SLOT_WORDS": lambda v: [f"PROC_SLOT_WORDS = {v}"],
    # replica-fleet control plane (§8): membership states + router fan-out
    "REPLICA_ACTIVE": lambda v: [f"REPLICA_ACTIVE = {v}"],
    "REPLICA_DRAINING": lambda v: [f"REPLICA_DRAINING = {v}"],
    "REPLICA_QUIESCED": lambda v: [f"REPLICA_QUIESCED = {v}"],
    "REPLICA_DEAD": lambda v: [f"REPLICA_DEAD = {v}"],
    "FLEET_CHOICES": lambda v: [f"FLEET_CHOICES = {v}"],
    # self-healing / overload control plane (§9)
    "DEADLINE_LANE": lambda v: [f"DEADLINE_LANE = {v}"],
    "DEADLINE_US_MAX": lambda v: [f"0x{v:08X}"],
    "HEDGE_RESERVOIR": lambda v: [f"HEDGE_RESERVOIR = {v}"],
    "REKEY_LIMIT": lambda v: [f"REKEY_LIMIT = {v}"],
    # multi-tenant QoS control plane (§10): priority lane + fair queuing
    "PRIORITY_LANE": lambda v: [f"PRIORITY_LANE = {v}"],
    "PRIO_NORMAL": lambda v: [f"PRIO_NORMAL = {v}"],
    "PRIO_HIGH": lambda v: [f"PRIO_HIGH = {v}"],
    "PRIO_BULK": lambda v: [f"PRIO_BULK = {v}"],
    "WFQ_QUANTUM": lambda v: [f"WFQ_QUANTUM = {v}"],
}

_ERROR_ROOT = "TransportError"
# chaos-fabric signals are BaseExceptions invisible to clients (§7) — the
# taxonomy documents what a *client* can observe
_TAXONOMY_EXEMPT = {"TransportError", "HandlerCrash", "DropResponse"}


def _spec(root: Optional[Path]) -> Optional[Tuple[Path, str]]:
    if root is None:
        return None
    p = root / "docs" / "protocol.md"
    if not p.is_file():
        return None
    return p, p.read_text()


def _module_constants(ctx: ModuleContext) -> Dict[str, Tuple[int, int]]:
    """Top-level ``NAME = <int literal>`` assignments → {name: (value,
    lineno)}."""
    out: Dict[str, Tuple[int, int]] = {}
    for node in ctx.tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and isinstance(node.value, ast.Constant) \
                and isinstance(node.value.value, int):
            out[node.targets[0].id] = (node.value.value, node.lineno)
    return out


class SpecConstantSyncRule(ProjectRule):
    """MPK201: a wire/MAC constant the code defines is absent from (or
    drifted in) docs/protocol.md.

    The spec is what a second implementation would be written against; a
    magic number it misquotes is a protocol fork waiting to ship."""

    id = "MPK201"
    severity = "error"
    hint = "update docs/protocol.md (or the constant) so they agree"

    def check_project(self, modules: List[ModuleContext],
                      root) -> List[Finding]:
        spec = _spec(root)
        if spec is None:
            return []
        _, text = spec
        out: List[Finding] = []
        seen: set = set()
        for ctx in modules:
            for name, (value, lineno) in _module_constants(ctx).items():
                forms = _CONSTANT_FORMS.get(name)
                if forms is None or name in seen:
                    continue
                seen.add(name)
                accepted = forms(value)
                if not any(a in text for a in accepted):
                    out.append(self.finding(
                        ctx, lineno,
                        f"constant {name} = {accepted[0]} is not quoted by "
                        f"docs/protocol.md — the normative spec drifted"))
        return out


class SpecTaxonomySyncRule(ProjectRule):
    """MPK202: a typed error class (``TransportError`` subclass) missing
    from the docs/protocol.md taxonomy table.

    §7 promises that everything a client can observe is one of the
    documented typed errors; an undocumented subclass breaks every
    caller's exhaustive handling."""

    id = "MPK202"
    severity = "error"
    hint = "add the error to the docs/protocol.md §7 taxonomy table"

    def check_project(self, modules: List[ModuleContext],
                      root) -> List[Finding]:
        spec = _spec(root)
        if spec is None:
            return []
        _, text = spec
        # transitive TransportError subclasses across the analyzed modules
        typed = {_ERROR_ROOT}
        classes: List[Tuple[ModuleContext, ast.ClassDef]] = []
        for ctx in modules:
            for node in ast.walk(ctx.tree):
                if isinstance(node, ast.ClassDef):
                    classes.append((ctx, node))
        grew = True
        while grew:
            grew = False
            for _, cls in classes:
                if cls.name in typed:
                    continue
                for base in cls.bases:
                    if isinstance(base, ast.Name) and base.id in typed:
                        typed.add(cls.name)
                        grew = True
        out: List[Finding] = []
        for ctx, cls in classes:
            if cls.name not in typed or cls.name in _TAXONOMY_EXEMPT:
                continue
            if f"`{cls.name}`" not in text:
                out.append(self.finding(
                    ctx, cls.lineno,
                    f"typed error {cls.name} is missing from the "
                    f"docs/protocol.md taxonomy"))
        return out
