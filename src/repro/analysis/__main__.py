"""``python -m repro.analysis`` — the mpklint CLI (see docs/analysis.md)."""
import sys

from repro.analysis.engine import run

if __name__ == "__main__":
    sys.exit(run())
