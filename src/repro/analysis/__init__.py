"""mpklint: AST-driven concurrency & protocol-invariant analyzer.

The MPKLink data plane's "secure AND efficient" claim rests on discipline
the type system cannot see: MAC-verify before any payload read, zero-copy
views that must not outlive their slot, monotonic clocks on every
deadline, and locks guarding every cross-thread counter.  PRs 2-5 each
fixed a latent violation of those rules by hand; this package turns them
into machine-checked rules (see docs/analysis.md for the catalog).

Usage:

    python -m repro.analysis [--json] [--baseline analysis/baseline.json] \
        [paths...]

Pure stdlib (``ast`` + the repo's own docs as ground truth) — no
third-party dependencies.
"""
from repro.analysis.engine import (  # noqa: F401
    Baseline,
    Finding,
    ModuleContext,
    all_rules,
    analyze_paths,
    run,
)

__all__ = ["Finding", "ModuleContext", "Baseline", "all_rules",
           "analyze_paths", "run"]
