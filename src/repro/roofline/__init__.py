from repro.roofline.analyze import (CollectiveOp, Roofline, analyze,
                                    model_flops, parse_collectives,
                                    PEAK_FLOPS, HBM_BW, ICI_BW)

__all__ = ["CollectiveOp", "Roofline", "analyze", "model_flops",
           "parse_collectives", "PEAK_FLOPS", "HBM_BW", "ICI_BW"]
