"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), all in seconds:

  compute    = HLO_FLOPs_per_device / peak_FLOP/s
  memory     = HLO_bytes_per_device / HBM_bw
  collective = Σ per-op bytes_moved_per_device / link_bw

``cost_analysis()`` provides FLOPs and bytes for the post-SPMD per-device
module. Collective bytes are NOT in cost_analysis, so we parse the compiled
HLO text: for every all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute we take the RESULT shapes from the def line and convert
to per-device bytes over the bottleneck link using ring-algorithm costs:

  all-reduce         2·(g-1)/g · bytes       (reduce-scatter + all-gather)
  all-gather           (g-1)/g · bytes       (bytes = gathered result)
  reduce-scatter       (g-1)   · bytes       (bytes = scattered result)
  all-to-all           (g-1)/g · bytes
  collective-permute           · bytes

g = size of the first replica group in the op's replica_groups.

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _result_bytes(line: str) -> int:
    """Sum result-tuple shapes on an HLO def line (before the op name)."""
    lhs = line.split(" = ", 1)[1] if " = " in line else line
    # result type is everything up to the op name token
    for op in _COLLECTIVES:
        k = lhs.find(f" {op}")
        if k < 0:
            k = lhs.find(f"{op}(")
        if k >= 0:
            lhs = lhs[:k + 1]
            break
    total = 0
    for m in _SHAPE_RE.finditer(lhs):
        total += _shape_bytes(m.group(1), m.group(2))
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # iota_replica_group_list=[ngroups, group_size] renders as [a,b]
        return int(m.group(2))
    return default


@dataclass
class CollectiveOp:
    kind: str
    result_bytes: int
    group_size: int
    moved_bytes: float          # per-device over the bottleneck link


def parse_collectives(hlo_text: str, n_devices: int) -> List[CollectiveOp]:
    ops: List[CollectiveOp] = []
    for raw in hlo_text.splitlines():
        line = raw.strip()
        if " = " not in line:
            continue
        kind = None
        head = line.split(" = ", 1)[1]
        for op in _COLLECTIVES:
            if re.search(rf"\b{op}(-start)?\(", head):
                kind = op
                break
        if kind is None:
            continue
        rb = _result_bytes(line)
        g = _group_size(line, n_devices)
        if g <= 1:
            moved = 0.0
        elif kind == "all-reduce":
            moved = 2.0 * (g - 1) / g * rb
        elif kind == "all-gather":
            moved = (g - 1) / g * rb
        elif kind == "reduce-scatter":
            moved = float(g - 1) * rb
        elif kind == "all-to-all":
            moved = (g - 1) / g * rb
        else:                       # collective-permute
            moved = float(rb)
        ops.append(CollectiveOp(kind, rb, g, moved))
    return ops


@dataclass
class Roofline:
    flops: float                 # per device
    hbm_bytes: float             # per device (fusion-aware estimate)
    collective_bytes: float      # per device, bottleneck-link model
    n_collectives: int
    by_kind: Dict[str, float]
    hbm_bytes_upper: float = 0.0  # every top-level op counted (upper bound)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def roofline_fraction(self) -> float:
        """dominant term / sum — how close the dominant term is to being the
        ONLY cost (1.0 = perfectly overlapped ideal)."""
        s = self.t_compute + self.t_memory + self.t_collective
        return self.t_bound / s if s else 0.0

    def to_dict(self) -> dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "hbm_bytes_upper": self.hbm_bytes_upper,
            "collective_bytes": self.collective_bytes,
            "n_collectives": self.n_collectives, "by_kind": self.by_kind,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective, "bottleneck": self.bottleneck,
        }


def analyze(cost: dict, hlo_text: str, n_devices: int) -> Roofline:
    """Primary path: trip-count-aware HLO walk (roofline/hlo_parse.py) —
    XLA's own cost_analysis counts while bodies once, which undercounts
    scanned-layer models ~L×n_micro-fold; the raw dict is kept by the
    caller for reference. Falls back to cost_analysis numbers if the parse
    fails."""
    from repro.roofline.hlo_parse import ModuleCost
    try:
        mc = ModuleCost(hlo_text, n_devices).total()
        return Roofline(mc.flops, mc.bytes_hot, mc.coll_bytes, mc.n_coll,
                        dict(mc.coll_by_kind), hbm_bytes_upper=mc.bytes)
    except Exception:
        flops = float(cost.get("flops", 0.0))
        hbm = float(cost.get("bytes accessed", 0.0))
        ops = parse_collectives(hlo_text, n_devices)
        by_kind: Dict[str, float] = {}
        for op in ops:
            by_kind[op.kind] = by_kind.get(op.kind, 0.0) + op.moved_bytes
        return Roofline(flops, hbm, sum(o.moved_bytes for o in ops), len(ops),
                        by_kind)


def model_flops(param_count_active: int, tokens: int, kind: str) -> float:
    """6·N·D for training; 2·N·D for a forward-only pass (prefill/decode)."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * param_count_active * tokens
