"""Trip-count-aware HLO cost model.

XLA's HloCostAnalysis (what ``compiled.cost_analysis()`` returns) counts a
``while`` body ONCE — with scan-over-layers + microbatch scans, that
undercounts FLOPs, bytes and collective traffic by the product of every
enclosing trip count (~46× for a 32-layer model with 8 microbatches).

This module parses ``compiled.as_text()`` (the post-optimization, post-SPMD
per-device module) into computations, extracts while-loop trip counts from
their condition computations (`compare(counter, constant), direction=LT`),
and evaluates costs recursively over the call graph:

  cost(while)   = trip × (cost(body) + cost(cond))
  cost(fusion)  = callsite operand/result bytes + cost(called computation)
  cost(dot)     = 2 · |result| · Π contracted dims        [FLOPs]
  cost(cheap elementwise fusions) ≈ |result| FLOPs         [minor]
  collectives   : ring-model bytes over the bottleneck link, scaled by the
                  enclosing trip counts (all-reduce 2(g-1)/g·b, all-gather
                  (g-1)/g·b, reduce-scatter (g-1)·b_result, all-to-all
                  (g-1)/g·b, collective-permute b)

Bytes accessed: per top-level op, Σ operand + result bytes (fusion-internal
ops are excluded — they live in registers/VMEM, matching XLA's convention).

Validated against analytic 6·N·D model FLOPs in tests/test_roofline.py.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*(.+?)\s*\{")
_OP_RE = re.compile(r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_OPNAME_RE = re.compile(r"\)\s*([a-z][a-z0-9\-]*)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALL_ATTR_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"iota_replica_group_list=\[(\d+),(\d+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_TRIP_RE = re.compile(r"constant\((\d+)\)")
_KNOWN_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')

_COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                     "all-to-all", "collective-permute")


def _shape_list_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        n = _DTYPE_BYTES.get(m.group(1))
        if n is None:
            continue
        k = 1
        for d in m.group(2).split(","):
            if d:
                k *= int(d)
        total += n * k
    return total


def _first_shape_dims(text: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d] or []


def _elements(text: str) -> int:
    dims = _first_shape_dims(text)
    if dims is None:
        return 0
    k = 1
    for d in dims:
        k *= d
    return k


@dataclass
class Op:
    name: str
    kind: str
    result_text: str            # result type text
    rest: str                   # full RHS (operands + attrs)
    operands: List[str]


@dataclass
class Computation:
    name: str
    params: Dict[str, str]      # param name -> type text
    ops: List[Op] = field(default_factory=list)
    defs: Dict[str, str] = field(default_factory=dict)  # op name -> result text


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0        # every top-level op's operands+results (upper bound)
    bytes_hot: float = 0.0    # fusion-aware estimate: naked cheap elementwise /
                              # broadcast / reshape ops assumed absorbed by TPU
                              # fusion; dots, fusions, reduces, scatters,
                              # collectives and control flow keep their traffic
    coll_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = field(default_factory=dict)
    n_coll: int = 0

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.bytes_hot += other.bytes_hot * mult
        self.coll_bytes += other.coll_bytes * mult
        self.n_coll += int(other.n_coll * mult)
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + v * mult


def parse_module(hlo_text: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR_RE.match(line.strip())
        if hdr and line.strip().endswith("{"):
            name = hdr.group(2)
            params = {}
            # depth-aware split: tuple-typed params contain commas
            depth = 0
            start = 0
            text = hdr.group(3)
            pieces = []
            for i, ch in enumerate(text):
                if ch in "([{":
                    depth += 1
                elif ch in ")]}":
                    depth -= 1
                elif ch == "," and depth == 0:
                    pieces.append(text[start:i])
                    start = i + 1
            pieces.append(text[start:])
            for p in pieces:
                p = p.strip()
                if ":" in p:
                    pname, ptype = p.split(":", 1)
                    params[pname.strip().lstrip("%")] = ptype.strip()
            cur = Computation(name, params)
            comps[name] = cur
            if hdr.group(1):
                entry = name
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(2), m.group(3)
        # result type = prefix of rhs up to the op name token
        opm = re.match(r"^((?:\([^)]*\)|[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?)+?)\s+([a-z][a-z0-9\-]*)\(", rhs)
        if opm:
            result_text, kind = opm.group(1), opm.group(2)
            rest = rhs[opm.end(2):]
        else:
            # e.g. constants / parameter
            parts = rhs.split(" ", 2)
            result_text = parts[0]
            kind = parts[1].split("(")[0] if len(parts) > 1 else "unknown"
            rest = rhs
        operands = []
        paren = rest.find("(")
        if paren >= 0:
            depth = 0
            end = paren
            for i in range(paren, len(rest)):
                if rest[i] == "(":
                    depth += 1
                elif rest[i] == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            operands = _OPERAND_RE.findall(rest[paren:end + 1])
        op = Op(name, kind, result_text, rhs, operands)
        cur.ops.append(op)
        cur.defs[name] = result_text
    assert entry is not None, "no ENTRY computation found"
    return comps, entry


def _trip_count(cond: Computation) -> int:
    """Scan-style conditions: ROOT compare(counter, constant(N)), LT."""
    consts = {}
    for op in cond.ops:
        if op.kind == "constant" or " constant(" in op.rest:
            m = _TRIP_RE.search(op.rest)
            if m:
                consts[op.name] = int(m.group(1))
    for op in reversed(cond.ops):
        if op.kind == "compare" or " compare(" in op.rest:
            for o in op.operands:
                if o in consts:
                    return consts[o]
    return 1


def _operand_bytes(comp: Computation, op: Op) -> int:
    total = 0
    for o in op.operands:
        t = comp.defs.get(o) or comp.params.get(o)
        if t:
            total += _shape_list_bytes(t)
    return total


def _group_size(rest: str, default: int) -> int:
    m = _GROUPS_RE.search(rest)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(rest)
    if m:
        return int(m.group(2))
    return default


def _dot_flops(comp: Computation, op: Op) -> float:
    out_elems = _elements(op.result_text)
    contract = 1
    m = _CONTRACT_RE.search(op.rest)
    if m and op.operands:
        lhs_t = comp.defs.get(op.operands[0]) or comp.params.get(op.operands[0])
        dims = _first_shape_dims(lhs_t or "")
        if dims is not None:
            for di in m.group(1).split(","):
                if di and int(di) < len(dims):
                    contract *= dims[int(di)]
    return 2.0 * out_elems * contract


_CHEAP_ELEMWISE = {"add", "subtract", "multiply", "divide", "maximum", "minimum",
                   "exponential", "tanh", "rsqrt", "sqrt", "negate", "abs",
                   "compare", "select", "convert", "log", "power", "floor"}


def _dus_update_bytes(comp_called: "Computation") -> Optional[int]:
    """If a computation's root is dynamic-update-slice, return the bytes of
    its update operand. XLA performs DUS in place — the loop-carried buffer
    (flash-bwd dq accumulator, KV-cache insert) is NOT re-read/re-written,
    only the updated slice is touched. Counting the full buffer overstated
    mixtral train memory 8× and decode memory ~600×."""
    if not comp_called.ops:
        return None
    root = comp_called.ops[-1]
    if root.kind != "dynamic-update-slice":
        return None
    if len(root.operands) >= 2:
        upd = root.operands[1]
        t = comp_called.defs.get(upd) or comp_called.params.get(upd)
        if t:
            return _shape_list_bytes(t)
    return None


class ModuleCost:
    def __init__(self, hlo_text: str, n_devices: int):
        self.comps, self.entry = parse_module(hlo_text)
        self.n_devices = n_devices
        self._memo: Dict[str, Cost] = {}
        # computations reached via calls=/to_apply= are fused/applied bodies:
        # their intermediate values never touch HBM
        self.internal = set()
        for comp in self.comps.values():
            for op in comp.ops:
                if op.kind in ("while", "conditional"):
                    continue
                for m in re.finditer(r"(?:calls|to_apply)=%?([\w.\-]+)", op.rest):
                    self.internal.add(m.group(1))

    def total(self) -> Cost:
        return self._cost(self.entry)

    def _cost(self, comp_name: str) -> Cost:
        if comp_name in self._memo:
            return self._memo[comp_name]
        comp = self.comps.get(comp_name)
        c = Cost()
        self._memo[comp_name] = c          # memo-before-recurse (no cycles in HLO)
        if comp is None:
            return c
        is_fusion_body = comp_name in self.internal
        for op in comp.ops:
            if op.kind == "while":
                body = _BODY_RE.search(op.rest)
                cond = _COND_RE.search(op.rest)
                tk = _KNOWN_TRIP_RE.search(op.rest)
                if tk:
                    trip = int(tk.group(1))
                else:
                    trip = _trip_count(self.comps[cond.group(1)]) if cond and \
                        cond.group(1) in self.comps else 1
                sub = Cost()
                if body and body.group(1) in self.comps:
                    sub.add(self._cost(body.group(1)))
                if cond and cond.group(1) in self.comps:
                    sub.add(self._cost(cond.group(1)))
                c.add(sub, trip)
                c.bytes += _shape_list_bytes(op.result_text)
                c.bytes_hot += _shape_list_bytes(op.result_text)
            elif op.kind == "fusion":
                m = _CALLS_RE.search(op.rest)
                called = self.comps.get(m.group(1)) if m else None
                if called is not None:
                    c.add(self._cost(called.name))
                fb = _shape_list_bytes(op.result_text) + _operand_bytes(comp, op)
                if called is not None:
                    upd = _dus_update_bytes(called)
                    if upd is not None:
                        # in-place DUS: only the slice moves, not the buffer
                        fb = max(2 * upd, fb - 2 * _shape_list_bytes(op.result_text))
                c.bytes += fb
                c.bytes_hot += fb
            elif op.kind == "conditional":
                m = _BRANCHES_RE.search(op.rest)
                if m:
                    branches = _OPERAND_RE.findall(m.group(1))
                    if branches:
                        subs = [self._cost(b) for b in branches if b in self.comps]
                        if subs:
                            worst = max(subs, key=lambda s: s.flops + s.bytes)
                            c.add(worst)
            elif op.kind in ("call", "custom-call", "map", "reduce", "sort",
                             "reduce-window", "scatter", "select-and-scatter"):
                m = _CALL_ATTR_RE.search(op.rest)
                if m and m.group(1) in self.comps:
                    # applied per element for reduce/map — approximate: once
                    c.add(self._cost(m.group(1)))
                rb2 = _shape_list_bytes(op.result_text) + _operand_bytes(comp, op)
                c.bytes += rb2
                c.bytes_hot += rb2
                if op.kind == "reduce":
                    c.flops += _operand_bytes(comp, op) / 4.0   # ~1 flop/elem
            elif any(op.kind == k or op.kind == k + "-start" for k in _COLLECTIVE_KINDS):
                g = _group_size(op.rest, self.n_devices)
                kind = op.kind.replace("-start", "")
                if kind == "all-reduce":
                    # -start results can be (operand, result) tuples; prefer
                    # operand bytes to avoid double counting
                    ob = _operand_bytes(comp, op)
                    base = ob if ob else _shape_list_bytes(op.result_text)
                    moved = 2.0 * (g - 1) / g * base
                elif kind == "all-gather":
                    moved = (g - 1) / g * _shape_list_bytes(op.result_text)
                elif kind == "reduce-scatter":
                    moved = float(g - 1) * _shape_list_bytes(op.result_text)
                elif kind == "all-to-all":
                    moved = (g - 1) / g * _shape_list_bytes(op.result_text)
                else:
                    moved = float(_shape_list_bytes(op.result_text))
                if g <= 1:
                    moved = 0.0
                c.coll_bytes += moved
                c.n_coll += 1
                c.coll_by_kind[kind] = c.coll_by_kind.get(kind, 0.0) + moved
                c.bytes += _shape_list_bytes(op.result_text)
                c.bytes_hot += _shape_list_bytes(op.result_text)
            elif op.kind in ("dot", "dot-general"):
                c.flops += _dot_flops(comp, op)
                if not is_fusion_body:
                    db = _shape_list_bytes(op.result_text) + _operand_bytes(comp, op)
                    c.bytes += db
                    c.bytes_hot += db
            elif op.kind == "convolution":
                # rough: 2 * out_elems * (in_channels * window) — not used by
                # our models (convs are expressed as shifts), keep minimal
                c.flops += 2.0 * _elements(op.result_text)
            elif op.kind == "dynamic-update-slice":
                upd = 0
                if len(op.operands) >= 2:
                    t = comp.defs.get(op.operands[1]) or comp.params.get(op.operands[1])
                    upd = _shape_list_bytes(t) if t else 0
                c.bytes += 2 * upd
                c.bytes_hot += 2 * upd
            elif op.kind in ("dynamic-slice", "gather"):
                db = 2 * _shape_list_bytes(op.result_text)
                c.bytes += db
                c.bytes_hot += db
            else:
                if op.kind in _CHEAP_ELEMWISE:
                    c.flops += float(_elements(op.result_text))
                if not is_fusion_body and op.kind not in (
                        "parameter", "constant", "get-tuple-element", "tuple",
                        "bitcast", "copy", "after-all"):
                    eb = _shape_list_bytes(op.result_text) + _operand_bytes(comp, op)
                    c.bytes += eb
                    # naked elementwise/shape ops fuse away on TPU; keep
                    # gather/scatter/dynamic-slice/DUS/iota-free data movers
                    if op.kind not in _CHEAP_ELEMWISE and op.kind not in (
                            "broadcast", "reshape", "transpose", "iota",
                            "slice", "concatenate", "pad", "reverse"):
                        c.bytes_hot += eb
        return c
