"""Checkpointing: atomic, asynchronous, retention-managed, reshard-on-restore.

Layout:  <dir>/step_<N>/
           meta.msgpack.{zst,zlib} — step, codec, tree structure,
                                  shapes/dtypes; zstd-compressed when the
                                  optional zstandard package is installed,
                                  stdlib-zlib otherwise (restore reads both)
           arrays.npz           — flattened leaves keyed by tree path

Atomicity: everything is written into ``<dir>/.tmp_<N>`` and os.replace()d
into place — a crash mid-save never corrupts the latest checkpoint (the
restart path always loads the newest *complete* step directory).

Async: ``save()`` snapshots the arrays to host (jax.device_get) synchronously
— cheap — then serializes/writes on a background thread so the train loop
overlaps checkpoint IO with the next steps. ``wait()`` drains.

Elastic restore: ``restore()`` returns host numpy; the caller re-places with
whatever sharding the *current* mesh wants (runtime/elastic.py) — a
checkpoint saved on a 16×16 mesh restores cleanly onto 8×16 after losing a
pod row; tests/test_checkpoint.py exercises a reshard round trip.

On a real multi-host pod each process saves only addressable shards
(jax.experimental.multihost_utils); single-process here, so leaves are full
arrays — the format keeps the per-leaf key scheme that the sharded writer
would use.
"""
from __future__ import annotations

import io
import json
import os
import re
import shutil
import threading
import zlib
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Tuple

import msgpack
import numpy as np

try:                                   # optional — stdlib zlib fallback below
    import zstandard as zstd
except ImportError:
    zstd = None

import jax

_STEP_RE = re.compile(r"^step_(\d+)$")

# Manifest codec: zstd when the optional package is present, else stdlib
# zlib. The codec is recorded both in the manifest filename extension and in
# the manifest body ("codec" key), so a checkpoint written by either side
# restores on the other (a .zst manifest still *requires* zstandard to read).
_META_BASENAME = "meta.msgpack"
_CODEC_EXT = {"zstd": ".zst", "zlib": ".zlib"}
_CODEC = "zstd" if zstd is not None else "zlib"


def _compress_meta(data: bytes) -> bytes:
    if _CODEC == "zstd":
        return zstd.ZstdCompressor().compress(data)
    return zlib.compress(data, 6)


def _decompress_meta(data: bytes, codec: str) -> bytes:
    if codec == "zstd":
        if zstd is None:
            raise ImportError(
                "checkpoint manifest is zstd-compressed but the optional "
                "'zstandard' package is not installed")
        return zstd.ZstdDecompressor().decompress(data)
    if codec == "zlib":
        return zlib.decompress(data)
    raise ValueError(f"unknown checkpoint manifest codec {codec!r}")


def _find_meta(path: str) -> Tuple[str, str]:
    """→ (manifest path, codec) for a step directory, any known codec."""
    for codec, ext in _CODEC_EXT.items():
        cand = os.path.join(path, _META_BASENAME + ext)
        if os.path.exists(cand):
            return cand, codec
    raise FileNotFoundError(f"no checkpoint manifest in {path}")


def _flatten(tree) -> Tuple[Dict[str, np.ndarray], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(leaf)
    return out, treedef


def _unflatten(treedef, arrays: Dict[str, np.ndarray]):
    leaves = [arrays[k] for k in sorted(arrays)]
    # tree_flatten_with_path orders leaves identically to tree_flatten; we
    # saved keys in that order, so rebuild by re-deriving the key order
    return jax.tree_util.tree_unflatten(treedef, leaves)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._pending: List[Future] = []
        self._lock = threading.Lock()

    # -- save -----------------------------------------------------------------
    def save(self, step: int, tree, blocking: bool = False) -> Future:
        host_tree = jax.device_get(tree)
        fut = self._pool.submit(self._write, step, host_tree)
        with self._lock:
            self._pending = [f for f in self._pending if not f.done()] + [fut]
        if blocking:
            fut.result()
        return fut

    def _write(self, step: int, host_tree):
        flat, treedef = _flatten(host_tree)
        tmp = os.path.join(self.dir, f".tmp_{step}")
        final = os.path.join(self.dir, f"step_{step}")
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        # leaf arrays (order-preserving keys: index prefix)
        ordered = {f"{i:06d}": v for i, (_, v) in enumerate(sorted(flat.items()))}
        np.savez(os.path.join(tmp, "arrays.npz"), **ordered)
        meta = {
            "step": step,
            "codec": _CODEC,
            "keys": sorted(flat.keys()),
            "treedef": str(treedef),
            "shapes": {k: list(v.shape) for k, v in flat.items()},
            "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        }
        blob = _compress_meta(msgpack.packb(meta, use_bin_type=True))
        with open(os.path.join(tmp, _META_BASENAME + _CODEC_EXT[_CODEC]), "wb") as f:
            f.write(blob)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._retain()
        return step

    def _retain(self):
        steps = self.list_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    def wait(self):
        with self._lock:
            pending = list(self._pending)
        for f in pending:
            f.result()

    # -- restore ---------------------------------------------------------------
    def list_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            m = _STEP_RE.match(name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, treedef_like, step: Optional[int] = None):
        """→ (step, host pytree shaped like ``treedef_like``)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step}")
        meta_path, codec = _find_meta(path)
        with open(meta_path, "rb") as f:
            meta = msgpack.unpackb(_decompress_meta(f.read(), codec), raw=False)
        with np.load(os.path.join(path, "arrays.npz")) as z:
            arrays = {meta["keys"][int(k)]: z[k] for k in z.files}
        ref_flat, treedef = _flatten(treedef_like)
        if sorted(ref_flat.keys()) != meta["keys"]:
            missing = set(meta["keys"]) ^ set(ref_flat.keys())
            raise ValueError(f"checkpoint/model tree mismatch: {sorted(missing)[:5]}")
        leaves_in_order = []
        flat_paths, _ = jax.tree_util.tree_flatten_with_path(treedef_like)
        for pth, _leaf in flat_paths:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in pth)
            leaves_in_order.append(arrays[key])
        tree = jax.tree_util.tree_unflatten(treedef, leaves_in_order)
        return step, tree

    def restore_placed(self, treedef_like, shardings, step: Optional[int] = None):
        """Restore + device_put with the CURRENT mesh's shardings (elastic)."""
        step, host = self.restore(treedef_like, step)
        placed = jax.tree.map(
            lambda a, s: jax.device_put(a, s), host, shardings)
        return step, placed
