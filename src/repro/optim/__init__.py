from repro.optim.adamw import (adamw_update, clip_by_global_norm, cosine_lr,
                               global_norm, init_opt_state)
from repro.optim.compression import (compressed_reduce, compressed_tree_reduce,
                                     dequantize_int8, init_error_feedback,
                                     quantize_int8)

__all__ = ["adamw_update", "clip_by_global_norm", "cosine_lr", "global_norm",
           "init_opt_state", "compressed_reduce", "compressed_tree_reduce",
           "dequantize_int8", "init_error_feedback", "quantize_int8"]
