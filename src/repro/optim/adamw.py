"""AdamW in pure JAX (no optax): decoupled weight decay, bias correction,
global-norm clipping, cosine schedule with linear warmup.

State is a pytree mirroring params ({m, v} f32 + scalar step), so ZeRO-1
sharding is just a PartitionSpec choice on m/v (sharding/specs.py).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig


def init_opt_state(params, dtype=jnp.float32):
    """``dtype`` — moment dtype; bf16 halves optimizer memory for the 314B
    arch (quantized-state practice); update math is always f32."""
    zeros = lambda p: jnp.zeros(p.shape, dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def cosine_lr(step, cfg: OptimizerConfig):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-12))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype),
                        grads), g


def adamw_update(params, grads, state, cfg: OptimizerConfig) -> Tuple[dict, dict, dict]:
    """→ (new_params, new_state, metrics). Decay is NOT applied to 1-D params
    (norms, biases) — standard practice."""
    step = state["step"] + 1
    lr = cosine_lr(step, cfg)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        mdt = m.dtype
        g = g.astype(jnp.float32)
        m = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mh = m / c1
        vh = v / c2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim > 1:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m.astype(mdt), v.astype(mdt))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_state = {
        "m": jax.tree.unflatten(treedef, [o[1] for o in out]),
        "v": jax.tree.unflatten(treedef, [o[2] for o in out]),
        "step": step,
    }
    return new_p, new_state, {"lr": lr, "grad_norm": gnorm}
