"""Gradient compression for the slow cross-pod link: int8 quantization with
error feedback.

Scheme (per tensor, inside shard_map over the ``pod`` axis):
  1. reduce-scatter the raw gradient over the pod axis (bf16/f32) — the
     reduction leg stays exact;
  2. add the local error-feedback residual, quantize the local shard to int8
     with one f32 scale per tensor (symmetric, max-abs);
  3. all-gather the INT8 shards (+ scales) — this leg moves 4× fewer bytes
     than f32 / 2× fewer than bf16, which is where cross-DCI bandwidth goes;
  4. dequantize; the residual (what quantization lost) is carried to the
     next step (error feedback keeps the scheme unbiased over time).

On a 2-pod mesh the all-gather leg is half the all-reduce traffic, so this
cuts cross-pod bytes ≈ 1.6-1.9× total (EXPERIMENTS.md §Perf measures it via
HLO collective bytes).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.utils import axis_size, match_vma


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def init_error_feedback(grads, axis_size: int):
    """EF residual holds the LOCAL reduce-scatter shard (leading dim / n)."""
    def shard_zeros(g):
        lead = g.shape[0] // axis_size if g.ndim and g.shape[0] % axis_size == 0 \
            else g.shape[0] if g.ndim else 1
        shape = (lead,) + tuple(g.shape[1:]) if g.ndim else (1,)
        return jnp.zeros(shape, jnp.float32)
    return jax.tree.map(shard_zeros, grads)


def compressed_reduce(g: jnp.ndarray, ef: jnp.ndarray, axis: str):
    """All-reduce-mean of one tensor over ``axis`` with an int8 all-gather leg.
    Call inside shard_map. Falls back to exact psum when the leading dim
    doesn't tile. → (reduced (same shape as g), new_ef)."""
    n = axis_size(axis)
    if g.ndim == 0 or g.shape[0] % n != 0:
        return jax.lax.pmean(g, axis), ef

    rs = jax.lax.psum_scatter(g.astype(jnp.float32), axis,
                              scatter_dimension=0, tiled=True) / n
    q, scale = quantize_int8(rs + ef)
    new_ef = (rs + ef) - dequantize_int8(q, scale)
    qg = jax.lax.all_gather(q, axis, tiled=True)
    sg = jax.lax.all_gather(scale[None], axis)                     # (n,)
    idx = jnp.repeat(jnp.arange(n), rs.shape[0])
    deq = qg.astype(jnp.float32) * sg[idx].reshape(
        (-1,) + (1,) * (qg.ndim - 1))
    return deq.astype(g.dtype), new_ef


def compressed_tree_reduce(grads, ef_tree, axis: str):
    """Tree version: → (reduced_grads, new_ef_tree)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef_tree)
    out = [compressed_reduce(g, e, axis) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            jax.tree.unflatten(treedef, [o[1] for o in out]))
