"""Message framing for MPKLink channels.

A frame is a uint32 matrix of 128 lanes (the TPU-native layout the guard
kernel consumes):

  row 0   — header: [MAGIC, seed, seq, nbytes, dtype_code, ndim,
                     shape[0..3], 0, mac^meta_mix, 0...]
  rows 1+ — payload: raw bytes viewed as little-endian uint32, zero-padded
            to a whole number of 128-lane rows.

The MAC in the header is the Horner hash of the payload rows seeded with
``seed = domain.tag ⊕ epoch-mix ⊕ session`` (see domains.mac_seed and
ca.session_seed) — so a frame is only verifiable by a peer holding the same
domain key *and* session identity, at the current epoch. That single uint32
check is where MPK access control and the paper's per-message signature
collapse into one fused operation on-device.

Header integrity: the stored word is ``payload_mac ⊕ _meta_mix(header)``, a
Horner mix of the ten metadata words — so flipping any header bit (dtype,
shape, nbytes, ...) fails verification exactly like a payload flip, and the
reserved lanes (10, 12..127) must be zero. The payload MAC itself is
unchanged and stays bit-identical to the guard kernel / fast_mac.

Works on both numpy (host transports) and jnp (device fabric) arrays.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np

MAGIC = 0x4D504B4C            # "MPKL"
LANES = 128

_DTYPES = {0: np.float32, 1: np.int32, 2: np.uint32, 3: np.uint8,
           4: np.dtype("<f8"), 5: np.int64, 6: np.uint16}
_DTYPE_CODES = {np.dtype(v): k for k, v in _DTYPES.items()}


class FrameError(ValueError):
    pass


def _mac_np(payload_u32: np.ndarray, seed: int) -> int:
    """Host twin of kernels.ref.mac_ref (same constants, same fold)."""
    from repro.kernels.ref import MAC_PRIME, MAC_INIT, _FOLD_POWERS
    h = np.full(LANES, MAC_INIT, np.uint64)
    h = (h + np.uint64(seed & 0xFFFFFFFF)) & 0xFFFFFFFF
    for row in payload_u32:
        h = (h * MAC_PRIME + row.astype(np.uint64)) & 0xFFFFFFFF
    return int((h * _FOLD_POWERS.astype(np.uint64)).sum() & 0xFFFFFFFF)


def _meta_mix(header: np.ndarray, seed: int) -> int:
    """Horner mix of the ten metadata words (magic..shape[3]) — folded into
    the stored MAC word so header tampering fails exactly like payload
    tampering. Pure uint arithmetic, deterministic everywhere."""
    from repro.kernels.ref import MAC_PRIME
    h = (0x9E3779B9 ^ (seed & 0xFFFFFFFF)) & 0xFFFFFFFF
    for w in header[:10]:
        h = (h * MAC_PRIME + int(w)) & 0xFFFFFFFF
    return h


def pack_payload(arr: np.ndarray) -> Tuple[np.ndarray, dict]:
    """array → ((rows, 128) uint32, meta). Zero-pads to lane multiples."""
    arr = np.ascontiguousarray(arr)
    if arr.dtype not in _DTYPE_CODES:
        raise FrameError(f"unsupported dtype {arr.dtype}")
    raw = arr.view(np.uint8).reshape(-1)
    pad = (-raw.size) % (LANES * 4)
    if pad:
        raw = np.concatenate([raw, np.zeros(pad, np.uint8)])
    u32 = raw.view("<u4").reshape(-1, LANES)
    meta = {"dtype_code": _DTYPE_CODES[arr.dtype], "nbytes": arr.nbytes,
            "shape": tuple(arr.shape)}
    return u32, meta


def unpack_payload(payload_u32: np.ndarray, meta: dict) -> np.ndarray:
    raw = np.ascontiguousarray(payload_u32).view(np.uint8).reshape(-1)
    raw = raw[: meta["nbytes"]]
    return raw.view(_DTYPES[meta["dtype_code"]]).reshape(meta["shape"])


def build_frame(arr: np.ndarray, *, seed: int, seq: int, mac_impl=None) -> np.ndarray:
    """array → full frame (header row + payload rows) uint32."""
    payload, meta = pack_payload(arr)
    shape = list(meta["shape"])[:4] + [0] * (4 - min(4, len(meta["shape"])))
    if len(meta["shape"]) > 4:
        raise FrameError("rank > 4 payloads unsupported by frame header")
    mac = (mac_impl or _mac_np)(payload, seed)
    header = np.zeros(LANES, np.uint32)
    header[:10] = [MAGIC, seed & 0xFFFFFFFF, seq & 0xFFFFFFFF,
                   meta["nbytes"] & 0xFFFFFFFF, meta["dtype_code"],
                   len(meta["shape"]), *[s & 0xFFFFFFFF for s in shape]]
    header[11] = (mac ^ _meta_mix(header, seed)) & 0xFFFFFFFF
    return np.concatenate([header[None], payload], axis=0)


def parse_frame(frame: np.ndarray, *, seed: int, expect_seq=None, mac_impl=None) -> np.ndarray:
    """Verify magic, seed, seq, header integrity, MAC; return the payload.
    Raises FrameError on any mismatch — this is the receive-side guard."""
    frame = np.asarray(frame)
    if frame.ndim != 2 or frame.shape[0] < 1 or frame.shape[1] != LANES:
        raise FrameError("malformed frame — truncated or not lane-aligned")
    header, payload = frame[0], frame[1:]
    if int(header[0]) != MAGIC:
        raise FrameError("bad magic — not an MPKLink frame")
    if int(header[1]) != (seed & 0xFFFFFFFF):
        raise FrameError("seed mismatch — wrong domain key, session or epoch")
    if expect_seq is not None and int(header[2]) != (expect_seq & 0xFFFFFFFF):
        raise FrameError(f"sequence mismatch (got {int(header[2])}, want {expect_seq})")
    if int(header[10]) != 0 or np.any(np.asarray(header[12:]) != 0):
        raise FrameError("nonzero reserved header lanes — header tampered")
    mac = (mac_impl or _mac_np)(payload, seed)
    if (mac ^ _meta_mix(header, seed)) & 0xFFFFFFFF != int(header[11]):
        raise FrameError("MAC mismatch — payload or header tampered/truncated")
    ndim = int(header[5])
    nbytes = int(header[3])
    dtype_code = int(header[4])
    if dtype_code not in _DTYPES or ndim > 4:
        raise FrameError("invalid header metadata (dtype/ndim)")
    shape = tuple(int(s) for s in header[6:6 + ndim])
    itemsize = np.dtype(_DTYPES[dtype_code]).itemsize
    if int(np.prod(shape, dtype=np.int64)) * itemsize != nbytes:
        raise FrameError("invalid header metadata (shape/nbytes disagree)")
    if payload.shape[0] != frame_rows(nbytes) - 1:
        raise FrameError(
            f"frame length mismatch ({payload.shape[0]} payload rows for "
            f"{nbytes} bytes)")
    meta = {"dtype_code": dtype_code, "nbytes": nbytes, "shape": shape}
    return unpack_payload(payload, meta)


def frame_rows(nbytes: int) -> int:
    """Total frame rows (header + payload) for an nbytes message."""
    return 1 + (nbytes + LANES * 4 - 1) // (LANES * 4)
