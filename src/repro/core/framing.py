"""Message framing for MPKLink channels.

A frame is a uint32 matrix of 128 lanes (the TPU-native layout the guard
kernel consumes):

  row 0   — header: [MAGIC, seed, seq, nbytes, dtype_code, ndim,
                     shape[0..3], deadline_us, mac^meta_mix, priority,
                     0...]
  rows 1+ — payload: raw bytes viewed as little-endian uint32, zero-padded
            to a whole number of 128-lane rows.

The MAC in the header is the Horner hash of the payload rows seeded with
``seed = domain.tag ⊕ epoch-mix ⊕ session`` (see domains.mac_seed and
ca.session_seed) — so a frame is only verifiable by a peer holding the same
domain key *and* session identity, at the current epoch. That single uint32
check is where MPK access control and the paper's per-message signature
collapse into one fused operation on-device.

Header integrity: the stored word is ``payload_mac ⊕ _meta_mix(header)``, a
Horner mix of the twelve metadata words (magic..shape[3], the lane-10
deadline word, plus the lane-12 priority word) — so flipping any header bit
(dtype, shape, nbytes, deadline, priority, ...) fails verification exactly
like a payload flip, and the reserved lanes (13..127) must be zero. Lane 10
(:data:`DEADLINE_LANE`) carries the sender's remaining deadline budget in
microseconds (0 = no deadline) so a propagated deadline rides every
envelope MAC-covered; see docs/protocol.md §9. Lane 12
(:data:`PRIORITY_LANE`) carries the sender's QoS class
(:data:`PRIO_NORMAL` / :data:`PRIO_HIGH` / :data:`PRIO_BULK`), likewise
MAC-covered; see docs/protocol.md §10. The payload MAC itself is unchanged
and stays bit-identical to the guard kernel / fast_mac.

Zero-copy path (the arena data plane): :func:`seal_into` writes the header
and payload of a frame directly into a caller-provided buffer — typically a
:class:`FrameArena` slot or a transport's shared region — and MACs the
payload in place, so sealing a message costs exactly ONE write of the
payload bytes (no pad/concat staging allocations). :func:`verify_view`
is the receive-side twin: it runs the full guard and hands back the payload
as a **read-only view** aliasing the frame storage — no copy-out. The
legacy :func:`build_frame` / :func:`parse_frame` API is preserved
bit-for-bit on top of these (``build_frame`` = ``seal_into`` a fresh
buffer). :data:`STATS` counts bytes copied / concat calls so benchmarks can
prove the hot path allocation-free.

Batch path (the pipelined data plane): :func:`seal_batch` /
:func:`verify_batch` frame / verify N messages at once, with all N payload
MACs computed in ONE fused vectorized pass (:func:`mac_batch`) instead of N
Python-loop calls — same constants, bit-identical to the scalar MAC (and to
the batched ``kernels/mpk_guard`` device kernel). :func:`seal_into_batch`
is the arena twin: N frames sealed in place with one fused MAC pass.
:func:`split_frames` separates concatenated frames back into messages,
which is how the gateway's batch envelope is carved up server-side.

Streaming MAC: :func:`mac_init_np` / :func:`mac_update_np` /
:func:`mac_finalize_np` expose the block-Horner recurrence directly, so a
large payload can be MAC'd chunk by chunk as it lands in a region — no
staging copy. ``transports.fast_mac`` and the batch pass are thin
compositions of these; ``kernels/mpk_guard`` carries the device twins
(``mac_update_pallas`` / ``mac_update_jnp``). All are bit-identical to the
scalar :func:`_mac_np`.

Works on both numpy (host transports) and jnp (device fabric) arrays.
"""
from __future__ import annotations

import functools
import math
import sys
import threading
import weakref
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

MAGIC = 0x4D504B4C            # "MPKL"
LANES = 128

# Header lane carrying the sender's remaining deadline budget in
# microseconds (0 = no deadline). MAC-covered via the meta mix, so a
# tampered deadline fails verification like any other header flip.
DEADLINE_LANE = 10
DEADLINE_US_MAX = 0xFFFFFFFF

# Header lane carrying the sender's QoS priority class (docs/protocol.md
# §10). PRIO_NORMAL = 0 so a legacy zeroed lane decodes as the default
# class. Folded into the meta mix like the lane-10 deadline word, so a
# tampered priority fails verification like any other header flip.
PRIORITY_LANE = 12
PRIO_NORMAL = 0
PRIO_HIGH = 1
PRIO_BULK = 2
_PRIO_MAX = PRIO_BULK

_DTYPES = {0: np.float32, 1: np.int32, 2: np.uint32, 3: np.uint8,
           4: np.dtype("<f8"), 5: np.int64, 6: np.uint16}
_DTYPE_CODES = {np.dtype(v): k for k, v in _DTYPES.items()}

# Benchmark/testing switch: False routes build paths through the PR 3 copy
# pattern (pad concat + header concat) so the zero-copy win is measurable
# in-run. Verification accepts frames from either path — they are
# bit-identical (tests/test_zero_copy.py asserts it).
ZERO_COPY = True


class FrameError(ValueError):
    pass


# ---------------------------------------------------------------------------
# allocation/copy accounting (the gateway_bench stats hook)
# ---------------------------------------------------------------------------

class FrameStats:
    """Process-wide framing + data-plane counters. ``bytes_copied`` counts
    every byte the framing layer writes or re-materializes (payload writes,
    pad/concat staging, header concat); ``concat_calls`` counts
    ``np.concatenate`` invocations on the frame path. The zero-copy seal
    path adds exactly ``payload nbytes`` per frame and zero concats —
    benchmarks assert the delta.

    The transports account their signalling here too: ``wakeups`` counts
    doorbell rings (every notify that can wake a parked peer — the
    coalescing bench divides this by requests), ``doorbell_parks`` counts
    waits that actually parked on the condition after the bounded spin,
    and ``key_syncs`` counts PKRU synchronization round trips (the
    transport-local ``sync_count`` aggregated process-wide).

    Updates go through :meth:`bump` — the ``workers=N`` sharded executor
    and the N per-session service threads all write these counters
    concurrently, and the unguarded ``+=`` this replaced drops counts
    under thread interleaving (tests/test_doorbell.py asserts exact
    totals). Counters are sharded per thread (each thread owns a private
    dict, registered once under a lock), so the hot path takes NO lock:
    an increment can never be lost, and :meth:`snapshot` sums the shards
    — exact whenever the counting threads have quiesced (how every test
    and benchmark reads it). Reading a field attribute
    (``STATS.bytes_copied``) sums shards the same way."""

    _FIELDS = ("frames_sealed", "frames_sealed_inplace", "frames_verified",
               "views_returned", "bytes_copied", "concat_calls",
               "arena_allocated", "arena_reused", "arena_released",
               "wakeups", "doorbell_parks", "key_syncs")

    def __init__(self):
        self._rlock = threading.Lock()      # guards the shard registry only
        self._local = threading.local()
        # (owner thread, shard dict): a dead owner can never bump again, so
        # its shard is folded into _retired and dropped — a long-lived
        # process cycling thousands of session threads must not accumulate
        # dead shards (or pay O(threads-ever) per snapshot)
        self._shards: List[Tuple[threading.Thread, Dict[str, int]]] = []
        self._retired: Dict[str, int] = dict.fromkeys(self._FIELDS, 0)

    def _shard(self) -> Dict[str, int]:
        d = getattr(self._local, "d", None)
        if d is None:
            d = dict.fromkeys(self._FIELDS, 0)
            self._local.d = d
            with self._rlock:
                self._shards.append((threading.current_thread(), d))
        return d

    def _fold_dead_locked(self) -> None:
        live = []
        for th, d in self._shards:
            if th.is_alive():
                live.append((th, d))
            else:                       # no further bumps possible: fold
                for f in self._FIELDS:
                    self._retired[f] += d[f]
        self._shards = live

    def bump(self, **deltas: int) -> None:
        """Add each delta to its counter — lock-free (per-thread shard);
        unknown counter names raise KeyError."""
        # inlined registered-shard fetch: this runs several times per
        # data-plane exchange, so the common case must not pay an extra
        # method call on top of the thread-local lookup
        d = getattr(self._local, "d", None)
        if d is None:
            d = self._shard()
        for name, delta in deltas.items():
            d[name] += delta            # KeyError on unknown fields

    def reset(self):
        with self._rlock:
            self._fold_dead_locked()
            self._retired = dict.fromkeys(self._FIELDS, 0)
            shards = [d for _, d in self._shards]
        for d in shards:
            for f in self._FIELDS:
                d[f] = 0

    def snapshot(self) -> Dict[str, int]:
        with self._rlock:
            self._fold_dead_locked()
            out = dict(self._retired)
            shards = [d for _, d in self._shards]
        for d in shards:
            for f in self._FIELDS:
                out[f] += d[f]
        return out

    def __getattr__(self, name: str):
        # field reads sum the shards; anything else is a real miss. The
        # startswith guard keeps __init__'s own attribute setup safe.
        if not name.startswith("_") and name in FrameStats._FIELDS:
            return self.snapshot()[name]
        raise AttributeError(name)


STATS = FrameStats()


# ---------------------------------------------------------------------------
# MAC: scalar reference, hoisted power tables, streaming block-Horner
# ---------------------------------------------------------------------------

def _mac_np(payload_u32: np.ndarray, seed: int) -> int:
    """Host twin of kernels.ref.mac_ref (same constants, same fold)."""
    from repro.kernels.ref import MAC_PRIME, MAC_INIT, _FOLD_POWERS
    h = np.full(LANES, MAC_INIT, np.uint64)
    h = (h + np.uint64(seed & 0xFFFFFFFF)) & 0xFFFFFFFF
    for row in payload_u32:
        h = (h * MAC_PRIME + row.astype(np.uint64)) & 0xFFFFFFFF
    return int((h * _FOLD_POWERS.astype(np.uint64)).sum() & 0xFFFFFFFF)


@functools.lru_cache(maxsize=256)
def _power_table(m: int) -> Tuple[np.ndarray, np.uint64]:
    """``([P^(m-1), ..., P, 1] mod 2^64, P^m mod 2^64)`` for an m-row block.

    Hoisted out of the block loops — the same table was being recomputed
    (full cumprod) for every block of every message. uint64 wraparound keeps
    the low 32 bits exact (2^32 | 2^64), so results are unchanged."""
    from repro.kernels.ref import MAC_PRIME
    with np.errstate(over="ignore"):
        pw = np.full(max(m, 1), MAC_PRIME, np.uint64)
        pw[0] = 1
        pw = np.ascontiguousarray(np.cumprod(pw)[::-1])
        if m == 0:
            pw = pw[:0]
            p_m = np.uint64(1)
        else:
            p_m = np.uint64((int(pw[0]) * MAC_PRIME) & 0xFFFFFFFFFFFFFFFF)
    pw.setflags(write=False)
    return pw, p_m


@functools.lru_cache(maxsize=1)
def _fold_powers_u32() -> np.ndarray:
    from repro.kernels.ref import _FOLD_POWERS
    fp = _FOLD_POWERS.astype(np.uint32)
    fp.setflags(write=False)
    return fp


@functools.lru_cache(maxsize=256)
def _power_table32(m: int) -> Tuple[np.ndarray, np.uint32]:
    """uint32 twin of :func:`_power_table`. Every Horner quantity is only
    ever needed mod 2^32, so the whole recurrence runs in native uint32 —
    SIMD-friendly multiplies, no widening staging copies — and wraps to
    exactly the same bits."""
    pw, p_m = _power_table(m)
    pw32 = (pw & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    pw32.setflags(write=False)
    return pw32, np.uint32(int(p_m) & 0xFFFFFFFF)


@functools.lru_cache(maxsize=256)
def _mac_row1_const(seed32: int) -> int:
    """Init-state contribution to a ONE-row payload MAC, folded to a
    scalar. The Horner init is lane-constant (h0 = INIT+seed), so its
    folded term Σ_l fold_l·h0·P collapses to h0·P·Σ_l fold_l mod 2^32
    (multiplication distributes over the mod-2^32 sum) — cache it per
    seed and the whole one-row MAC is two vector ops."""
    from repro.kernels.ref import MAC_INIT, MAC_PRIME
    s_fold = int(_fold_powers_u32().sum(dtype=np.uint32))
    h0 = (MAC_INIT + seed32) & 0xFFFFFFFF
    return (h0 * MAC_PRIME * s_fold) & 0xFFFFFFFF


@functools.lru_cache(maxsize=1)
def _fold_ints() -> Tuple[int, ...]:
    """The fold powers as plain python ints (for the short-row MAC)."""
    return tuple(int(v) for v in _fold_powers_u32())


def _mac_row1(row_u32: np.ndarray, seed: int) -> int:
    """One-row payload MAC: cached init fold + fold·row. Bit-identical to
    :func:`_mac_np` on a (1, LANES) payload (the batch/zero-copy tests
    assert equality over the dtype/shape sweep).

    Short messages (the common RPC response) occupy a handful of leading
    words — every zero lane contributes 0 to the fold, so after a C-level
    trailing-zero scan the whole contraction is a few python multiplies,
    cheaper than two numpy dispatches over 128 lanes."""
    b = row_u32.tobytes()
    nz = len(b.rstrip(b"\x00"))
    if nz <= 64:
        fold = _fold_ints()
        body = 0
        for i in range(0, nz, 4):
            body += fold[i >> 2] * int.from_bytes(b[i:i + 4], "little")
        return (_mac_row1_const(seed & 0xFFFFFFFF) + body) & 0xFFFFFFFF
    body = int((_fold_powers_u32() * row_u32).sum(dtype=np.uint32))
    return (_mac_row1_const(seed & 0xFFFFFFFF) + body) & 0xFFFFFFFF


@functools.lru_cache(maxsize=512)
def _mac_block_const(seed32: int, m: int) -> int:
    """``_mac_row1_const`` generalized to an m-row payload: the folded
    init-state term h0·P^m·Σ_l fold_l mod 2^32, cached per (seed, m)."""
    from repro.kernels.ref import MAC_INIT, MAC_PRIME
    s_fold = int(_fold_powers_u32().sum(dtype=np.uint32))
    h0 = (MAC_INIT + seed32) & 0xFFFFFFFF
    return (h0 * pow(MAC_PRIME, m, 1 << 32) * s_fold) & 0xFFFFFFFF


def _mac_block(payload_u32: np.ndarray, seed: int) -> int:
    """Whole-payload MAC in two contractions. The folded MAC
    Σ_l fold_l·(h0·P^m + Σ_r row_r·P^(m-1-r))_l regroups — every product
    distributes over the mod-2^32 sums — into

        h0·P^m·Σ_l fold_l  +  Σ_r P^(m-1-r) · (Σ_l fold_l·row_{r,l})

    i.e. fold the LANE axis first (one (m,L)×(L) contraction), then a
    length-m dot with the power table. Bit-identical to running
    init → update → finalize, at a fraction of the dispatch overhead."""
    m = payload_u32.shape[0]
    pw32, _ = _power_table32(m)
    s = np.einsum("rl,l->r", payload_u32, _fold_powers_u32(),
                  dtype=np.uint32, casting="unsafe")
    body = int((pw32 * s).sum(dtype=np.uint32))
    return (_mac_block_const(seed & 0xFFFFFFFF, m) + body) & 0xFFFFFFFF


@functools.lru_cache(maxsize=256)
def _mac_init_cached(seed32: int) -> np.ndarray:
    from repro.kernels.ref import MAC_INIT
    h = np.full(LANES, (MAC_INIT + seed32) & 0xFFFFFFFF, np.uint32)
    h.setflags(write=False)
    return h


def mac_init_np(seed: int) -> np.ndarray:
    """(LANES,) uint32 Horner state for ``seed`` (values < 2^32). The
    returned array is READ-ONLY (and cached per seed — sessions init a
    state per exchange): advance it with :func:`mac_update_np`, which
    returns a fresh array rather than mutating."""
    return _mac_init_cached(seed & 0xFFFFFFFF)


def mac_update_np(h: np.ndarray, block_u32: np.ndarray) -> np.ndarray:
    """Advance the Horner state over an (m, LANES) uint32 block in one
    vectorized step: ``h' = h·P^m + Σ_r row_r·P^(m-1-r)`` (mod 2^32).
    Pure uint32 arithmetic end to end (wraparound mod 2^32 IS the MAC's
    modulus — no uint64 widening or staging copy), one einsum contraction
    per block. Bit-identical to feeding the rows one by one into
    :func:`_mac_np`'s loop — the streaming form lets large payloads be
    MAC'd chunk by chunk as they land in a region."""
    m = block_u32.shape[0]
    if m == 0:
        return h
    pw32, p_m32 = _power_table32(m)
    if m == 1:                  # P^0 = 1: the contraction IS the row
        return h * p_m32 + block_u32[0]
    # no errstate guard: unsigned ARRAY arithmetic wraps silently in numpy
    # (wraparound mod 2^32 IS the modulus) — only scalar ops would warn,
    # and none run here. Saves ~1.5us per call on the data-plane hot path.
    acc = np.einsum("r,rl->l", pw32, block_u32, dtype=np.uint32,
                    casting="unsafe")
    return h * p_m32 + acc


def mac_finalize_np(h: np.ndarray) -> int:
    """Fold the (LANES,) Horner state to the 32-bit MAC word."""
    return int((h * _fold_powers_u32()).sum(dtype=np.uint32))


def warm_mac_caches(seed: int = 0) -> None:
    """Populate every lazily-imported constant and lru cache the hot
    seal/verify path touches. Process-backed transports call this BEFORE
    forking a service child: the deferred ``repro.kernels.ref`` import is
    expensive (it drags in the accelerator stack), and without the warm
    each child would re-pay it inside its first ``verify_view`` — the
    fork snapshot ships the warmed caches for free."""
    _fold_powers_u32()
    _power_table32(1)
    mac_init_np(seed)
    _mac_row1_const(seed & 0xFFFFFFFF)
    _meta_mix_words((0,) * 12, 0)


_MAC_PRIME: Optional[int] = None    # lazy: kernels.ref drags in jax


def _meta_mix_words(words, seed: int) -> int:
    """:func:`_meta_mix` over already-materialized python ints (the twelve
    MAC-covered header words: magic..deadline plus the lane-12 priority) —
    the hot-path form for callers that have the header words in hand."""
    global _MAC_PRIME
    prime = _MAC_PRIME
    if prime is None:
        from repro.kernels.ref import MAC_PRIME
        _MAC_PRIME = prime = MAC_PRIME
    h = (0x9E3779B9 ^ (seed & 0xFFFFFFFF)) & 0xFFFFFFFF
    for w in words:
        h = (h * prime + w) & 0xFFFFFFFF
    return h


def _meta_mix(header: np.ndarray, seed: int) -> int:
    """Horner mix of the twelve metadata words (magic..shape[3], the
    lane-10 deadline word, plus the lane-12 priority word) — folded into
    the stored MAC word so header tampering fails exactly like payload
    tampering. Pure uint arithmetic, deterministic everywhere."""
    h = np.asarray(header)
    return _meta_mix_words(h[:11].tolist() + [int(h[PRIORITY_LANE])], seed)


# ---------------------------------------------------------------------------
# pack / unpack
# ---------------------------------------------------------------------------

def pack_payload(arr: np.ndarray) -> Tuple[np.ndarray, dict]:
    """array → ((rows, 128) uint32, meta). Zero-pads to lane multiples.

    Lane-aligned inputs are returned as a zero-copy view; the pad path
    writes into ONE preallocated output buffer (no full-payload
    ``np.concatenate`` staging copy)."""
    arr = np.ascontiguousarray(arr)
    if arr.dtype not in _DTYPE_CODES:
        raise FrameError(f"unsupported dtype {arr.dtype}")
    raw = arr.view(np.uint8).reshape(-1)
    pad = (-raw.size) % (LANES * 4)
    if pad:
        rows = (raw.size + pad) // (LANES * 4)
        u32 = np.zeros((rows, LANES), np.uint32)
        u32.reshape(-1).view(np.uint8)[: raw.size] = raw
        STATS.bump(bytes_copied=raw.size)
    else:
        u32 = raw.view("<u4").reshape(-1, LANES)
    meta = {"dtype_code": _DTYPE_CODES[arr.dtype], "nbytes": arr.nbytes,
            "shape": tuple(arr.shape)}
    return u32, meta


def unpack_payload(payload_u32: np.ndarray, meta: dict) -> np.ndarray:
    raw = np.ascontiguousarray(payload_u32).view(np.uint8).reshape(-1)
    raw = raw[: meta["nbytes"]]
    return raw.view(_DTYPES[meta["dtype_code"]]).reshape(meta["shape"])


def _meta_of(arr: np.ndarray) -> dict:
    if arr.dtype not in _DTYPE_CODES:
        raise FrameError(f"unsupported dtype {arr.dtype}")
    if arr.ndim > 4:
        raise FrameError("rank > 4 payloads unsupported by frame header")
    return {"dtype_code": _DTYPE_CODES[arr.dtype], "nbytes": arr.nbytes,
            "shape": tuple(arr.shape)}


def _write_header(hrow: np.ndarray, meta: dict, seed: int, seq: int,
                  mac: int, deadline_us: int = 0, priority: int = 0) -> None:
    """Fill one 128-lane header row in place (reserved lanes zeroed — the
    row may be a recycled arena slot holding stale words). ``deadline_us``
    lands in lane 10 and ``priority`` in lane 12; both are folded into the
    meta mix, so the propagated deadline and QoS class are MAC-covered like
    every other header word."""
    shape = list(meta["shape"])[:4] + [0] * (4 - min(4, len(meta["shape"])))
    if len(meta["shape"]) > 4:
        raise FrameError("rank > 4 payloads unsupported by frame header")
    prio = int(priority)
    if not 0 <= prio <= _PRIO_MAX:
        raise FrameError(f"invalid priority class {priority}")
    words = [MAGIC, seed & 0xFFFFFFFF, seq & 0xFFFFFFFF,
             meta["nbytes"] & 0xFFFFFFFF, meta["dtype_code"],
             len(meta["shape"]), *[s & 0xFFFFFFFF for s in shape],
             int(deadline_us) & 0xFFFFFFFF]
    hrow[13:] = 0
    hrow[:13] = words + [
        (mac ^ _meta_mix_words(words + [prio], seed)) & 0xFFFFFFFF, prio]


def _assemble(payload: np.ndarray, meta: dict, seed: int, seq: int,
              mac: int, deadline_us: int = 0,
              priority: int = 0) -> np.ndarray:
    """Header row from (meta, seed, seq, precomputed payload MAC) + payload,
    materialized into ONE preallocated frame buffer."""
    frame = np.empty((payload.shape[0] + 1, LANES), np.uint32)
    _write_header(frame[0], meta, seed, seq, mac, deadline_us, priority)
    frame[1:] = payload
    STATS.bump(bytes_copied=payload.nbytes)
    return frame


# ---------------------------------------------------------------------------
# zero-copy seal / verify (the arena data plane)
# ---------------------------------------------------------------------------

def _check_buf(buf: np.ndarray, rows: int) -> None:
    shape = buf.shape
    if (len(shape) != 2 or shape[1] != LANES
            or buf.dtype != np.dtype(np.uint32)):
        raise FrameError("seal buffer must be a (rows, 128) uint32 matrix")
    flags = buf.flags
    if not flags.c_contiguous or not flags.writeable:
        raise FrameError("seal buffer must be C-contiguous and writable")
    if shape[0] < rows:
        raise FrameError(
            f"seal buffer too small ({shape[0]} rows for a {rows}-row "
            f"frame)")


def seal_into(buf: np.ndarray, arr: np.ndarray, *, seed: int, seq: int,
              mac_impl=None, deadline_us: int = 0, priority: int = 0,
              _inplace: bool = True) -> int:
    """Seal ``arr`` as a frame directly into ``buf`` (no staging buffers).

    ``buf`` is any C-contiguous writable ``(>= frame_rows(nbytes), 128)``
    uint32 buffer — a FrameArena slot, a transport's shared region, or a
    byte-slice of an outgoing envelope. The payload bytes are written once,
    the pad tail is zeroed (it is MAC-covered), the MAC runs over the
    payload *in place*, and the header row is written last. Returns the
    number of rows used; ``buf[rows:]`` is untouched. Bit-identical to
    :func:`build_frame` (tests/test_zero_copy.py asserts it for every
    dtype)."""
    if not isinstance(arr, np.ndarray) or not arr.flags.c_contiguous:
        arr = np.ascontiguousarray(arr)
    meta = _meta_of(arr)
    rows = frame_rows(meta["nbytes"])
    _check_buf(buf, rows)
    payload = buf[1:rows]
    pbytes = payload.reshape(-1).view(np.uint8)
    pbytes[: meta["nbytes"]] = arr.view(np.uint8).reshape(-1)
    pbytes[meta["nbytes"]:] = 0
    mac = (mac_impl or _mac_np)(payload, seed)
    _write_header(buf[0], meta, seed, seq, mac, deadline_us, priority)
    STATS.bump(frames_sealed=1, bytes_copied=meta["nbytes"],
               # build_frame seals a FRESH buffer: sealed, not in-place
               frames_sealed_inplace=int(_inplace))
    return rows


def seal_into_batch(bufs: Sequence[np.ndarray], arrays: Sequence[np.ndarray],
                    *, seed: int, seqs: Sequence[int], mac_impl=None,
                    deadlines_us: Optional[Sequence[int]] = None,
                    priorities: Optional[Sequence[int]] = None) -> List[int]:
    """Seal N frames in place with ONE fused vectorized MAC pass.

    The arena twin of :func:`seal_batch`: payload bytes land directly in
    each ``bufs[i]`` and all MACs are computed by :func:`mac_batch` over the
    in-place payload views. Returns rows-used per frame."""
    arrays = [np.ascontiguousarray(np.asarray(a)) for a in arrays]
    metas = [_meta_of(a) for a in arrays]
    rows_list = [frame_rows(m["nbytes"]) for m in metas]
    payloads = []
    for buf, arr, meta, rows in zip(bufs, arrays, metas, rows_list):
        _check_buf(buf, rows)
        payload = buf[1:rows]
        pbytes = payload.reshape(-1).view(np.uint8)
        pbytes[: meta["nbytes"]] = arr.view(np.uint8).reshape(-1)
        pbytes[meta["nbytes"]:] = 0
        payloads.append(payload)
        STATS.bump(bytes_copied=meta["nbytes"])
    if mac_impl is None:
        macs = mac_batch(payloads, seed)
    else:
        macs = [mac_impl(p, seed) for p in payloads]
    if deadlines_us is None:
        deadlines_us = [0] * len(metas)
    if priorities is None:
        priorities = [0] * len(metas)
    for buf, meta, seq, mac, dl, pr in zip(bufs, metas, seqs, macs,
                                           deadlines_us, priorities):
        _write_header(buf[0], meta, seed, seq, mac, dl, pr)
    STATS.bump(frames_sealed=len(arrays), frames_sealed_inplace=len(arrays))
    return rows_list


def seal_prefilled(buf: np.ndarray, nbytes: int, *, seed: int, seq: int,
                   mac_impl=None, deadline_us: int = 0,
                   priority: int = 0) -> int:
    """Seal a frame whose payload bytes the caller ALREADY wrote into
    ``buf``'s payload area (``buf[1:]`` viewed as bytes) — the fully
    zero-copy producer path: an upper layer assembles its message directly
    in a region/arena slot and this only zeroes the pad tail, MACs in
    place and writes the header. The frame is declared as a flat uint8
    payload of ``nbytes`` (the bytes ARE the message). Bit-identical to
    ``seal_into(buf, <those bytes>, ...)``."""
    rows = frame_rows(nbytes)
    _check_buf(buf, rows)
    payload = buf[1:rows]
    pbytes = payload.reshape(-1).view(np.uint8)
    pbytes[nbytes:] = 0
    mac = (mac_impl or _mac_np)(payload, seed)
    meta = {"dtype_code": _DTYPE_CODES[np.dtype(np.uint8)],
            "nbytes": int(nbytes), "shape": (int(nbytes),)}
    _write_header(buf[0], meta, seed, seq, mac, deadline_us, priority)
    STATS.bump(frames_sealed=1, frames_sealed_inplace=1)
    return rows


_U8_CODE = _DTYPE_CODES[np.dtype(np.uint8)]


def _payload_view(frame: np.ndarray, meta: dict) -> np.ndarray:
    """Read-only payload view aliasing ``frame`` storage — zero copy."""
    raw = frame[1:].reshape(-1).view(np.uint8)[: meta["nbytes"]]
    shape = meta["shape"]
    if meta["dtype_code"] == _U8_CODE and len(shape) == 1:
        out = raw                   # flat bytes: raw IS the payload view
    else:
        out = raw.view(_DTYPES[meta["dtype_code"]]).reshape(shape)
    out.flags.writeable = False
    return out


def verify_view(frame: np.ndarray, *, seed: int, expect_seq=None,
                mac_impl=None) -> np.ndarray:
    """Full receive-side guard (magic/seed/seq/reserved/MAC/metadata), then
    return the payload as a **read-only view** aliasing ``frame`` — the
    zero-copy twin of :func:`parse_frame`. The view's lifetime is the
    frame buffer's: callers that outlive the slot (see FrameArena) must
    copy. Mutating the underlying buffer after sealing is caught by the
    MAC; mutating through the view raises (read-only)."""
    frame = np.asarray(frame)
    if frame.ndim != 2 or frame.shape[0] < 1 or frame.shape[1] != LANES:
        raise FrameError("malformed frame — truncated or not lane-aligned")
    if not frame.flags.c_contiguous:
        raise FrameError("verify_view needs a contiguous frame")
    hdr = frame[0].tolist()
    _precheck(frame, seed, expect_seq, hdr)
    mac = (mac_impl or _mac_np)(frame[1:], seed)
    meta = _check_meta(frame, seed, mac, hdr)
    STATS.bump(frames_verified=1, views_returned=1)
    return _payload_view(frame, meta)


class FrameArena:
    """Recycling pool of slot-sized ``(rows, 128)`` uint32 frame buffers.

    The transports stage frames straight into arena slots (``seal_into``)
    and hand responses back as views (``verify_view``), so the steady-state
    data plane allocates nothing: a slot is acquired per message, sealed in
    place, and recycled through a free list when released.

    Slots are size-classed (rows rounded up to the next power of two above
    ``min_rows``) so mixed payload sizes recycle without fragmentation.
    ``release_on_collect(view, buf)`` parks the slot on a *pending* list;
    pending slots re-enter the free list only during a later sweep (at
    ``acquire`` time — a settled state, never mid-deallocation) and only
    once the handed-out view is dead AND nothing else references the
    buffer. numpy collapses view base chains, so a DERIVED sub-view of
    the handed-out view references ``buf`` directly and keeps its
    refcount elevated — the sweep sees that and leaves the slot parked.
    A slot with any live alias is therefore NEVER reused, so recycling
    cannot corrupt data a caller still holds (the aliasing invariant
    tests/test_zero_copy.py locks in). Thread-safe.

    A BACKED arena (``backing=`` a fixed ``(N, 128)`` uint32 array, e.g. a
    view of a ``multiprocessing.shared_memory`` segment) carves its slots
    out of that array with a bump cursor instead of ``np.empty`` — the
    size-class free lists and pending sweep then recycle the carved slices
    exactly like heap slots, so the steady state never advances the
    cursor. Exhausting the backing raises :class:`FrameError` (transports
    surface it as their typed capacity error). ``offset_rows`` maps a
    carved slot back to its row offset inside the backing, which is how a
    process on the other side of a shared segment locates the slot."""

    def __init__(self, min_rows: int = 16, *,
                 backing: Optional[np.ndarray] = None):
        self.min_rows = max(1, min_rows)
        self._free: Dict[int, List[np.ndarray]] = {}
        # (weakref-to-view, buf): swept into _free when view is dead and
        # buf's refcount says nobody else aliases it
        self._pending: List[Tuple[object, np.ndarray]] = []
        self._lock = threading.Lock()
        if backing is not None and (
                backing.ndim != 2 or backing.shape[1] != LANES
                or backing.dtype != np.uint32):
            raise FrameError(
                f"arena backing must be a (rows, {LANES}) uint32 array")
        self._backing = backing
        self._backing_addr = (backing.__array_interface__["data"][0]
                              if backing is not None else 0)
        self._brk = 0                   # rows carved so far (backed mode)
        # id(slot) -> row offset, filled at carve time. Slot objects are
        # kept alive forever by the free/pending/caller chain, so the ids
        # are stable; offset_rows still falls back to address arithmetic
        # for views it has never carved.
        self._carved_off: Dict[int, int] = {}

    def _class_rows(self, rows: int) -> int:
        c = self.min_rows
        while c < rows:
            c <<= 1
        return c

    def _sweep_locked(self) -> None:
        if not self._pending:
            return
        keep = []
        for wr, buf in self._pending:
            if wr() is None \
                    and sys.getrefcount(buf) <= _PENDING_BASELINE_REFS:
                self._free.setdefault(buf.shape[0], []).append(buf)
                STATS.bump(arena_released=1)
            else:
                keep.append((wr, buf))
        self._pending = keep

    def acquire(self, rows: int) -> np.ndarray:
        """A writable (class_rows, 128) uint32 buffer with class_rows ≥
        rows — recycled when the free list has one, freshly allocated
        otherwise. Contents are undefined; seal_into fully initializes the
        frame region."""
        c = self._class_rows(max(1, int(rows)))
        carved = False
        with self._lock:
            self._sweep_locked()
            lst = self._free.get(c)
            buf = lst.pop() if lst else None
            if buf is None and self._backing is not None:
                if self._brk + c > self._backing.shape[0]:
                    raise FrameError(
                        f"backed arena exhausted: need {c} rows, "
                        f"{self._backing.shape[0] - self._brk} of "
                        f"{self._backing.shape[0]} left (slots pinned by "
                        f"live views don't recycle)")
                buf = self._backing[self._brk:self._brk + c]
                self._carved_off[id(buf)] = self._brk
                self._brk += c
                carved = True
        if buf is None:
            buf = np.empty((c, LANES), np.uint32)
            STATS.bump(arena_allocated=1)
        elif carved:
            STATS.bump(arena_allocated=1)
        else:
            STATS.bump(arena_reused=1)
        return buf

    def offset_rows(self, buf: np.ndarray) -> int:
        """Row offset of a carved slot inside the backing array (backed
        arenas only) — the address a peer process uses to find the slot
        in the shared segment."""
        if self._backing is None:
            raise FrameError("offset_rows requires a backed arena")
        off = self._carved_off.get(id(buf))
        if off is not None:
            return off
        span = buf.__array_interface__["data"][0] - self._backing_addr
        off, rem = divmod(span, LANES * 4)
        if rem or off < 0 or off + buf.shape[0] > self._backing.shape[0]:
            raise FrameError("buffer is not a row-aligned slot of this "
                             "arena's backing")
        return int(off)

    def release(self, buf: Optional[np.ndarray]) -> None:
        """Return a slot to its size-class free list. The caller promises no
        live views of ``buf`` remain (use :meth:`release_on_collect` to tie
        the release to a view's lifetime instead)."""
        if buf is None:
            return
        with self._lock:
            self._free.setdefault(buf.shape[0], []).append(buf)
        STATS.bump(arena_released=1)

    def release_on_collect(self, view, buf: np.ndarray) -> None:
        """Recycle ``buf`` once ``view`` has been garbage-collected AND
        nothing else (e.g. a derived sub-view) still aliases it — checked
        by a sweep in a settled state, not a GC callback."""
        with self._lock:
            self._pending.append((weakref.ref(view), buf))

    def free_slots(self) -> int:
        with self._lock:
            self._sweep_locked()
            return sum(len(v) for v in self._free.values())


def _measure_pending_baseline() -> int:
    """Refcount a pending buffer has during the sweep when NOTHING else
    references it (the pending tuple + the loop binding + getrefcount's
    argument) — measured on this interpreter instead of hard-coding
    CPython internals."""
    pending = [(None, np.empty(0, np.uint32))]
    for _, buf in pending:
        return sys.getrefcount(buf)
    raise AssertionError("unreachable")


_PENDING_BASELINE_REFS = _measure_pending_baseline()


# ---------------------------------------------------------------------------
# build / parse (legacy API — now thin wrappers over the in-place path)
# ---------------------------------------------------------------------------

def _build_frame_legacy(arr: np.ndarray, *, seed: int, seq: int,
                        mac_impl=None, deadline_us: int = 0,
                        priority: int = 0) -> np.ndarray:
    """The PR 3 copy pattern (pad concat + header concat), kept only for
    A/B benchmarking (``framing.ZERO_COPY = False``) — byte-identical
    output, 3–4× the copies."""
    arr = np.ascontiguousarray(arr)
    meta = _meta_of(arr)
    raw = arr.view(np.uint8).reshape(-1)
    pad = (-raw.size) % (LANES * 4)
    if pad:
        raw = np.concatenate([raw, np.zeros(pad, np.uint8)])
        STATS.bump(concat_calls=1, bytes_copied=raw.size)
    payload = raw.view("<u4").reshape(-1, LANES)
    mac = (mac_impl or _mac_np)(payload, seed)
    header = np.zeros(LANES, np.uint32)
    _write_header(header, meta, seed, seq, mac, deadline_us, priority)
    STATS.bump(concat_calls=1, frames_sealed=1,
               bytes_copied=payload.nbytes + header.nbytes)
    return np.concatenate([header[None], payload.view(np.uint32)], axis=0)


def build_frame(arr: np.ndarray, *, seed: int, seq: int, mac_impl=None,
                deadline_us: int = 0, priority: int = 0) -> np.ndarray:
    """array → full frame (header row + payload rows) uint32.

    One buffer, one payload write (``seal_into`` into a fresh allocation).
    With ``framing.ZERO_COPY = False`` the PR 3 concat pattern is used
    instead — identical bytes, for benchmark baselines."""
    if not ZERO_COPY:
        return _build_frame_legacy(arr, seed=seed, seq=seq, mac_impl=mac_impl,
                                   deadline_us=deadline_us, priority=priority)
    arr = np.ascontiguousarray(np.asarray(arr))
    meta = _meta_of(arr)
    frame = np.empty((frame_rows(meta["nbytes"]), LANES), np.uint32)
    seal_into(frame, arr, seed=seed, seq=seq, mac_impl=mac_impl,
              deadline_us=deadline_us, priority=priority, _inplace=False)
    return frame


def _precheck(frame: np.ndarray, seed: int, expect_seq,
              _hdr: Optional[list] = None) -> None:
    """The cheap receive-side rejects (no MAC): magic, seed, sequence,
    reserved lanes. Run BEFORE paying for the payload Horner pass so
    garbage/mis-routed frames are turned away after reading header words.
    ``_hdr`` lets a caller that already materialized ``frame[0].tolist()``
    share it (one C call instead of per-word numpy scalar reads)."""
    header = frame[0].tolist() if _hdr is None else _hdr
    if header[0] != MAGIC:
        raise FrameError("bad magic — not an MPKLink frame")
    if header[1] != (seed & 0xFFFFFFFF):
        raise FrameError("seed mismatch — wrong domain key, session or epoch")
    if expect_seq is not None and header[2] != (expect_seq & 0xFFFFFFFF):
        raise FrameError(f"sequence mismatch (got {header[2]}, want {expect_seq})")
    # lanes 10/12 are the (MAC-covered) deadline and priority words,
    # checked by _check_meta; the priority class range is a cheap reject
    if header[PRIORITY_LANE] > _PRIO_MAX:
        raise FrameError("invalid priority class — header tampered")
    if any(header[13:]):
        raise FrameError("nonzero reserved header lanes — header tampered")


def _check_meta(frame: np.ndarray, seed: int, mac: int,
                _hdr: Optional[list] = None) -> dict:
    """The MAC + metadata half of the receive-side checks, given a
    precomputed payload MAC. Callers MUST run :func:`_precheck` first (all
    of parse_frame, verify_view and verify_batch do, before paying for the
    MAC). Shared by every guard so they cannot diverge. Returns the
    validated meta dict."""
    header = frame[0].tolist() if _hdr is None else _hdr
    mixed = _meta_mix_words(header[:11] + [header[PRIORITY_LANE]], seed)
    if (mac ^ mixed) & 0xFFFFFFFF != header[11]:
        raise FrameError("MAC mismatch — payload or header tampered/truncated")
    ndim = header[5]
    nbytes = header[3]
    dtype_code = header[4]
    if dtype_code not in _DTYPES or ndim > 4:
        raise FrameError("invalid header metadata (dtype/ndim)")
    shape = tuple(header[6:6 + ndim])
    itemsize = np.dtype(_DTYPES[dtype_code]).itemsize
    if math.prod(shape) * itemsize != nbytes:
        raise FrameError("invalid header metadata (shape/nbytes disagree)")
    if frame.shape[0] - 1 != frame_rows(nbytes) - 1:
        raise FrameError(
            f"frame length mismatch ({frame.shape[0] - 1} payload rows for "
            f"{nbytes} bytes)")
    return {"dtype_code": dtype_code, "nbytes": nbytes, "shape": shape}


def _verify_with_mac(frame: np.ndarray, seed: int, mac: int) -> np.ndarray:
    meta = _check_meta(frame, seed, mac)
    return unpack_payload(frame[1:], meta)


def parse_frame(frame: np.ndarray, *, seed: int, expect_seq=None, mac_impl=None) -> np.ndarray:
    """Verify magic, seed, seq, header integrity, MAC; return the payload.
    Raises FrameError on any mismatch — this is the receive-side guard.
    Cheap header checks run first so garbage frames never pay for a MAC."""
    frame = np.asarray(frame)
    if frame.ndim != 2 or frame.shape[0] < 1 or frame.shape[1] != LANES:
        raise FrameError("malformed frame — truncated or not lane-aligned")
    hdr = frame[0].tolist()
    _precheck(frame, seed, expect_seq, hdr)
    mac = (mac_impl or _mac_np)(frame[1:], seed)
    STATS.bump(frames_verified=1)
    meta = _check_meta(frame, seed, mac, hdr)
    return unpack_payload(frame[1:], meta)


def frame_rows(nbytes: int) -> int:
    """Total frame rows (header + payload) for an nbytes message."""
    return 1 + (nbytes + LANES * 4 - 1) // (LANES * 4)


def frame_deadline_us(frame: np.ndarray) -> int:
    """The lane-10 deadline word of a frame (0 = no deadline). Only
    meaningful AFTER the frame passed :func:`parse_frame` /
    :func:`verify_view` / :func:`verify_batch` — the word is MAC-covered,
    so a verified frame's deadline cannot have been tampered."""
    return int(np.asarray(frame)[0][DEADLINE_LANE])


def frame_priority(frame: np.ndarray) -> int:
    """The lane-12 priority word of a frame (:data:`PRIO_NORMAL` /
    :data:`PRIO_HIGH` / :data:`PRIO_BULK`). Only meaningful AFTER the frame
    passed :func:`parse_frame` / :func:`verify_view` / :func:`verify_batch`
    — the word is MAC-covered, so a verified frame's class cannot have been
    tampered."""
    return int(np.asarray(frame)[0][PRIORITY_LANE])


def deadline_to_us(remaining_s: Optional[float]) -> int:
    """Encode a remaining budget in seconds as the lane-10 wire word.

    ``None``/non-positive-infinite budgets encode as 0 (no deadline). An
    already-expired budget encodes as 1µs — the smallest nonzero word — so
    the receiver sheds it typed instead of silently dropping the deadline.
    Saturates at :data:`DEADLINE_US_MAX` (~71.6 minutes)."""
    if remaining_s is None:
        return 0
    us = int(remaining_s * 1e6)
    if us <= 0:
        return 1
    return min(us, DEADLINE_US_MAX)


# ---------------------------------------------------------------------------
# batch path: N frames sealed/verified with ONE fused MAC pass
# ---------------------------------------------------------------------------

def _mac_batch_np(stack: np.ndarray, seed: int,
                  block_rows: int = 65536) -> np.ndarray:
    """Vectorized Horner MACs for a (G, rows, LANES) uint32 stack → (G,)
    uint32. One fused pass over the row axis, broadcast across the G frames:
    h = h·P^m + Σ_r row_r·P^(m-1-r) per block, exactly the fast_mac
    recurrence (power tables hoisted via :func:`_power_table32` — they were
    being recomputed per block), in native uint32 (wraparound mod 2^32 is
    the MAC's modulus). Bit-identical to the scalar :func:`_mac_np`."""
    from repro.kernels.ref import MAC_INIT
    g, n = stack.shape[0], stack.shape[1]
    h = np.full((g, LANES), (MAC_INIT + (seed & 0xFFFFFFFF)) & 0xFFFFFFFF,
                np.uint32)
    with np.errstate(over="ignore"):
        for s in range(0, n, block_rows):
            blk = stack[:, s:s + block_rows]
            pw32, p_m32 = _power_table32(blk.shape[1])
            h = h * p_m32 + np.einsum("r,grl->gl", pw32, blk,
                                      dtype=np.uint32, casting="unsafe")
        return (h * _fold_powers_u32()[None, :]).sum(axis=1, dtype=np.uint32)


def _mac_batch_np_legacy(stack: np.ndarray, seed: int,
                         block_rows: int = 65536) -> np.ndarray:
    """The PR 3 fused batch MAC, verbatim (uint64 arithmetic, per-block
    cumprod power recomputation). Bit-identical to :func:`_mac_batch_np`;
    kept ONLY as the measured baseline when ``ZERO_COPY=False``."""
    from repro.kernels.ref import MAC_PRIME, MAC_INIT, _FOLD_POWERS
    g, n = stack.shape[0], stack.shape[1]
    h = np.full((g, LANES), MAC_INIT, np.uint64) + np.uint64(seed & 0xFFFFFFFF)
    h &= np.uint64(0xFFFFFFFF)
    with np.errstate(over="ignore"):
        for s in range(0, n, block_rows):
            blk = stack[:, s:s + block_rows].astype(np.uint64)
            m = blk.shape[1]
            pw = np.full(m, MAC_PRIME, np.uint64)
            pw[0] = 1
            pw = np.cumprod(pw)[::-1]
            p_m = np.uint64((int(pw[0]) * MAC_PRIME) & 0xFFFFFFFFFFFFFFFF)
            h = (h * p_m + (blk * pw[None, :, None]).sum(axis=1,
                                                         dtype=np.uint64)) \
                & np.uint64(0xFFFFFFFF)
        return ((h * _FOLD_POWERS.astype(np.uint64)[None, :])
                .sum(axis=1, dtype=np.uint64) & np.uint64(0xFFFFFFFF)) \
            .astype(np.uint32)


def mac_batch(payloads: Sequence[np.ndarray], seed: int) -> List[int]:
    """Payload MACs for N (rows, LANES) uint32 matrices, vectorized.

    Frames are grouped by row count and each group is hashed in one fused
    pass (:func:`_mac_batch_np`) — the host twin of the batched
    ``kernels/mpk_guard`` kernel. A singleton group is passed as a
    broadcast view (no stacking copy), so a single large payload is MAC'd
    strictly in place. Bit-identical to calling :func:`_mac_np` per
    payload (tests/test_batching.py asserts it). With ``ZERO_COPY=False``
    the PR 3 fused pass is used instead — same bits, the A/B baseline."""
    fused = _mac_batch_np if ZERO_COPY else _mac_batch_np_legacy
    out: List[Optional[int]] = [None] * len(payloads)
    groups: dict = {}
    for i, p in enumerate(payloads):
        groups.setdefault(p.shape[0], []).append(i)
    for rows, idx in groups.items():
        if rows == 0:
            for i in idx:
                out[i] = _mac_np(payloads[i], seed)
            continue
        if len(idx) == 1:
            stack = np.asarray(payloads[idx[0]])[None]      # view, no copy
        else:
            stack = np.stack([np.asarray(payloads[i]) for i in idx])
        macs = fused(stack, seed)
        for j, i in enumerate(idx):
            out[i] = int(macs[j])
    return out


def seal_batch(arrays: Sequence[np.ndarray], *, seed: int,
               start_seq: Optional[int] = None,
               seqs: Optional[Sequence[int]] = None, mac_impl=None,
               priorities: Optional[Sequence[int]] = None
               ) -> List[np.ndarray]:
    """Frame N messages, MAC'ing all payloads in one vectorized pass.

    Sequence numbers come from ``start_seq`` (consecutive:
    ``start_seq..start_seq+N-1``) or an explicit ``seqs`` list (the
    transport ring uses this to seal responses whose request seqs have gaps
    from failed items). Equivalent to ``[build_frame(a, seed=seed, seq=...)
    for a in arrays]`` but without N scalar MAC loops. ``mac_impl`` forces a
    per-frame scalar impl (tests use it to cross-check the batched path)."""
    if seqs is None:
        if start_seq is None:
            raise ValueError("seal_batch needs start_seq or seqs")
        seqs = [start_seq + i for i in range(len(arrays))]
    packed = [pack_payload(np.asarray(a)) for a in arrays]
    if mac_impl is None:
        macs = mac_batch([p for p, _ in packed], seed)
    else:
        macs = [mac_impl(p, seed) for p, _ in packed]
    if priorities is None:
        priorities = [0] * len(packed)
    STATS.bump(frames_sealed=len(packed))
    return [_assemble(p, meta, seed, seqs[i], macs[i], 0, priorities[i])
            for i, (p, meta) in enumerate(packed)]


def verify_batch(frames: Sequence[np.ndarray], *, seed: int,
                 seqs: Optional[Sequence[int]] = None,
                 start_seq: Optional[int] = None, mac_impl=None,
                 strict: bool = True) -> List[Union[np.ndarray, FrameError]]:
    """Receive-side guard for N frames with one vectorized MAC pass.

    ``seqs`` (or ``start_seq`` for consecutive numbering; neither skips the
    sequence check) gives the expected sequence per frame. With
    ``strict=True`` the first bad frame raises ``FrameError`` (message
    prefixed with its batch index); with ``strict=False`` the returned list
    carries the ``FrameError`` *object* in that frame's position so a batch
    can drain partially — the transport-ring and gateway-batch paths use
    this to keep per-message typed errors."""
    frames = [np.asarray(f) for f in frames]
    if seqs is None and start_seq is not None:
        seqs = [start_seq + i for i in range(len(frames))]
    out: List[Union[np.ndarray, FrameError]] = [None] * len(frames)
    # cheap rejects first (shape/magic/seed/seq/reserved) — only survivors
    # pay for the fused MAC pass
    candidates: List[int] = []
    for i, f in enumerate(frames):
        try:
            if f.ndim != 2 or f.shape[0] < 1 or f.shape[1] != LANES:
                raise FrameError(
                    "malformed frame — truncated or not lane-aligned")
            _precheck(f, seed, None if seqs is None else seqs[i])
            candidates.append(i)
        except FrameError as e:
            if strict:
                raise FrameError(f"frame {i}: {e}") from None
            out[i] = e
    if mac_impl is None:
        macs = mac_batch([frames[i][1:] for i in candidates], seed)
    else:
        macs = [mac_impl(frames[i][1:], seed) for i in candidates]
    STATS.bump(frames_verified=len(candidates))
    for i, mac in zip(candidates, macs):
        try:
            out[i] = _verify_with_mac(frames[i], seed, mac)
        except FrameError as e:
            if strict:
                raise FrameError(f"frame {i}: {e}") from None
            out[i] = e
    return out


def split_frames(flat_u32: np.ndarray, max_frames: int = 4096) -> List[np.ndarray]:
    """Carve a row-concatenation of frames back into individual frames.

    Each frame declares its own length (header ``nbytes`` → frame_rows), so
    the walk needs no out-of-band index. The declared length is only trusted
    for *splitting*; it is re-checked against the MAC during verify. A
    corrupted length desyncs the walk and raises ``FrameError`` for the
    whole concatenation — bounded, typed, never out-of-range reads."""
    flat_u32 = np.asarray(flat_u32)
    if flat_u32.ndim != 2 or flat_u32.shape[1] != LANES:
        raise FrameError("malformed frame concatenation — not lane-aligned")
    frames: List[np.ndarray] = []
    row = 0
    while row < flat_u32.shape[0]:
        if len(frames) >= max_frames:
            raise FrameError(f"more than {max_frames} frames in one batch")
        header = flat_u32[row]
        if int(header[0]) != MAGIC:
            raise FrameError(
                f"bad magic at row {row} — frame walk desynced (corrupted "
                f"length in an earlier header?)")
        rows = frame_rows(int(header[3]))
        if row + rows > flat_u32.shape[0]:
            raise FrameError(
                f"frame at row {row} declares {rows} rows but only "
                f"{flat_u32.shape[0] - row} remain")
        frames.append(flat_u32[row: row + rows])
        row += rows
    return frames
