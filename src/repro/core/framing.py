"""Message framing for MPKLink channels.

A frame is a uint32 matrix of 128 lanes (the TPU-native layout the guard
kernel consumes):

  row 0   — header: [MAGIC, seed, seq, nbytes, dtype_code, ndim,
                     shape[0..3], 0, mac^meta_mix, 0...]
  rows 1+ — payload: raw bytes viewed as little-endian uint32, zero-padded
            to a whole number of 128-lane rows.

The MAC in the header is the Horner hash of the payload rows seeded with
``seed = domain.tag ⊕ epoch-mix ⊕ session`` (see domains.mac_seed and
ca.session_seed) — so a frame is only verifiable by a peer holding the same
domain key *and* session identity, at the current epoch. That single uint32
check is where MPK access control and the paper's per-message signature
collapse into one fused operation on-device.

Header integrity: the stored word is ``payload_mac ⊕ _meta_mix(header)``, a
Horner mix of the ten metadata words — so flipping any header bit (dtype,
shape, nbytes, ...) fails verification exactly like a payload flip, and the
reserved lanes (10, 12..127) must be zero. The payload MAC itself is
unchanged and stays bit-identical to the guard kernel / fast_mac.

Batch path (the pipelined data plane): :func:`seal_batch` /
:func:`verify_batch` frame / verify N messages at once, with all N payload
MACs computed in ONE fused vectorized pass (:func:`mac_batch`) instead of N
Python-loop calls — same constants, bit-identical to the scalar MAC (and to
the batched ``kernels/mpk_guard`` device kernel). :func:`split_frames`
separates concatenated frames back into messages, which is how the gateway's
batch envelope is carved up server-side.

Works on both numpy (host transports) and jnp (device fabric) arrays.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

MAGIC = 0x4D504B4C            # "MPKL"
LANES = 128

_DTYPES = {0: np.float32, 1: np.int32, 2: np.uint32, 3: np.uint8,
           4: np.dtype("<f8"), 5: np.int64, 6: np.uint16}
_DTYPE_CODES = {np.dtype(v): k for k, v in _DTYPES.items()}


class FrameError(ValueError):
    pass


def _mac_np(payload_u32: np.ndarray, seed: int) -> int:
    """Host twin of kernels.ref.mac_ref (same constants, same fold)."""
    from repro.kernels.ref import MAC_PRIME, MAC_INIT, _FOLD_POWERS
    h = np.full(LANES, MAC_INIT, np.uint64)
    h = (h + np.uint64(seed & 0xFFFFFFFF)) & 0xFFFFFFFF
    for row in payload_u32:
        h = (h * MAC_PRIME + row.astype(np.uint64)) & 0xFFFFFFFF
    return int((h * _FOLD_POWERS.astype(np.uint64)).sum() & 0xFFFFFFFF)


def _meta_mix(header: np.ndarray, seed: int) -> int:
    """Horner mix of the ten metadata words (magic..shape[3]) — folded into
    the stored MAC word so header tampering fails exactly like payload
    tampering. Pure uint arithmetic, deterministic everywhere."""
    from repro.kernels.ref import MAC_PRIME
    h = (0x9E3779B9 ^ (seed & 0xFFFFFFFF)) & 0xFFFFFFFF
    for w in header[:10]:
        h = (h * MAC_PRIME + int(w)) & 0xFFFFFFFF
    return h


def pack_payload(arr: np.ndarray) -> Tuple[np.ndarray, dict]:
    """array → ((rows, 128) uint32, meta). Zero-pads to lane multiples."""
    arr = np.ascontiguousarray(arr)
    if arr.dtype not in _DTYPE_CODES:
        raise FrameError(f"unsupported dtype {arr.dtype}")
    raw = arr.view(np.uint8).reshape(-1)
    pad = (-raw.size) % (LANES * 4)
    if pad:
        raw = np.concatenate([raw, np.zeros(pad, np.uint8)])
    u32 = raw.view("<u4").reshape(-1, LANES)
    meta = {"dtype_code": _DTYPE_CODES[arr.dtype], "nbytes": arr.nbytes,
            "shape": tuple(arr.shape)}
    return u32, meta


def unpack_payload(payload_u32: np.ndarray, meta: dict) -> np.ndarray:
    raw = np.ascontiguousarray(payload_u32).view(np.uint8).reshape(-1)
    raw = raw[: meta["nbytes"]]
    return raw.view(_DTYPES[meta["dtype_code"]]).reshape(meta["shape"])


def _assemble(payload: np.ndarray, meta: dict, seed: int, seq: int,
              mac: int) -> np.ndarray:
    """Header row from (meta, seed, seq, precomputed payload MAC) + payload."""
    shape = list(meta["shape"])[:4] + [0] * (4 - min(4, len(meta["shape"])))
    if len(meta["shape"]) > 4:
        raise FrameError("rank > 4 payloads unsupported by frame header")
    header = np.zeros(LANES, np.uint32)
    header[:10] = [MAGIC, seed & 0xFFFFFFFF, seq & 0xFFFFFFFF,
                   meta["nbytes"] & 0xFFFFFFFF, meta["dtype_code"],
                   len(meta["shape"]), *[s & 0xFFFFFFFF for s in shape]]
    header[11] = (mac ^ _meta_mix(header, seed)) & 0xFFFFFFFF
    return np.concatenate([header[None], payload], axis=0)


def build_frame(arr: np.ndarray, *, seed: int, seq: int, mac_impl=None) -> np.ndarray:
    """array → full frame (header row + payload rows) uint32."""
    payload, meta = pack_payload(arr)
    mac = (mac_impl or _mac_np)(payload, seed)
    return _assemble(payload, meta, seed, seq, mac)


def _precheck(frame: np.ndarray, seed: int, expect_seq) -> None:
    """The cheap receive-side rejects (no MAC): magic, seed, sequence,
    reserved lanes. Run BEFORE paying for the payload Horner pass so
    garbage/mis-routed frames are turned away after reading header words."""
    header = frame[0]
    if int(header[0]) != MAGIC:
        raise FrameError("bad magic — not an MPKLink frame")
    if int(header[1]) != (seed & 0xFFFFFFFF):
        raise FrameError("seed mismatch — wrong domain key, session or epoch")
    if expect_seq is not None and int(header[2]) != (expect_seq & 0xFFFFFFFF):
        raise FrameError(f"sequence mismatch (got {int(header[2])}, want {expect_seq})")
    if int(header[10]) != 0 or np.any(np.asarray(header[12:]) != 0):
        raise FrameError("nonzero reserved header lanes — header tampered")


def _verify_with_mac(frame: np.ndarray, seed: int, mac: int) -> np.ndarray:
    """The MAC + metadata half of the receive-side checks, given a
    precomputed payload MAC. Callers MUST run :func:`_precheck` first (both
    parse_frame and verify_batch do, before paying for the MAC). Shared by
    the scalar and batch guards so they cannot diverge."""
    header, payload = frame[0], frame[1:]
    if (mac ^ _meta_mix(header, seed)) & 0xFFFFFFFF != int(header[11]):
        raise FrameError("MAC mismatch — payload or header tampered/truncated")
    ndim = int(header[5])
    nbytes = int(header[3])
    dtype_code = int(header[4])
    if dtype_code not in _DTYPES or ndim > 4:
        raise FrameError("invalid header metadata (dtype/ndim)")
    shape = tuple(int(s) for s in header[6:6 + ndim])
    itemsize = np.dtype(_DTYPES[dtype_code]).itemsize
    if int(np.prod(shape, dtype=np.int64)) * itemsize != nbytes:
        raise FrameError("invalid header metadata (shape/nbytes disagree)")
    if payload.shape[0] != frame_rows(nbytes) - 1:
        raise FrameError(
            f"frame length mismatch ({payload.shape[0]} payload rows for "
            f"{nbytes} bytes)")
    meta = {"dtype_code": dtype_code, "nbytes": nbytes, "shape": shape}
    return unpack_payload(payload, meta)


def parse_frame(frame: np.ndarray, *, seed: int, expect_seq=None, mac_impl=None) -> np.ndarray:
    """Verify magic, seed, seq, header integrity, MAC; return the payload.
    Raises FrameError on any mismatch — this is the receive-side guard.
    Cheap header checks run first so garbage frames never pay for a MAC."""
    frame = np.asarray(frame)
    if frame.ndim != 2 or frame.shape[0] < 1 or frame.shape[1] != LANES:
        raise FrameError("malformed frame — truncated or not lane-aligned")
    _precheck(frame, seed, expect_seq)
    mac = (mac_impl or _mac_np)(frame[1:], seed)
    return _verify_with_mac(frame, seed, mac)


def frame_rows(nbytes: int) -> int:
    """Total frame rows (header + payload) for an nbytes message."""
    return 1 + (nbytes + LANES * 4 - 1) // (LANES * 4)


# ---------------------------------------------------------------------------
# batch path: N frames sealed/verified with ONE fused MAC pass
# ---------------------------------------------------------------------------

def _mac_batch_np(stack: np.ndarray, seed: int,
                  block_rows: int = 65536) -> np.ndarray:
    """Vectorized Horner MACs for a (G, rows, LANES) uint32 stack → (G,)
    uint32. One fused pass over the row axis, broadcast across the G frames:
    h = h·P^m + Σ_r row_r·P^(m-1-r) per block, exactly the fast_mac
    recurrence. uint64 wraparound keeps the low 32 bits exact (2^32 | 2^64),
    so the result is bit-identical to the scalar :func:`_mac_np`."""
    from repro.kernels.ref import MAC_PRIME, MAC_INIT, _FOLD_POWERS
    g, n = stack.shape[0], stack.shape[1]
    h = np.full((g, LANES), MAC_INIT, np.uint64) + np.uint64(seed & 0xFFFFFFFF)
    h &= np.uint64(0xFFFFFFFF)
    with np.errstate(over="ignore"):
        for s in range(0, n, block_rows):
            blk = stack[:, s:s + block_rows].astype(np.uint64)
            m = blk.shape[1]
            pw = np.full(m, MAC_PRIME, np.uint64)       # [P^(m-1), ..., P, 1]
            pw[0] = 1
            pw = np.cumprod(pw)[::-1]
            p_m = np.uint64((int(pw[0]) * MAC_PRIME) & 0xFFFFFFFFFFFFFFFF)
            h = (h * p_m + (blk * pw[None, :, None]).sum(axis=1,
                                                         dtype=np.uint64)) \
                & np.uint64(0xFFFFFFFF)
        return ((h * _FOLD_POWERS.astype(np.uint64)[None, :])
                .sum(axis=1, dtype=np.uint64) & np.uint64(0xFFFFFFFF)) \
            .astype(np.uint32)


def mac_batch(payloads: Sequence[np.ndarray], seed: int) -> List[int]:
    """Payload MACs for N (rows, LANES) uint32 matrices, vectorized.

    Frames are grouped by row count and each group is hashed in one fused
    pass (:func:`_mac_batch_np`) — the host twin of the batched
    ``kernels/mpk_guard`` kernel. Bit-identical to calling :func:`_mac_np`
    per payload (tests/test_batching.py asserts it)."""
    out: List[Optional[int]] = [None] * len(payloads)
    groups: dict = {}
    for i, p in enumerate(payloads):
        groups.setdefault(p.shape[0], []).append(i)
    for rows, idx in groups.items():
        if rows == 0:
            for i in idx:
                out[i] = _mac_np(payloads[i], seed)
            continue
        stack = np.stack([np.asarray(payloads[i]) for i in idx])
        macs = _mac_batch_np(stack, seed)
        for j, i in enumerate(idx):
            out[i] = int(macs[j])
    return out


def seal_batch(arrays: Sequence[np.ndarray], *, seed: int,
               start_seq: Optional[int] = None,
               seqs: Optional[Sequence[int]] = None,
               mac_impl=None) -> List[np.ndarray]:
    """Frame N messages, MAC'ing all payloads in one vectorized pass.

    Sequence numbers come from ``start_seq`` (consecutive:
    ``start_seq..start_seq+N-1``) or an explicit ``seqs`` list (the
    transport ring uses this to seal responses whose request seqs have gaps
    from failed items). Equivalent to ``[build_frame(a, seed=seed, seq=...)
    for a in arrays]`` but without N scalar MAC loops. ``mac_impl`` forces a
    per-frame scalar impl (tests use it to cross-check the batched path)."""
    if seqs is None:
        if start_seq is None:
            raise ValueError("seal_batch needs start_seq or seqs")
        seqs = [start_seq + i for i in range(len(arrays))]
    packed = [pack_payload(np.asarray(a)) for a in arrays]
    if mac_impl is None:
        macs = mac_batch([p for p, _ in packed], seed)
    else:
        macs = [mac_impl(p, seed) for p, _ in packed]
    return [_assemble(p, meta, seed, seqs[i], macs[i])
            for i, (p, meta) in enumerate(packed)]


def verify_batch(frames: Sequence[np.ndarray], *, seed: int,
                 seqs: Optional[Sequence[int]] = None,
                 start_seq: Optional[int] = None, mac_impl=None,
                 strict: bool = True) -> List[Union[np.ndarray, FrameError]]:
    """Receive-side guard for N frames with one vectorized MAC pass.

    ``seqs`` (or ``start_seq`` for consecutive numbering; neither skips the
    sequence check) gives the expected sequence per frame. With
    ``strict=True`` the first bad frame raises ``FrameError`` (message
    prefixed with its batch index); with ``strict=False`` the returned list
    carries the ``FrameError`` *object* in that frame's position so a batch
    can drain partially — the transport-ring and gateway-batch paths use
    this to keep per-message typed errors."""
    frames = [np.asarray(f) for f in frames]
    if seqs is None and start_seq is not None:
        seqs = [start_seq + i for i in range(len(frames))]
    out: List[Union[np.ndarray, FrameError]] = [None] * len(frames)
    # cheap rejects first (shape/magic/seed/seq/reserved) — only survivors
    # pay for the fused MAC pass
    candidates: List[int] = []
    for i, f in enumerate(frames):
        try:
            if f.ndim != 2 or f.shape[0] < 1 or f.shape[1] != LANES:
                raise FrameError(
                    "malformed frame — truncated or not lane-aligned")
            _precheck(f, seed, None if seqs is None else seqs[i])
            candidates.append(i)
        except FrameError as e:
            if strict:
                raise FrameError(f"frame {i}: {e}") from None
            out[i] = e
    if mac_impl is None:
        macs = mac_batch([frames[i][1:] for i in candidates], seed)
    else:
        macs = [mac_impl(frames[i][1:], seed) for i in candidates]
    for i, mac in zip(candidates, macs):
        try:
            out[i] = _verify_with_mac(frames[i], seed, mac)
        except FrameError as e:
            if strict:
                raise FrameError(f"frame {i}: {e}") from None
            out[i] = e
    return out


def split_frames(flat_u32: np.ndarray, max_frames: int = 4096) -> List[np.ndarray]:
    """Carve a row-concatenation of frames back into individual frames.

    Each frame declares its own length (header ``nbytes`` → frame_rows), so
    the walk needs no out-of-band index. The declared length is only trusted
    for *splitting*; it is re-checked against the MAC during verify. A
    corrupted length desyncs the walk and raises ``FrameError`` for the
    whole concatenation — bounded, typed, never out-of-range reads."""
    flat_u32 = np.asarray(flat_u32)
    if flat_u32.ndim != 2 or flat_u32.shape[1] != LANES:
        raise FrameError("malformed frame concatenation — not lane-aligned")
    frames: List[np.ndarray] = []
    row = 0
    while row < flat_u32.shape[0]:
        if len(frames) >= max_frames:
            raise FrameError(f"more than {max_frames} frames in one batch")
        header = flat_u32[row]
        if int(header[0]) != MAGIC:
            raise FrameError(
                f"bad magic at row {row} — frame walk desynced (corrupted "
                f"length in an earlier header?)")
        rows = frame_rows(int(header[3]))
        if row + rows > flat_u32.shape[0]:
            raise FrameError(
                f"frame at row {row} declares {rows} rows but only "
                f"{flat_u32.shape[0] - row} remain")
        frames.append(flat_u32[row: row + rows])
        row += rows
    return frames
