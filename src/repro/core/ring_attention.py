"""Ring attention — sequence/context parallelism over MPKLink channels.

Q, K, V are sharded along the SEQUENCE dim across the channel's mesh axis.
Each of the n ring steps computes a local flash partial (out, lse) for the
resident KV block, then rotates the KV block (and its positions) to the
next neighbor through the guarded channel — after n steps every Q shard has
attended to the full sequence while only ever holding 1/n of K/V.

This is the paper's pattern at pod scale: instead of the compiler's global
all-gather of K/V ("the network stack"), n-1 explicit neighbor pushes
through a pre-established protected channel move exactly the bytes the
algorithm needs. It is also the escape hatch for attention shapes TP can't
shard (non-divisible head counts — smollm/whisper): shard the sequence
instead of heads.

Forward-only (serving/prefill); partials merge by the standard logsumexp
rule. Validated against the full-attention oracle on an 8-device mesh
(tests/test_ring_attention.py).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.fabric import FabricChannel, MPKLinkFabric, neighbor_exchange
from repro.core.domains import DomainKey
from repro.kernels.flash_jnp import _fwd_core, _pad_to
from repro.kernels.ref import NEG_INF
from repro.utils import axis_size, match_vma


def _merge(out1, lse1, out2, lse2):
    """Combine two attention partials over the same queries."""
    m = jnp.maximum(lse1, lse2)
    m_safe = jnp.maximum(m, NEG_INF / 2)
    w1 = jnp.exp(lse1 - m_safe)
    w2 = jnp.exp(lse2 - m_safe)
    denom = jnp.maximum(w1 + w2, 1e-30)
    out = (out1 * w1[..., None] + out2 * w2[..., None]) / denom[..., None]
    lse = jnp.where(m > NEG_INF / 2, m_safe + jnp.log(denom), NEG_INF)
    return out, lse


def ring_attention(fabric: MPKLinkFabric, chan: FabricChannel, key: DomainKey,
                   q, k, v, q_pos, kv_pos, *, causal: bool = True,
                   window: Optional[int] = None, q_chunk: int = 128,
                   kv_chunk: int = 128):
    """Call inside shard_map with q/k/v sequence-sharded over chan.axis.

    q (B, Sq_loc, H, Dh); k/v (B, Skv_loc, Hkv, Dh); positions (B, S*_loc)
    hold ABSOLUTE positions (so causal/window masks stay exact across
    blocks). → (out (B, Sq_loc, H, Dh), ok flag)."""
    fabric.check(chan, key)
    n = axis_size(chan.axis)
    B, Sq, H, Dh = q.shape

    qc = min(q_chunk, Sq)
    kc = min(kv_chunk, k.shape[1])
    qp = _pad_to(q_pos.astype(jnp.int32), 1, qc, -2)
    qpad = _pad_to(q, 1, qc, 0)

    def local_partial(kb, vb, kpb):
        kp = _pad_to(kpb.astype(jnp.int32), 1, kc, -1)
        out, lse = _fwd_core(qpad, _pad_to(kb, 1, kc, 0), _pad_to(vb, 1, kc, 0),
                             qp, kp, causal, window, qc, kc)
        return out, lse

    out, lse = local_partial(k, v, kv_pos)

    def step(carry, _):
        out, lse, kb, vb, kpb, ok = carry
        kb, ok1 = neighbor_exchange(fabric, chan, key, kb, shift=1)
        vb, ok2 = neighbor_exchange(fabric, chan, key, vb, shift=1)
        kpb, ok3 = neighbor_exchange(fabric, chan, key, kpb, shift=1)
        o2, l2 = local_partial(kb, vb, kpb)
        out, lse = _merge(out, lse, o2, l2)
        return (out, lse, kb, vb, kpb, ok & ok1 & ok2 & ok3), None

    init = (out, lse, k, v, kv_pos.astype(jnp.int32),
            match_vma(jnp.int32(1), q))
    (out, lse, _, _, _, ok), _ = jax.lax.scan(step, init, None, length=n - 1)
    out = out[:, :Sq].astype(q.dtype)
    out = jnp.where(q_pos[:, :, None, None] < 0, 0, out)
    return out, ok
