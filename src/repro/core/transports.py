"""The paper's IPC transport zoo, reproduced measurably on CPU (§VI).

Microservices run as threads of one master process (exactly the paper's
final design — their separate-process attempt segfaulted, §VI) and exchange
request/response messages through one of six transports:

  pipe        two unidirectional OS pipes (the named-pipe setup of §VI;
              anonymous pipes share the same kernel FIFO path, minus the
              filesystem name)
  uds         one bidirectional AF_UNIX stream socket pair
  shm         two raw shared-memory regions (req/resp) with metadata
              signalling and a FIXED capacity — faithfully fails for large
              payloads like the paper's baseline (incapable ≥100k words)
  grpc_sim    the REST/gRPC stand-in: msgpack serialization (protobuf
              analogue) + HTTP/2-style 9-byte frame headers per 16 KiB DATA
              frame + a 64 KiB flow-control window with WINDOW_UPDATE acks
  mpklink     shared memory region + MPK emulation: per-chunk PKRU
              synchronization ping-pong between the threads (the paper's
              key-sync overhead — the large-payload cliff), domain-seeded
              MAC over the message, CA-verified endpoints
  mpklink_opt beyond-paper: ONE key sync per message (batched epoch),
              vectorized MAC — the cliff removed (EXPERIMENTS.md §Perf)

Concurrency model (this file's post-seed refactor): every transport now
serves **N concurrent client sessions**. ``transport.connect()`` returns a
:class:`Session` with its own channel (own fds / socketpair / regions) and a
dedicated service thread, so independent clients never share a wire. The
mpklink variants give each session its own CA enrollment, protection domain,
capability keys and per-session MAC seed + framing sequence — the paper's
per-endpoint isolation, finally exercised with more than one client.
``transport.request()`` keeps the old single-client API by lazily opening a
default session.

Pipelined data plane (this file's batching refactor): every session also
speaks a **ring of message slots** — ``submit()`` stages a request into the
next free slot and returns a ticket, ``flush()`` publishes all staged slots
to the service in one step, ``poll(ticket)`` redeems a response, and
``call_batch(payloads)`` runs the whole submit→flush→poll cycle for N
messages. The shm/mpklink/mpklink_opt sessions back this with a real
fixed-capacity slot ring (head/tail under one guarded control point), so a
client keeps up to ``ring_slots`` requests in flight and the service drains
them without per-message key-sync round-trips: one PKRU sync covers every
frame published by a flush (chunk-scaled for paper-faithful mpklink), one
more covers every response in a drain pass, and the MACs of a drained batch
are verified/sealed in one vectorized pass (framing.verify_batch/
seal_batch). Stream transports (pipe/uds/grpc_sim) keep the same API
through a lockstep fallback so callers never special-case.

Zero-copy data plane (this file's arena refactor): each transport owns a
shared :class:`framing.FrameArena`; the shm/mpklink/mpklink_opt sessions
stage messages straight into recycled arena slots (submit seals in place
— one payload write), hand responses back as read-only views whose slots
recycle only after the view dies, and seal lockstep frames directly into
the shared regions (``request_into`` even lets the caller assemble its
message inside the region). ``framing.ZERO_COPY = False`` restores the
PR 3 copy pattern for A/B benchmarking — bit-identical frames either way.

Doorbell data plane (this file's coalescing refactor): all shm/mpklink
signalling now goes through :class:`Doorbell` — a hybrid spin/park wakeup
(bounded predicate spin, then park on a condition) where ONE ring covers
every waiter: a flush wakes the service once however many slots it
published, and a drain pass wakes every poller of the pass with one ring.
Rings/parks are counted in ``framing.STATS`` (``wakeups`` /
``doorbell_parks``; ``key_syncs`` aggregates the PKRU sync counts), so
benchmarks report wakeups-per-request. Rings also carry credit-based flow
control: ``submit()`` against a full ring blocks up to
``transport.credit_wait`` for a slot credit (granted when a concurrent
``poll()`` frees a slot) before raising the typed ``CapacityError``, and
``poll``/``request`` accept a per-call ``timeout`` tighter than the
transport deadline.

Failure model: handler exceptions and capacity overflows are propagated to
the *calling* client as typed exceptions (never swallowed in the service
thread), and blocking-wait transports (shm, mpklink) bound their response
waits with ``timeout`` so no transport can deadlock the process. Ring
slots carry the same typed errors per ticket: a failed message surfaces on
ITS poll() while the rest of the batch drains normally.

Adaptation notes (single-core container):
  * the paper polls shared metadata; busy-spin on one core inverts results,
    so signalling uses threading.Event — the *count* of synchronization
    round-trips per message is preserved exactly, which is what produces
    the paper's scaling behaviour;
  * thread-based + anonymous buffers mirrors the paper's single-process
    mmap design.
"""
from __future__ import annotations

import itertools
import os
import select
import socket
import struct
import threading
import time
from typing import Callable, Dict, List, Optional

import msgpack
import numpy as np

from repro.core import framing
from repro.core.ca import CertificateAuthority, enroll
from repro.core.domains import (AccessViolation, KeyRegistry, READ, WRITE,
                                RW, mac_seed)
Handler = Callable[[np.ndarray], np.ndarray]


class TransportError(RuntimeError):
    pass


class CapacityError(TransportError):
    """Raised when a fixed-capacity transport cannot hold the payload."""


class ResponseTimeout(TransportError):
    """The client-side response wait expired (the service may still be
    alive — e.g. a dropped/late response). The session poisons itself."""


class DeadlineExpired(ResponseTimeout):
    """The request's propagated deadline (the lane-10 budget word — see
    docs/protocol.md §9) expired before the work could run: the request was
    shed *before* execution, or stood down while queued. Subclasses
    :class:`ResponseTimeout` so existing typed-error nets treat it as a
    timeout, but retrying is pointless — the caller's budget is spent, so
    retry layers re-raise instead of healing. Never poisons a session (the
    wire exchange itself completed)."""


class ServiceCrashed(TransportError):
    """The service handler/thread died while a request was in flight —
    distinguished from :class:`ResponseTimeout` so retry layers fail over
    immediately instead of waiting out the full deadline on a dead peer."""


class ServiceUnavailable(TransportError):
    """A circuit breaker is shedding load for this service — the request
    was rejected up-front instead of being allowed to hang."""


class Overloaded(ServiceUnavailable):
    """Brownout admission shed: the service crossed its overload high-water
    mark (inflight depth × EWMA service time), so new admissions are turned
    away typed instead of queueing into timeout collapse. Carries a
    ``retry_after`` hint in seconds (an estimate of when the backlog
    drains); a well-behaved client backs off at least that long before
    retrying. Subclasses :class:`ServiceUnavailable` so existing shed
    accounting and retry nets apply unchanged."""

    def __init__(self, msg: str = "service overloaded",
                 retry_after: float = 0.0):
        super().__init__(msg)
        self.retry_after = float(retry_after)


class RateLimited(Overloaded):
    """Per-identity token-bucket admission shed (docs/protocol.md §10): the
    calling CA identity exceeded its configured ``rate``/``burst`` envelope,
    so the request was turned away BEFORE it charged any service capacity
    (brownout in-flight weight, shard queues, replica slots — no
    double-penalty). Carries the bucket's ``retry_after`` hint: the seconds
    until the identity's bucket refills enough to admit one request.
    Subclasses :class:`Overloaded` so back-off nets apply unchanged, but is
    distinguishable — a rate-limit shed is the CALLER's doing, not the
    service's, and no amount of failover heals it."""

    def __init__(self, msg: str = "identity rate limited",
                 retry_after: float = 0.0):
        super().__init__(msg, retry_after=retry_after)


class HandlerCrash(BaseException):
    """Fault-injection signal: a handler failure that KILLS the service
    thread instead of being propagated as a normal error response (a
    BaseException so the per-request ``except Exception`` nets don't absorb
    it). The session's crash path converts it into a typed
    :class:`ServiceCrashed` for the waiting client."""


class DropResponse(BaseException):
    """Fault-injection signal: execute the handler but never send the
    response — the client's bounded wait must expire. The service thread
    itself keeps serving (the wire dropped the frame, the peer is alive)."""


# exception types a service thread may propagate back to its client by name
_REMOTE_ERRORS: Dict[str, type] = {
    "CapacityError": CapacityError,
    "TransportError": TransportError,
    "ResponseTimeout": ResponseTimeout,
    "DeadlineExpired": DeadlineExpired,
    "ServiceCrashed": ServiceCrashed,
    "ServiceUnavailable": ServiceUnavailable,
    "Overloaded": Overloaded,
    "RateLimited": RateLimited,
    "AccessViolation": AccessViolation,
    "FrameError": framing.FrameError,
}


def _pack_error(exc: BaseException) -> bytes:
    info = {"type": type(exc).__name__, "msg": str(exc)}
    retry_after = getattr(exc, "retry_after", None)
    if retry_after is not None:
        info["retry_after"] = float(retry_after)
    return msgpack.packb(info, use_bin_type=True)


def _raise_remote(blob: bytes):
    info = msgpack.unpackb(bytes(blob), raw=False)
    cls = _REMOTE_ERRORS.get(info.get("type", ""), TransportError)
    if issubclass(cls, Overloaded):
        # the whole Overloaded family carries retry_after — reconstruct it
        # so subclasses (RateLimited) keep their hint across the wire
        raise cls(info.get("msg", "remote service error"),
                  retry_after=info.get("retry_after", 0.0))
    raise cls(info.get("msg", "remote service error"))


# ---------------------------------------------------------------------------
# fast MAC (vectorized twin of framing._mac_np — bit-identical)
# ---------------------------------------------------------------------------

def fast_mac(payload_u32: np.ndarray, seed: int, block_rows: int = 65536) -> int:
    """Horner hash over rows, vectorized: h_n = INIT·P^n + Σ row_r·P^(n-1-r).
    A thin composition of framing's streaming helpers (init → block updates
    with hoisted power tables → fold), so the one-shot and chunked paths
    cannot diverge. All arithmetic runs natively in uint32 (wraparound mod
    2^32 IS the MAC's modulus). Bit-identical to framing._mac_np (tests
    assert it)."""
    if not framing.ZERO_COPY:       # A/B baseline: the full PR 3 data plane
        return legacy_fast_mac(payload_u32, seed, block_rows)
    n = payload_u32.shape[0]
    if n == 1:                      # short responses: closed-form fold
        return framing._mac_row1(payload_u32[0], seed)
    if n <= block_rows:             # one block: fold lanes first, then rows
        return framing._mac_block(payload_u32, seed)
    h = framing.mac_init_np(seed)
    for s in range(0, n, block_rows):
        h = framing.mac_update_np(h, payload_u32[s:s + block_rows])
    return framing.mac_finalize_np(h)


def legacy_fast_mac(payload_u32: np.ndarray, seed: int,
                    block_rows: int = 65536) -> int:
    """The PR 3 fast_mac, verbatim: per-block cumprod power recomputation
    and a materialized (m, LANES) uint64 product. Bit-identical to
    :func:`fast_mac`, which routes here when ``framing.ZERO_COPY=False``
    (the measured PR 3 baseline for the A/B cells in gateway_bench)."""
    from repro.kernels.ref import MAC_PRIME, MAC_INIT, _FOLD_POWERS
    n = payload_u32.shape[0]
    h = (np.full(framing.LANES, MAC_INIT, np.uint64)
         + np.uint64(seed & 0xFFFFFFFF))
    with np.errstate(over="ignore"):
        for s in range(0, n, block_rows):
            blk = payload_u32[s:s + block_rows].astype(np.uint64)
            m = blk.shape[0]
            pw = np.full(m, MAC_PRIME, np.uint64)
            pw[0] = 1
            pw = np.cumprod(pw)[::-1]
            p_m = np.uint64((int(pw[0]) * MAC_PRIME) & 0xFFFFFFFFFFFFFFFF)
            h = (h * p_m + (blk * pw[:, None]).sum(axis=0, dtype=np.uint64)) \
                & np.uint64(0xFFFFFFFF)
    return int((h * _FOLD_POWERS.astype(np.uint64)).sum(dtype=np.uint64)
               & np.uint64(0xFFFFFFFF))


# ---------------------------------------------------------------------------
# byte-stream helpers
# ---------------------------------------------------------------------------

_LEN = struct.Struct("<Q")
_ERR_BIT = 1 << 63                    # high bit of the length word = error


def _write_fd(fd: int, data: memoryview):
    while data:
        n = os.write(fd, data[: 1 << 20])
        data = data[n:]


def _write_fd_deadline(fd: int, data: memoryview, timeout: Optional[float]):
    """Write all of ``data``; with ``timeout`` the fd must be non-blocking
    and the whole write is select(2)-bounded — a full pipe against a dead
    reader raises :class:`ResponseTimeout` instead of hanging forever."""
    if timeout is None:
        return _write_fd(fd, data)
    deadline = time.monotonic() + timeout
    while data:
        remaining = deadline - time.monotonic()
        if remaining <= 0:
            raise ResponseTimeout(f"pipe write timed out after {timeout}s")
        _, ready, _ = select.select([], [fd], [], remaining)
        if not ready:
            continue
        try:
            n = os.write(fd, data[: 1 << 20])
        except BlockingIOError:
            continue
        data = data[n:]


def _read_fd(fd: int, n: int, timeout: Optional[float] = None) -> bytearray:
    """Read exactly n bytes; with ``timeout`` the whole read is bounded by a
    select(2) deadline and raises :class:`ResponseTimeout` on expiry."""
    buf = bytearray(n)
    view = memoryview(buf)
    got = 0
    deadline = None if timeout is None else time.monotonic() + timeout
    while got < n:
        if deadline is not None:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise ResponseTimeout(
                    f"pipe read timed out after {timeout}s")
            ready, _, _ = select.select([fd], [], [], remaining)
            if not ready:
                continue
        chunk = os.read(fd, min(n - got, 1 << 20))
        if not chunk:
            raise TransportError("pipe closed")
        view[got:got + len(chunk)] = chunk
        got += len(chunk)
    return buf


# ---------------------------------------------------------------------------
# doorbell: hybrid spin/park wakeup (one ring covers a whole drain pass)
# ---------------------------------------------------------------------------

# predicate probes (each yields the GIL) before parking. Small on purpose:
# sleep(0) is a sched_yield, so a long spin under load burns timeslices a
# park would have spent asleep — at 64-client fan-in a 32-probe spin more
# than halves throughput. 2 probes catches publishes that land within a
# couple of scheduler beats and parks for everything slower (measured
# best-of {0, 2, 8, 32} across solo latency AND 64-client fan-in, 2 cores)
DOORBELL_SPIN = 2


class Doorbell:
    """Hybrid spin-then-park wakeup primitive for the ring data plane.

    A waiter first probes its predicate a bounded number of times
    (:data:`DOORBELL_SPIN`, yielding the GIL between probes — the cheap
    path when the peer is about to publish), then parks on a condition
    until :meth:`ring` or the timeout. One ``ring()`` is a broadcast: it
    covers every waiter, so a service draining a whole batch notifies its
    pollers ONCE per pass instead of once per message — the wakeup twin of
    the batched key sync.

    Doorbells sharing one session pass ``lock`` (an RLock) so predicate
    re-checks inside the park happen under the same lock that guards the
    state they read. Rings are counted in ``framing.STATS.wakeups`` and
    parks in ``framing.STATS.doorbell_parks`` — the high-fan-in benchmark
    reports wakeups/request from these."""

    __slots__ = ("cond", "spin")

    def __init__(self, lock: Optional[threading.RLock] = None,
                 spin: Optional[int] = None):
        self.cond = threading.Condition(lock)
        self.spin = DOORBELL_SPIN if spin is None else spin

    def ring(self):
        """Wake every waiter (acquires the shared lock briefly)."""
        with self.cond:
            self.cond.notify_all()
        framing.STATS.bump(wakeups=1)

    def ring_owned(self):
        """:meth:`ring` for callers already holding the shared lock."""
        self.cond.notify_all()
        framing.STATS.bump(wakeups=1)

    def wait(self, pred: Callable[[], bool], timeout: float) -> bool:
        """True once ``pred()`` holds; False when ``timeout`` expires first.
        Spin phase reads shared state without the lock (safe: the ring's
        transitions are monotonic and the park re-checks under the lock)."""
        if pred():
            return True
        for _ in range(self.spin):
            time.sleep(0)               # yield — don't starve the peer
            if pred():
                return True
        framing.STATS.bump(doorbell_parks=1)
        with self.cond:
            return self.cond.wait_for(pred, timeout)


# ---------------------------------------------------------------------------
# ring of message slots (the pipelined data plane)
# ---------------------------------------------------------------------------

# slot lifecycle: FREE → STAGED (submit) → PUBLISHED (flush) → DONE (service
# wrote response/error; poll frees) — or DROPPED (injected wire drop: the
# slot never completes and the client's bounded poll() expires)
_FREE, _STAGED, _PUBLISHED, _DONE, _DROPPED = range(5)


class _RingSlot:
    """One message slot: request/response storage + status + typed error.
    shm sessions fill ``req``/``resp`` with arena slot buffers holding raw
    bytes; mpklink sessions carry whole MAC'd frames in
    ``frame``/``resp_frame`` (views into the arena buffers in
    ``req``/``resp`` on the zero-copy path)."""

    __slots__ = ("state", "ticket", "req", "req_len", "resp", "resp_len",
                 "frame", "resp_frame", "seq", "error")

    def __init__(self):
        self.state = _FREE
        self.ticket = -1
        self.req = None
        self.req_len = 0
        self.resp = None
        self.resp_len = 0
        self.frame = None
        self.resp_frame = None
        self.seq = 0
        self.error: Optional[BaseException] = None


class _Ring:
    """Fixed-capacity ring of :class:`_RingSlot`.

    Tickets are monotone ints; ticket → slot is ``ticket % capacity``, so at
    most ``capacity`` messages are in flight per session. ``head`` is the
    service's drain cursor (the next ticket it will serve); the client-side
    tail is the session's ticket counter. Every state transition happens
    under ``cv`` — the emulation's stand-in for the guarded head/tail
    control word of the shared region. ``cv`` shares the session's lock so
    the session doorbells' parked predicate checks see consistent state;
    wakeups go through the doorbells, never ``cv`` itself."""

    def __init__(self, capacity: int, lock: Optional[threading.RLock] = None):
        self.capacity = capacity
        self.slots = [_RingSlot() for _ in range(capacity)]
        self.head = 0                   # service drain cursor (ticket)
        self.cv = threading.Condition(lock)


# ---------------------------------------------------------------------------
# session / transport base
# ---------------------------------------------------------------------------

class Session:
    """One client's private channel to the service.

    Each session owns its wire (fds / socketpair / shared regions) and a
    dedicated service thread, so N sessions run N concurrent request/response
    streams with no cross-talk. ``request()`` is synchronous per session;
    open one session per client thread.
    """

    def __init__(self, transport: "Transport", name: str):
        self.transport = transport
        self.name = name
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._closed = False
        self._crashed = False
        self._poisoned = False
        # one lock guards all ring/signalling state; the two doorbells
        # (service-facing and client-facing) park on conditions over it so
        # one ring() covers every waiter of that side
        self._slk = threading.RLock()
        self._bell_svc = Doorbell(self._slk)    # client → service wakeups
        self._bell_cli = Doorbell(self._slk)    # service → client wakeups
        self._credit_waiters = 0                # submit()s blocked on credit
        # pipelined API state: ring transports use a real _Ring; the
        # lockstep fallback buffers payloads/results per ticket
        self._tickets = 0
        self._ring: Optional[_Ring] = None
        self._outstanding: set = set()      # issued, not yet redeemed
        self._lazy_pending: Dict[int, np.ndarray] = {}
        self._lazy_results: Dict[int, tuple] = {}

    @property
    def handler(self) -> Handler:
        # resolved per request so fault fabrics / gateway restarts that swap
        # transport.handler take effect on live sessions too
        return self.transport.handler

    # -- lifecycle --------------------------------------------------------
    def ensure_started(self):
        if self._thread is None and not self._closed:
            self._thread = threading.Thread(
                target=self._serve, daemon=True,
                name=f"{self.transport.name}:{self.name}")
            self._thread.start()

    def close(self):
        if self._closed:
            return
        self._closed = True
        self._stop.set()
        self._wake()
        if self._thread is not None:
            self._thread.join(timeout=5)
        self._teardown()
        self.transport._forget(self)

    # -- per-transport hooks ----------------------------------------------
    def _wake(self):
        pass

    def _teardown(self):
        pass

    def _serve(self):
        """Thread body: run the transport's serve loop; if it dies with a
        request possibly in flight (injected HandlerCrash, or any escaped
        BaseException), mark the session crashed and push a typed
        :class:`ServiceCrashed` to the waiting client IMMEDIATELY — the
        client must never wait out its full deadline on a dead service."""
        try:
            self._serve_loop()
        except BaseException as e:          # noqa: B036 — crash containment
            if self._stop.is_set():
                return
            self._crashed = True
            try:
                self._notify_crash(ServiceCrashed(
                    f"service thread for session {self.name!r} crashed: "
                    f"{type(e).__name__}: {e}"))
            # mpklint: disable=MPK105 reason=crash notify is best-effort; session already dead
            except Exception:
                pass

    def _serve_loop(self):
        raise NotImplementedError

    def _notify_crash(self, exc: ServiceCrashed):
        """Deliver ``exc`` to a client blocked on this session's response."""

    def _check_usable(self):
        if self._crashed:
            raise ServiceCrashed(
                f"session {self.name!r}: service thread is dead — "
                f"open a new session")
        self._check_pollable()

    def _check_pollable(self):
        """Like :meth:`_check_usable` minus the crash check: a crashed
        service may still hold honestly-completed ring slots, which poll()
        redeems; the crash surfaces per-ticket for everything that never
        finished."""
        if self._poisoned:
            raise TransportError(
                "session poisoned by an earlier timeout (a stale response "
                "may be in flight) — open a new session")
        if self._closed:
            raise TransportError(f"session {self.name!r} is closed")

    def request(self, payload: np.ndarray,
                timeout: Optional[float] = None) -> np.ndarray:
        """Synchronous single exchange: send ``payload``, block for the
        response (or its typed error). One in flight per session.
        ``timeout`` tightens the response deadline for THIS exchange only
        (transport default when None); expiry poisons the session exactly
        like a default-deadline timeout."""
        raise NotImplementedError

    def request_into(self, nbytes: int, fill,
                     timeout: Optional[float] = None) -> np.ndarray:
        """Zero-copy producer exchange: the caller's ``fill(dst)`` writes
        the ``nbytes`` message directly into the transport's staging
        storage (a uint8 view of the shared region on mpklink — the
        message is never materialized in a separate buffer), then the
        exchange proceeds like :meth:`request`. This base fallback
        materializes one buffer for transports without in-place staging,
        so callers never special-case."""
        buf = np.empty(nbytes, np.uint8)
        fill(buf)
        return self.request(buf, timeout=timeout)

    # -- pipelined API (ring transports override; base = lockstep fallback) --
    def submit(self, payload: np.ndarray,
               timeout: Optional[float] = None) -> int:
        """Stage one request; returns a ticket redeemable with
        :meth:`poll`. The lockstep fallback buffers the payload and runs
        the exchange lazily inside poll(); ring transports write the
        message into the next free slot. A full ring backpressures:
        submit blocks up to ``transport.credit_wait`` for a slot credit (a
        concurrent poll() freeing a slot grants one) and only then raises
        a typed :class:`CapacityError`. ``timeout`` clamps the credit wait
        to THIS call's remaining budget — a ``submit(timeout=0.05)``
        against a full ring surfaces its typed error within ~0.05s even
        when ``credit_wait`` is much larger (expiry of the caller bound
        raises :class:`ResponseTimeout`, of the credit bound
        :class:`CapacityError`). The lockstep fallback stages without
        blocking, so ``timeout`` is a no-op there."""
        self._check_usable()
        t = self._tickets
        self._tickets += 1
        self._lazy_pending[t] = np.asarray(payload)
        return t

    def flush(self):
        """Publish everything staged by :meth:`submit` to the service.
        No-op for the lockstep fallback; ring transports flip staged slots
        to published under ONE control-word update (one key-sync round trip
        on the mpklink variants, however many messages were staged)."""

    def poll(self, ticket: int, timeout: Optional[float] = None) -> np.ndarray:
        """Redeem ``ticket``: return its response, or raise its typed
        error. Blocks up to ``timeout`` (transport default when None) —
        honored by ring transports through the doorbell wait AND by this
        lockstep fallback, which runs the buffered exchanges under one
        per-poll deadline (each lazy ``request()`` gets the remaining
        budget)."""
        if ticket not in self._lazy_results and ticket not in self._lazy_pending:
            raise TransportError(f"unknown or already-redeemed ticket {ticket}")
        deadline = None if timeout is None else time.monotonic() + timeout
        for t in sorted(self._lazy_pending):        # FIFO up to the ticket
            if t > ticket:
                break
            payload = self._lazy_pending.pop(t)
            try:
                remaining = None if deadline is None \
                    else max(1e-3, deadline - time.monotonic())
                self._lazy_results[t] = (True, self.request(
                    payload, timeout=remaining))
            except Exception as e:
                self._lazy_results[t] = (False, e)
        ok, val = self._lazy_results.pop(ticket)
        if not ok:
            raise val
        return val

    def call_batch(self, payloads, return_exceptions: bool = False):
        """Pipelined batch call: submit every payload, flush once, poll
        every ticket. Returns responses in payload order. Per-message
        failures stay typed: with ``return_exceptions`` the exception
        object sits in that message's position; otherwise the first error
        is raised after the whole batch has drained (later messages are
        still consumed, so the session stays usable when it isn't
        poisoned/crashed)."""
        tickets = [self.submit(p) for p in payloads]
        self.flush()
        out, first = [], None
        for t in tickets:
            try:
                out.append(self.poll(t))
            except Exception as e:          # noqa: PERF203 — per-ticket fate
                if first is None:
                    first = e
                out.append(e)
        if first is not None and not return_exceptions:
            raise first
        return out

    # -- shared ring redeem (the wait state machine exists ONCE) -----------
    def _slot_take(self, slot: _RingSlot):
        """Extract a completed slot's response payload (called under the
        ring lock, just before the slot is freed). Ring sessions override."""
        raise NotImplementedError

    def _ring_redeem(self, ticket: int, timeout: Optional[float]):
        """Wait (bounded) for ``ticket``'s slot to reach DONE, mark the
        ticket redeemed and free the slot. Returns ``(error, extracted)``
        — exactly one is meaningful. Typed outcomes: double-redeeming or a
        never-issued ticket raises immediately (never a deadline wait on a
        healthy session), a crash surfaces as ServiceCrashed for anything
        not already completed, and a deadline expiry poisons the session
        like a lockstep timeout. The wait itself is the client doorbell:
        bounded spin on the slot state, then park — ONE service-side ring
        per drain pass wakes every poller of that pass."""
        ring = self._ring
        if ring is None or ticket >= self._tickets:
            raise TransportError(f"unknown ticket {ticket}")
        timeout = self.transport.timeout if timeout is None else timeout
        deadline = time.monotonic() + timeout
        slot = ring.slots[ticket % ring.capacity]

        def settled():                  # lock-free probe; re-checked locked
            return (slot.ticket == ticket and slot.state == _DONE) \
                or self._crashed or self._closed

        with ring.cv:
            if ticket not in self._outstanding:
                raise TransportError(f"ticket {ticket} already redeemed")
        while True:
            self._bell_cli.wait(
                settled, max(0.0, deadline - time.monotonic()))
            with ring.cv:
                if slot.ticket == ticket and slot.state == _DONE:
                    self._outstanding.discard(ticket)
                    err, slot.error = slot.error, None
                    extracted = None if err is not None \
                        else self._slot_take(slot)
                    slot.state = _FREE
                    if self._credit_waiters:    # grant the freed credit
                        self._bell_cli.ring_owned()
                    return err, extracted
                if self._crashed:
                    raise ServiceCrashed(
                        f"session {self.name!r}: service thread died with "
                        f"ticket {ticket} in flight")
                if self._closed:
                    raise TransportError(f"session {self.name!r} is closed")
                if time.monotonic() >= deadline:
                    self._poisoned = True
                    raise ResponseTimeout(
                        f"ring response timed out after {timeout}s")

    def _await_credit(self, ring: _Ring,
                      deadline: Optional[float] = None):
        """Credit-based ring flow control: block (bounded by
        ``transport.credit_wait``, further clamped by the caller's
        remaining per-call budget ``deadline`` — an absolute monotonic
        instant) until the next slot is FREE — a concurrent :meth:`poll`
        freeing a slot grants the credit — instead of rejecting a full
        ring outright. Anything already staged is published first so
        in-flight work can complete while we wait. Expiry raises the typed
        error matching whichever bound was the tighter one: the credit
        window → :class:`CapacityError`; the caller's call budget →
        :class:`ResponseTimeout` (the call's deadline elapsed before its
        message could even be staged — the session is NOT poisoned, since
        nothing was submitted)."""
        slot = ring.slots[self._tickets % ring.capacity]
        if slot.state == _FREE:
            return
        # the credit clock starts BEFORE the publish: the flush below lets
        # the service drain in-flight work but must not extend the bound
        # (its own key-sync handshake is separately crash/close-bounded)
        credit_deadline = time.monotonic() + self.transport.credit_wait
        eff_deadline = credit_deadline if deadline is None \
            else min(credit_deadline, deadline)
        self.flush()

        def free():
            return slot.state == _FREE or self._crashed or self._closed

        with ring.cv:
            self._credit_waiters += 1
        try:
            while True:
                self._bell_cli.wait(
                    free, max(0.0, eff_deadline - time.monotonic()))
                with ring.cv:
                    if slot.state == _FREE:
                        return
                    if self._crashed:
                        raise ServiceCrashed(
                            f"session {self.name!r}: service thread died "
                            f"while waiting for a ring credit")
                    if self._closed:
                        raise TransportError(
                            f"session {self.name!r} is closed")
                    if time.monotonic() >= eff_deadline:
                        if eff_deadline < credit_deadline:
                            raise ResponseTimeout(
                                f"call budget exhausted while waiting for "
                                f"a ring credit (ring full, "
                                f"{ring.capacity} messages in flight)")
                        raise CapacityError(
                            f"ring full ({ring.capacity} messages in "
                            f"flight) — poll() before submitting more")
        finally:
            with ring.cv:
                self._credit_waiters -= 1


class Transport:
    """Base: a service handler plus N client sessions (threads of one
    process — the paper's co-located microservice design).

    ``arena`` is the transport-wide :class:`framing.FrameArena`: a
    recycling pool of slot-sized frame buffers shared by every session's
    ring, so the steady-state pipelined data plane stages requests and
    responses without allocating (shm slots hold raw bytes in arena
    buffers; mpklink slots hold sealed frames)."""

    name = "?"
    DEFAULT_RING_SLOTS = 8              # in-flight messages per session ring
    DEFAULT_CREDIT_WAIT = 1.0           # submit() backpressure bound (s)

    def __init__(self, handler: Handler, timeout: float = 120.0,
                 ring_slots: Optional[int] = None,
                 credit_wait: Optional[float] = None):
        self.handler = handler
        self.timeout = timeout          # client-side response deadline
        self.ring_slots = ring_slots or self.DEFAULT_RING_SLOTS
        self.credit_wait = self.DEFAULT_CREDIT_WAIT \
            if credit_wait is None else credit_wait
        self.arena = framing.FrameArena()
        self._sessions: List[Session] = []
        self._slock = threading.Lock()
        self._default: Optional[Session] = None
        self._counter = itertools.count()

    # -- session management -----------------------------------------------
    def _make_session(self, name: str) -> Session:
        raise NotImplementedError

    def connect(self, name: Optional[str] = None) -> Session:
        """Open a new client session (own channel + service thread)."""
        s = self._make_session(name or f"{self.name}-client-{next(self._counter)}")
        with self._slock:
            self._sessions.append(s)
        s.ensure_started()
        return s

    def _forget(self, session: Session):
        with self._slock:
            if session in self._sessions:
                self._sessions.remove(session)

    # -- legacy single-client API ------------------------------------------
    def start(self):
        with self._slock:
            sessions = list(self._sessions)
        for s in sessions:
            s.ensure_started()
        return self

    def request(self, payload: np.ndarray) -> np.ndarray:
        d = self._default
        if d is None or d._closed or d._crashed or d._poisoned:
            if d is not None and not d._closed:
                d.close()       # a poisoned/crashed session is done for
            self._default = self.connect("svc-client")
            self._on_new_default()
        self._default.ensure_started()
        return self._default.request(payload)

    def _on_new_default(self):
        """Hook: the default session was replaced (first use, or recovery
        after a poisoning timeout)."""

    def close(self):
        with self._slock:
            sessions = list(self._sessions)
        for s in sessions:
            s.close()


# ---------------------------------------------------------------------------
# 1. OS pipes (two unidirectional per session)
# ---------------------------------------------------------------------------

class PipeSession(Session):
    def __init__(self, transport, name):
        super().__init__(transport, name)
        self._c2s = os.pipe()
        self._s2c = os.pipe()
        # client-side write end is non-blocking so request() sends can be
        # deadline-bounded (a dead service thread stops draining the pipe)
        os.set_blocking(self._c2s[1], False)

    def _send_error(self, exc: BaseException):
        blob = _pack_error(exc)
        _write_fd(self._s2c[1], memoryview(_LEN.pack(len(blob) | _ERR_BIT)))
        _write_fd(self._s2c[1], memoryview(blob))

    def _serve_loop(self):
        while not self._stop.is_set():
            try:
                n = _LEN.unpack(bytes(_read_fd(self._c2s[0], 8)))[0]
            except (TransportError, OSError):
                return
            if n == 0:
                return
            req = np.frombuffer(_read_fd(self._c2s[0], n), np.uint8)
            try:
                resp = self.handler(req)
                raw = np.ascontiguousarray(resp).view(np.uint8).reshape(-1)
            except DropResponse:                   # injected wire drop
                continue
            except Exception as e:                 # propagate, don't die
                self._send_error(e)
                continue
            _write_fd(self._s2c[1], memoryview(_LEN.pack(raw.nbytes)))
            _write_fd(self._s2c[1], memoryview(raw))

    def _notify_crash(self, exc: ServiceCrashed):
        try:
            self._send_error(exc)
        except OSError:
            pass

    def _wake(self):
        try:
            os.write(self._c2s[1], _LEN.pack(0))
        except OSError:
            pass

    def request(self, payload: np.ndarray,
                timeout: Optional[float] = None) -> np.ndarray:
        self._check_usable()
        timeout = self.transport.timeout if timeout is None else timeout
        raw = np.ascontiguousarray(payload).view(np.uint8).reshape(-1)
        try:
            _write_fd_deadline(self._c2s[1],
                               memoryview(_LEN.pack(raw.nbytes)), timeout)
            _write_fd_deadline(self._c2s[1], memoryview(raw), timeout)
            n = _LEN.unpack(bytes(_read_fd(self._s2c[0], 8, timeout)))[0]
            if n & _ERR_BIT:
                _raise_remote(_read_fd(self._s2c[0], n & ~_ERR_BIT, timeout))
            return np.frombuffer(_read_fd(self._s2c[0], n, timeout), np.uint8)
        except ResponseTimeout:
            # a late response may still arrive; never let it be read as the
            # answer to a NEW request
            self._poisoned = True
            if self._crashed:
                raise ServiceCrashed(
                    f"session {self.name!r}: service thread died mid-request")
            raise

    def _teardown(self):
        for fd in (*self._c2s, *self._s2c):
            try:
                os.close(fd)
            except OSError:
                pass


class PipeTransport(Transport):
    name = "pipe"

    def _make_session(self, name):
        return PipeSession(self, name)


# ---------------------------------------------------------------------------
# 2. Unix domain sockets (one bidirectional pair per session)
# ---------------------------------------------------------------------------

def _recv_exact(sock: socket.socket, n: int) -> bytearray:
    buf = bytearray(n)
    view = memoryview(buf)
    got = 0
    while got < n:
        r = sock.recv_into(view[got:], n - got)
        if r == 0:
            # EOF mid-message is peer DEATH, not a protocol error: classify
            # it as a liveness failure so bounded retry / circuit breaking
            # engage exactly as they do when a ring transport's service dies
            # (ServiceCrashed ⊂ TransportError, so serve loops that catch
            # TransportError to exit quietly are unaffected)
            raise ServiceCrashed(
                f"peer closed the socket mid-read ({got}/{n} bytes)")
        got += r
    return buf


class UDSSession(Session):
    def __init__(self, transport, name):
        super().__init__(transport, name)
        self._client, self._server = socket.socketpair(
            socket.AF_UNIX, socket.SOCK_STREAM)
        self._client.settimeout(transport.timeout)

    def _send_error(self, exc: BaseException):
        blob = _pack_error(exc)
        self._server.sendall(_LEN.pack(len(blob) | _ERR_BIT))
        self._server.sendall(blob)

    def _serve_loop(self):
        while not self._stop.is_set():
            try:
                n = _LEN.unpack(bytes(_recv_exact(self._server, 8)))[0]
            except (TransportError, OSError):
                return
            if n == 0:
                return
            req = np.frombuffer(_recv_exact(self._server, n), np.uint8)
            try:
                resp = np.ascontiguousarray(self.handler(req)) \
                    .view(np.uint8).reshape(-1)
            except DropResponse:                   # injected wire drop
                continue
            except Exception as e:
                self._send_error(e)
                continue
            self._server.sendall(_LEN.pack(resp.nbytes))
            self._server.sendall(resp)

    def _notify_crash(self, exc: ServiceCrashed):
        try:
            self._send_error(exc)
        except OSError:
            pass

    def _wake(self):
        try:
            self._client.sendall(_LEN.pack(0))
        except OSError:
            pass

    def request(self, payload: np.ndarray,
                timeout: Optional[float] = None) -> np.ndarray:
        self._check_usable()
        eff = self.transport.timeout if timeout is None else timeout
        self._client.settimeout(eff)
        raw = np.ascontiguousarray(payload).view(np.uint8).reshape(-1)
        try:
            # sends are inside the timeout net too: a send-side stall (full
            # socket buffer against a wedged peer) must poison the session
            # — the stream is desynced mid-message — not escape untyped
            self._client.sendall(_LEN.pack(raw.nbytes))
            self._client.sendall(raw)
            n = _LEN.unpack(bytes(_recv_exact(self._client, 8)))[0]
            if n & _ERR_BIT:
                _raise_remote(_recv_exact(self._client, n & ~_ERR_BIT))
            return np.frombuffer(_recv_exact(self._client, n), np.uint8)
        except socket.timeout:
            self._poisoned = True
            if self._crashed:
                raise ServiceCrashed(
                    f"session {self.name!r}: service thread died mid-request")
            raise ResponseTimeout(
                f"uds response timed out after {eff}s")

    def _teardown(self):
        self._client.close()
        self._server.close()


class UDSTransport(Transport):
    name = "uds"

    # kept as a staticmethod for back-compat with callers of the old API
    _recv_exact = staticmethod(_recv_exact)

    def _make_session(self, name):
        return UDSSession(self, name)


# ---------------------------------------------------------------------------
# 3. raw shared memory, fixed capacity (the paper's failing baseline)
# ---------------------------------------------------------------------------

class ShmSession(Session):
    """One client's pair of raw shared regions + a ring of message slots.

    Lockstep ``request()`` uses the dedicated one-slot region pair (the
    paper's baseline); the pipelined ``submit``/``flush``/``poll`` path uses
    a lazily-created :class:`_Ring` whose slots each hold a capacity-sized
    req/resp buffer — the service thread drains published slots in ticket
    order between lockstep exchanges."""

    def __init__(self, transport, name):
        super().__init__(transport, name)
        self.capacity = transport.capacity
        self._req = np.zeros(self.capacity, np.uint8)
        self._resp = np.zeros(self.capacity, np.uint8)
        self._req_len = 0
        self._resp_len = 0
        self._req_pending = False       # lockstep request staged (vs ring wake)
        self._resp_flag = False         # lockstep response/error delivered
        self._error: Optional[BaseException] = None

    def _svc_pending(self) -> bool:
        """Service doorbell predicate: a lockstep request is staged, a
        published ring slot awaits the drain cursor, or we're stopping."""
        if self._stop.is_set() or self._req_pending:
            return True
        ring = self._ring
        if ring is None:
            return False
        slot = ring.slots[ring.head % ring.capacity]
        return slot.state == _PUBLISHED and slot.ticket == ring.head

    def _serve_loop(self):
        while not self._stop.is_set():
            if not self._bell_svc.wait(self._svc_pending, timeout=0.5):
                continue
            if self._stop.is_set():
                return
            if self._req_pending:
                self._req_pending = False
                self._serve_lockstep()
            self._drain_ring()

    def _serve_lockstep(self):
        req = self._req[: self._req_len]
        try:
            resp = np.ascontiguousarray(self.handler(req)) \
                .view(np.uint8).reshape(-1)
            if resp.nbytes > self.capacity:
                raise CapacityError(
                    f"shm region ({self.capacity}B) cannot hold "
                    f"{resp.nbytes}B response")
            self._error = None
            self._resp[: resp.nbytes] = resp
            self._resp_len = resp.nbytes
        except DropResponse:                   # injected wire drop: the
            return                             # client wait must expire
        except Exception as e:                 # incl. CapacityError
            self._error = e
            self._resp_len = 0
        self._resp_flag = True
        self._bell_cli.ring()

    # -- ring (pipelined) path: slots are recycled arena buffers -----------
    def _ring_obj(self) -> _Ring:
        if self._ring is None:
            self._ring = _Ring(self.transport.ring_slots, self._slk)
        return self._ring

    @staticmethod
    def _bytes_rows(nbytes: int) -> int:
        return -(-nbytes // (framing.LANES * 4))

    def submit(self, payload: np.ndarray,
               timeout: Optional[float] = None) -> int:
        self._check_usable()
        raw = np.ascontiguousarray(np.asarray(payload)) \
            .view(np.uint8).reshape(-1)
        if raw.nbytes > self.capacity:
            raise CapacityError(
                f"shm region ({self.capacity}B) cannot hold {raw.nbytes}B payload")
        ring = self._ring_obj()
        # credit-based backpressure BEFORE paying for a slot + payload copy,
        # clamped to the caller's per-call budget
        self._await_credit(ring, None if timeout is None
                           else time.monotonic() + timeout)
        buf = self.transport.arena.acquire(self._bytes_rows(raw.nbytes))
        buf.reshape(-1).view(np.uint8)[: raw.nbytes] = raw
        with ring.cv:
            t = self._tickets
            slot = ring.slots[t % ring.capacity]
            if slot.state != _FREE:     # re-check: sessions are serial per
                self.transport.arena.release(buf)   # client, but stay safe
                raise CapacityError(
                    f"ring full ({ring.capacity} messages in flight) — "
                    f"poll() before submitting more")
            self._tickets += 1
            self._outstanding.add(t)
            slot.ticket = t
            slot.req = buf
            slot.req_len = raw.nbytes
            slot.error = None
            slot.state = _STAGED
        return t

    def flush(self):
        ring = self._ring
        if ring is None:
            return
        published = False
        with ring.cv:
            for s in ring.slots:
                if s.state == _STAGED:
                    s.state = _PUBLISHED
                    published = True
        if published:
            self._bell_svc.ring()       # one ring covers the whole flush

    def _drain_ring(self):
        """Consume published slots in ticket order; completed slots are
        announced with ONE client-doorbell ring per drain pass (not one
        per slot) — the wakeup twin of the batched key sync."""
        ring = self._ring
        if ring is None:
            return
        arena = self.transport.arena
        completed = 0
        while True:
            with ring.cv:
                slot = ring.slots[ring.head % ring.capacity]
                if slot.state != _PUBLISHED or slot.ticket != ring.head:
                    break
                req = slot.req.reshape(-1).view(np.uint8)[: slot.req_len]
            error = resp = rbuf = None
            try:                        # handler outside the ring lock
                resp = np.ascontiguousarray(self.handler(req)) \
                    .view(np.uint8).reshape(-1)
                if resp.nbytes > self.capacity:
                    raise CapacityError(
                        f"shm region ({self.capacity}B) cannot hold "
                        f"{resp.nbytes}B response")
                rbuf = arena.acquire(self._bytes_rows(resp.nbytes))
                rbuf.reshape(-1).view(np.uint8)[: resp.nbytes] = resp
            except DropResponse:        # injected wire drop: this slot never
                with ring.cv:           # completes; its poll() must expire
                    arena.release(slot.req)
                    slot.req = None
                    slot.state = _DROPPED
                    ring.head += 1
                continue
            except Exception as e:
                error = e
            with ring.cv:
                arena.release(slot.req)     # request consumed by the handler
                slot.req = None
                if error is None:
                    slot.resp = rbuf
                    slot.resp_len = resp.nbytes
                else:
                    slot.error = error
                    slot.resp_len = 0
                slot.state = _DONE
                ring.head += 1
                completed += 1
        if completed:
            self._bell_cli.ring()

    def _slot_take(self, slot: _RingSlot):
        """Hand the response back as a read-only view of the arena buffer;
        the buffer recycles when the view is garbage-collected, so a live
        view can never alias a reused slot."""
        buf, slot.resp = slot.resp, None
        out = buf.reshape(-1).view(np.uint8)[: slot.resp_len]
        out.flags.writeable = False
        self.transport.arena.release_on_collect(out, buf)
        return out

    def poll(self, ticket: int, timeout: Optional[float] = None) -> np.ndarray:
        self._check_pollable()
        self.flush()                    # poll implies publish
        err, resp = self._ring_redeem(ticket, timeout)
        if err is not None:
            raise err
        return resp

    def _notify_crash(self, exc: ServiceCrashed):
        # wake the blocked waiter immediately with the typed crash — it must
        # not sit out the full deadline against a dead service thread
        self._error = exc
        self._resp_len = 0
        self._resp_flag = True
        self._bell_cli.ring()

    def _wake(self):
        # a waiter woken by close() must get an error, never the previous
        # request's bytes masquerading as its response
        self._error = TransportError("session closed while request in flight")
        self._resp_flag = True
        self._bell_svc.ring()
        self._bell_cli.ring()

    def request(self, payload: np.ndarray,
                timeout: Optional[float] = None) -> np.ndarray:
        self._check_usable()
        eff = self.transport.timeout if timeout is None else timeout
        raw = np.ascontiguousarray(payload).view(np.uint8).reshape(-1)
        if raw.nbytes > self.capacity:
            raise CapacityError(
                f"shm region ({self.capacity}B) cannot hold {raw.nbytes}B payload")
        self._req[: raw.nbytes] = raw
        self._req_len = raw.nbytes
        self._resp_flag = False
        self._req_pending = True
        self._bell_svc.ring()
        if not self._bell_cli.wait(lambda: self._resp_flag, eff):
            # the service thread may still deliver later; never let that
            # stale response be mistaken for the answer to a NEW request
            self._poisoned = True
            if self._crashed:
                raise ServiceCrashed(
                    f"session {self.name!r}: service thread died mid-request")
            raise ResponseTimeout(
                f"shm response timed out after {eff}s")
        self._resp_flag = False
        if self._error is not None:
            err, self._error = self._error, None
            raise err
        return self._resp[: self._resp_len].copy()


class ShmTransport(Transport):
    """Two regions (req/resp) per session + length words + ready events.
    Capacity is fixed at construction — ≥capacity payloads raise
    CapacityError (on EITHER direction — an oversized handler response is
    reported to the caller, never stranded in the service thread),
    reproducing the paper's observation that baseline shm "is incapable of
    handling requests involving 100,000 words or more"."""

    name = "shm"
    DEFAULT_CAPACITY = 512 * 1024      # ≈70k words of ~7 chars — fails at 100k

    def __init__(self, handler: Handler, capacity: int = DEFAULT_CAPACITY,
                 timeout: float = 120.0, ring_slots: Optional[int] = None,
                 credit_wait: Optional[float] = None):
        super().__init__(handler, timeout=timeout, ring_slots=ring_slots,
                         credit_wait=credit_wait)
        self.capacity = capacity

    def _make_session(self, name):
        return ShmSession(self, name)


# ---------------------------------------------------------------------------
# 4. gRPC simulation (serialization + HTTP/2 framing + flow control)
# ---------------------------------------------------------------------------

class GrpcSimSession(Session):
    def __init__(self, transport, name):
        super().__init__(transport, name)
        self.FRAME = transport.FRAME
        self.WINDOW = transport.WINDOW
        self._HDR = transport._HDR
        self._client, self._server = socket.socketpair(
            socket.AF_UNIX, socket.SOCK_STREAM)
        for s in (self._client, self._server):
            s.setsockopt(socket.SOL_SOCKET, socket.SO_SNDBUF, 1 << 20)
        self._client.settimeout(transport.timeout)

    def _send_msg(self, sock: socket.socket, obj):
        body = msgpack.packb(obj, use_bin_type=True)
        sent = 0
        credit = self.WINDOW
        while sent < len(body):
            if credit <= 0:                      # wait for WINDOW_UPDATE
                hdr = _recv_exact(sock, self._HDR.size)
                ln, typ, _ = self._HDR.unpack(bytes(hdr))
                assert typ == 8, "expected WINDOW_UPDATE"
                credit += ln
            n = min(self.FRAME, len(body) - sent, credit)
            sock.sendall(self._HDR.pack(n, 0, 1))
            sock.sendall(body[sent:sent + n])
            sent += n
            credit -= n
        sock.sendall(self._HDR.pack(0, 1, 1))    # END_STREAM
    def _recv_msg(self, sock: socket.socket):
        chunks = []
        consumed = 0
        while True:
            hdr = _recv_exact(sock, self._HDR.size)
            ln, typ, _ = self._HDR.unpack(bytes(hdr))
            if typ == 1:
                break
            if typ == 8:
                continue                          # WINDOW_UPDATE for our own
                                                  # sends — headers only
            chunks.append(bytes(_recv_exact(sock, ln)))
            consumed += ln
            if consumed >= self.WINDOW // 2:     # grant more window
                sock.sendall(self._HDR.pack(consumed, 8, 1))
                consumed = 0
        return msgpack.unpackb(b"".join(chunks), raw=False)

    def _serve_loop(self):
        while not self._stop.is_set():
            try:
                msg = self._recv_msg(self._server)
            except (TransportError, OSError, AssertionError):
                return
            if msg.get("op") == "stop":
                return
            req = np.frombuffer(msg["data"], np.uint8)
            try:
                resp = np.ascontiguousarray(self.handler(req)) \
                    .view(np.uint8).reshape(-1)
            except DropResponse:                   # injected wire drop
                continue
            except Exception as e:
                self._send_msg(self._server,
                               {"status": 1, "error": _pack_error(e)})
                continue
            self._send_msg(self._server, {"status": 0, "data": resp.tobytes()})

    def _notify_crash(self, exc: ServiceCrashed):
        try:
            self._send_msg(self._server, {"status": 1, "error": _pack_error(exc)})
        except OSError:
            pass

    def _wake(self):
        try:
            self._send_msg(self._client, {"op": "stop"})
        except OSError:
            pass

    def request(self, payload: np.ndarray,
                timeout: Optional[float] = None) -> np.ndarray:
        self._check_usable()
        eff = self.transport.timeout if timeout is None else timeout
        self._client.settimeout(eff)
        raw = np.ascontiguousarray(payload).view(np.uint8).reshape(-1)
        try:
            self._send_msg(self._client, {"op": "count", "data": raw.tobytes()})
            resp = self._recv_msg(self._client)
        except socket.timeout:
            self._poisoned = True
            if self._crashed:
                raise ServiceCrashed(
                    f"session {self.name!r}: service thread died mid-request")
            raise ResponseTimeout(
                f"grpc_sim response timed out after {eff}s")
        if resp.get("status"):
            _raise_remote(resp["error"])
        return np.frombuffer(resp["data"], np.uint8)

    def _teardown(self):
        self._client.close()
        self._server.close()


class GrpcSimTransport(Transport):
    """msgpack body + 9-byte frame header per 16 KiB DATA frame + 64 KiB
    flow-control window with WINDOW_UPDATE acks — the protocol overhead the
    paper attributes to network-style IPC for co-located services."""

    name = "grpc_sim"
    FRAME = 16 * 1024
    WINDOW = 64 * 1024
    _HDR = struct.Struct("<IBI")       # length, type, stream_id

    def _make_session(self, name):
        return GrpcSimSession(self, name)


# ---------------------------------------------------------------------------
# 5. MPKLink (paper-faithful) and 6. MPKLink-opt (beyond paper)
# ---------------------------------------------------------------------------

class MPKLinkSession(Session):
    """One CA-enrolled client endpoint: its own protection domain shared
    with the server, capability keys, session-derived MAC seed, framing
    sequence, and guarded regions."""

    def __init__(self, transport: "MPKLinkTransport", name: str):
        super().__init__(transport, name)
        self.chunk = transport.chunk
        self._mac = transport._mac
        # batch-path MAC: None selects framing's fused vectorized pass
        # (bit-identical to fast_mac); a custom scalar impl is honored
        # per frame so batched and lockstep exchanges can never disagree
        self._batch_mac = None if transport._mac is fast_mac \
            else transport._mac
        self.registry = transport.registry
        # --- control plane: CA handshake (per client) ----------------------
        self._kp, _ = enroll(transport.ca, name)
        self.domain, self.key_client, self.key_server = \
            transport.ca.grant_channel(name, transport.server_name, RW)
        sess = transport.ca.session_seed(self._kp.private, transport.server_name)
        self.seed = mac_seed(self.domain,
                             self.registry.epoch(self.domain)) ^ sess
        # --- data plane: shared regions + PKRU "register file" -------------
        self._region_req = np.zeros((0, framing.LANES), np.uint32)
        self._region_resp = np.zeros((0, framing.LANES), np.uint32)
        self._pkru = np.zeros(2, np.uint64)        # [pkru_word, epoch]
        self._chunk_pending = False                # client staged a chunk sync
        self._chunk_acked = False                  # service loaded the PKRU word
        self._resp_flag = False                    # lockstep response delivered
        self._final = False                        # last chunk of a request?
        self._error: Optional[BaseException] = None
        self._req_rows = 0
        self._resp_rows = 0
        self._seq = 0
        self.sync_count = 0                        # per-session key syncs
        # the client thread (request/flush path) and the service thread
        # (response/drain path) both bump sync_count — the += must not
        # drop counts (benchmarks assert exact syncs/request)
        self._sync_slk = threading.Lock()

    def _bump_sync(self):
        """One PKRU key-sync round trip: session- and transport-level
        accounting (both counters have concurrent writers)."""
        with self._sync_slk:
            self.sync_count += 1
        self.transport._bump_sync()

    # -- one PKRU synchronization round trip (writer side) -------------------
    def _sync_key(self, key, rights):
        self.registry.check(key, rights)           # staging-time capability check
        self._pkru[0] = self.registry.pkru_word((key,))
        self._pkru[1] = self.registry.epoch(self.domain)
        self._bump_sync()
        self._chunk_acked = False
        self._chunk_pending = True
        self._bell_svc.ring()
        # bounded ack wait: a service thread that dies mid-exchange acks at
        # most once (via _notify_crash), so an unbounded wait here could
        # strand a multi-sync send/flush forever — surface the typed crash
        # instead, preserving the 'no transport can deadlock' bound
        while True:
            self._bell_cli.wait(
                lambda: self._chunk_acked or self._crashed or self._closed
                or self._stop.is_set(), timeout=0.5)
            if self._chunk_acked:
                break
            if self._crashed:
                raise ServiceCrashed(
                    f"session {self.name!r}: service thread died during a "
                    f"key-sync round trip")
            if self._closed or self._stop.is_set():
                raise TransportError(
                    f"session {self.name!r} closed during a key sync")
        self._chunk_acked = False

    def _svc_pending(self) -> bool:
        return self._stop.is_set() or self._chunk_pending

    def _serve_loop(self):
        while not self._stop.is_set():
            if not self._bell_svc.wait(self._svc_pending, timeout=0.5):
                continue
            if not self._chunk_pending:            # woken to stop
                if self._stop.is_set():
                    return
                continue
            self._chunk_pending = False
            if self._stop.is_set():
                self._chunk_acked = True
                self._bell_cli.ring()
                return
            final = self._final                    # read before acking
            self._chunk_acked = True               # reader loads PKRU word
            self._bell_cli.ring()
            self._drain_ring()                     # published ring slots
            if not final:
                continue
            # full frame visible → verify + handle + respond. The request
            # is handed to the handler as a read-only zero-copy view of the
            # region; the response is sealed directly into the response
            # region (no intermediate frame buffer)
            self.registry.check(self.key_server, READ)
            try:
                req = framing.verify_view(self._region_req[: self._req_rows],
                                          seed=self.seed, expect_seq=self._seq,
                                          mac_impl=self._mac)
            except framing.FrameError:
                self._error = None                 # guard rejection, not a crash
                self._resp_rows = 0
                self._resp_flag = True
                self._bell_cli.ring()
                continue
            self.registry.check(self.key_server, WRITE)
            try:
                resp = np.ascontiguousarray(self.handler(req)) \
                    .view(np.uint8).reshape(-1)
            except DropResponse:                   # injected wire drop: the
                continue                           # client wait must expire
            except Exception as e:
                self._error = e
                self._resp_rows = 0
                self._resp_flag = True
                self._bell_cli.ring()
                continue
            rows = framing.frame_rows(resp.nbytes)
            if self._region_resp.shape[0] < rows:
                self._region_resp = np.zeros((rows, framing.LANES), np.uint32)
            if framing.ZERO_COPY:
                framing.seal_into(self._region_resp, resp, seed=self.seed,
                                  seq=self._seq, mac_impl=self._mac)
            else:
                self._region_resp[:rows] = framing.build_frame(
                    resp, seed=self.seed, seq=self._seq, mac_impl=self._mac)
            self._resp_rows = rows
            self._bump_sync()                      # response-side key sync
            self._resp_flag = True
            self._bell_cli.ring()

    def _notify_crash(self, exc: ServiceCrashed):
        # wake both the chunk-sync and response waiters with the typed crash
        # (one client-doorbell ring covers chunk-ack, lockstep and ring
        # pollers — they all park on the same bell)
        self._error = exc
        self._resp_rows = 0
        self._chunk_acked = True
        self._resp_flag = True
        self._bell_cli.ring()

    def _wake(self):
        self._final = False
        self._chunk_acked = True
        self._resp_flag = True
        self._bell_svc.ring()
        self._bell_cli.ring()

    def _teardown(self):
        # give the pkey back (pkey_free) so long-lived transports can cycle
        # through many more sessions than the key-table size
        self.registry.free_domain(self.domain)

    def _grow_req(self, rows: int):
        if self._region_req.shape[0] < rows:
            self._region_req = np.zeros((rows, framing.LANES), np.uint32)

    def request(self, payload: np.ndarray,
                timeout: Optional[float] = None) -> np.ndarray:
        self._check_usable()
        payload = np.ascontiguousarray(np.asarray(payload))
        rows = framing.frame_rows(payload.nbytes)
        self._grow_req(rows)
        if framing.ZERO_COPY:
            # zero-copy seal: header + payload + MAC land directly in the
            # shared region — no intermediate frame materialization. The
            # per-chunk key-sync schedule is unchanged (the paper's
            # measured cost model is the sync COUNT, not the copy schedule)
            framing.seal_into(self._region_req, payload, seed=self.seed,
                              seq=self._seq, mac_impl=self._mac)
            return self._exchange(rows, timeout=timeout)
        frame = framing.build_frame(payload, seed=self.seed,
                                    seq=self._seq, mac_impl=self._mac)
        return self._exchange(rows, legacy_frame=frame, timeout=timeout)

    def request_into(self, nbytes: int, fill,
                     timeout: Optional[float] = None) -> np.ndarray:
        """Fully zero-copy producer path: ``fill(dst)`` writes the message
        straight into the request region's payload bytes, which are then
        pad-zeroed, MAC'd in place and headed (framing.seal_prefilled) —
        the message is never materialized outside the shared region."""
        self._check_usable()
        if not framing.ZERO_COPY:
            buf = np.empty(nbytes, np.uint8)
            fill(buf)
            return self.request(buf, timeout=timeout)
        rows = framing.frame_rows(nbytes)
        self._grow_req(rows)
        body = self._region_req[1:rows].reshape(-1).view(np.uint8)[:nbytes]
        fill(body)      # the filler accounts its own writes (STATS)
        framing.seal_prefilled(self._region_req, nbytes, seed=self.seed,
                               seq=self._seq, mac_impl=self._mac)
        return self._exchange(rows, timeout=timeout)

    def _exchange(self, rows: int,
                  legacy_frame: Optional[np.ndarray] = None,
                  timeout: Optional[float] = None) -> np.ndarray:
        """The chunk-sync publish loop + bounded response wait + response
        guard, shared by request()/request_into()."""
        eff = self.transport.timeout if timeout is None else timeout
        chunk_rows = max(1, self.chunk // (framing.LANES * 4))
        self._resp_flag = False
        for s in range(0, rows, chunk_rows):
            e = min(rows, s + chunk_rows)
            if legacy_frame is not None:
                self._region_req[s:e] = legacy_frame[s:e]
            self._req_rows = rows
            self._final = e >= rows
            self._sync_key(self.key_client, WRITE)
        if not self._bell_cli.wait(lambda: self._resp_flag, eff):
            self._poisoned = True       # a late response must never be
            if self._crashed:           # read back as the next one's answer
                raise ServiceCrashed(
                    f"session {self.name!r}: service thread died mid-request")
            raise ResponseTimeout(
                f"mpklink response timed out after {eff}s")
        self._resp_flag = False
        if self._resp_rows == 0:
            if self._error is not None:
                err, self._error = self._error, None
                raise err
            raise TransportError("server rejected frame (guard failure)")
        self.registry.check(self.key_client, READ)
        # read-only view into the response region — valid until the next
        # exchange on this session overwrites it (the session is serial)
        out = framing.verify_view(self._region_resp[: self._resp_rows],
                                  seed=self.seed, expect_seq=self._seq,
                                  mac_impl=self._mac)
        self._seq += 1
        return out

    # -- ring (pipelined) path --------------------------------------------
    def _ring_obj(self) -> _Ring:
        if self._ring is None:
            self._ring = _Ring(self.transport.ring_slots, self._slk)
        return self._ring

    def _stage_frame(self, frame: np.ndarray, buf=None) -> int:
        """Write one sealed frame into the next free slot (STAGED — not yet
        visible to the service; flush() publishes). The slot remembers the
        frame's sequence number so the drain verifies exactly what the
        client committed to. ``buf`` is the arena buffer backing ``frame``
        (recycled once the service has consumed the request); externally
        built frames pass None."""
        self._check_usable()
        ring = self._ring_obj()
        with ring.cv:
            t = self._tickets
            slot = ring.slots[t % ring.capacity]
            if slot.state != _FREE:
                if buf is not None:
                    self.transport.arena.release(buf)
                raise CapacityError(
                    f"ring full ({ring.capacity} messages in flight) — "
                    f"poll() before submitting more")
            self._tickets += 1
            self._outstanding.add(t)
            slot.ticket = t
            slot.frame = frame
            slot.req = buf
            slot.seq = self._seq
            slot.error = None
            slot.resp_frame = None
            slot.resp = None
            slot.state = _STAGED
        self._seq += 1
        return t

    def submit(self, payload: np.ndarray,
               timeout: Optional[float] = None) -> int:
        payload = np.asarray(payload)
        self._check_usable()
        # credit-based backpressure BEFORE paying for a slot + seal + MAC,
        # clamped to the caller's per-call budget
        self._await_credit(self._ring_obj(), None if timeout is None
                           else time.monotonic() + timeout)
        if framing.ZERO_COPY:
            # stage the frame straight into a recycled arena slot: one
            # payload write, no build/concat staging
            buf = self.transport.arena.acquire(
                framing.frame_rows(np.ascontiguousarray(payload).nbytes))
            rows = framing.seal_into(buf, payload, seed=self.seed,
                                     seq=self._seq, mac_impl=self._mac)
            return self._stage_frame(buf[:rows], buf=buf)
        frame = framing.build_frame(payload, seed=self.seed,
                                    seq=self._seq, mac_impl=self._mac)
        return self._stage_frame(frame)

    def flush(self):
        """Publish all staged slots with ONE batched key-sync round trip
        (chunk-scaled for paper-faithful mpklink: ceil(bytes/chunk) syncs
        over the published frames — mpklink_opt's huge chunk makes that
        exactly one). This is the 'batched epoch grant' that lets k frames
        cross the region for O(1) synchronization instead of O(k)."""
        ring = self._ring
        if ring is None or self._crashed:   # a dead thread can't ack syncs
            return
        staged_bytes = 0
        with ring.cv:
            for s in ring.slots:
                if s.state == _STAGED:
                    s.state = _PUBLISHED
                    staged_bytes += s.frame.nbytes
        if not staged_bytes:
            return
        syncs = max(1, -(-staged_bytes // self.chunk))
        for _ in range(syncs):
            self._final = False         # never mistaken for a lockstep frame
            self._sync_key(self.key_client, WRITE)

    def _drain_ring(self):
        """Service side: consume published slots in ticket order. The whole
        drained batch is MAC-verified in one vectorized pass, handlers run
        per message (typed per-slot errors), and all responses are sealed in
        one vectorized pass under ONE response-side key sync."""
        ring = self._ring
        if ring is None:
            return
        while True:
            batch: List[_RingSlot] = []
            with ring.cv:
                while True:
                    slot = ring.slots[ring.head % ring.capacity]
                    if slot.state != _PUBLISHED or slot.ticket != ring.head:
                        break
                    batch.append(slot)
                    ring.head += 1
            if not batch:
                return
            arena = self.transport.arena
            self.registry.check(self.key_server, READ)
            parsed = framing.verify_batch(
                [s.frame for s in batch], seed=self.seed,
                seqs=[s.seq for s in batch], strict=False,
                mac_impl=self._batch_mac)
            self.registry.check(self.key_server, WRITE)
            ok_slots, responses = [], []
            for slot, res in zip(batch, parsed):
                if isinstance(res, framing.FrameError):
                    with ring.cv:
                        arena.release(slot.req)
                        slot.req = None
                        slot.error = res
                        slot.state = _DONE
                        self._bell_cli.ring_owned()     # fail fast per slot
                    continue
                try:                    # handler errors stay per-slot typed;
                    resp = np.ascontiguousarray(self.handler(res)) \
                        .view(np.uint8).reshape(-1)
                except DropResponse:    # injected wire drop: never completes
                    with ring.cv:
                        arena.release(slot.req)
                        slot.req = None
                        slot.state = _DROPPED
                    continue
                except Exception as e:
                    with ring.cv:
                        arena.release(slot.req)
                        slot.req = None
                        slot.error = e
                        slot.state = _DONE
                        self._bell_cli.ring_owned()     # fail fast per slot
                    continue
                ok_slots.append(slot)
                responses.append(resp)
            if ok_slots:
                if framing.ZERO_COPY:
                    # responses sealed straight into recycled arena slots,
                    # MACs still ONE fused vectorized pass
                    rbufs = [arena.acquire(framing.frame_rows(r.nbytes))
                             for r in responses]
                    rows_list = framing.seal_into_batch(
                        rbufs, responses, seed=self.seed,
                        seqs=[s.seq for s in ok_slots],
                        mac_impl=self._batch_mac)
                    rframes = [b[:r] for b, r in zip(rbufs, rows_list)]
                else:
                    rbufs = [None] * len(ok_slots)
                    rframes = framing.seal_batch(
                        responses, seed=self.seed,
                        seqs=[s.seq for s in ok_slots],
                        mac_impl=self._batch_mac)
                self._bump_sync()       # ONE response-side key sync for the
                                        # whole drained batch
                with ring.cv:
                    for slot, rf, rb in zip(ok_slots, rframes, rbufs):
                        # request slot consumed (a response that aliased the
                        # request payload has been copied out by the seal)
                        arena.release(slot.req)
                        slot.req = None
                        slot.resp_frame = rf
                        slot.resp = rb
                        slot.state = _DONE
                    # ONE doorbell ring covers every poller of the pass —
                    # the wakeup twin of the batched response key sync
                    self._bell_cli.ring_owned()

    def _slot_take(self, slot: _RingSlot):
        rframe, slot.resp_frame = slot.resp_frame, None
        rbuf, slot.resp = slot.resp, None
        return rframe, slot.seq, rbuf

    def _collect(self, ticket: int, timeout: Optional[float] = None):
        """Wait for ``ticket``'s slot to complete; return its raw response
        (frame, seq, arena_buf) — MAC not yet verified; poll()/call_batch()
        do that, scalar or vectorized. Frees the slot."""
        err, extracted = self._ring_redeem(ticket, timeout)
        if err is not None:
            raise err
        return extracted

    def poll(self, ticket: int, timeout: Optional[float] = None) -> np.ndarray:
        self._check_pollable()
        self.flush()                    # poll implies publish
        rframe, seq, rbuf = self._collect(ticket, timeout)
        self.registry.check(self.key_client, READ)
        try:
            out = framing.verify_view(rframe, seed=self.seed, expect_seq=seq,
                                      mac_impl=self._mac)
        except framing.FrameError:
            self.transport.arena.release(rbuf)
            raise
        if rbuf is not None:            # slot recycles when the view dies
            self.transport.arena.release_on_collect(out, rbuf)
        return out

    def call_batch(self, payloads, return_exceptions: bool = False):
        """Ring-pipelined batch: frames are sealed in one vectorized MAC
        pass, staged into the ring, published with one flush (one key sync),
        and the responses are verified in one vectorized pass. Batches
        larger than the ring run in ring-sized windows (one sync each)."""
        self._check_usable()
        cap = self._ring_obj().capacity
        out: List = []
        first: Optional[BaseException] = None
        for start in range(0, len(payloads), cap):
            window = [np.ascontiguousarray(np.asarray(p))
                      for p in payloads[start:start + cap]]
            if framing.ZERO_COPY:
                # one fused MAC pass, frames sealed straight into arena slots
                arena = self.transport.arena
                bufs = [arena.acquire(framing.frame_rows(p.nbytes))
                        for p in window]
                rows_list = framing.seal_into_batch(
                    bufs, window, seed=self.seed,
                    seqs=[self._seq + i for i in range(len(window))],
                    mac_impl=self._batch_mac)
                tickets = [self._stage_frame(b[:r], buf=b)
                           for b, r in zip(bufs, rows_list)]
            else:
                frames = framing.seal_batch(window, seed=self.seed,
                                            start_seq=self._seq,
                                            mac_impl=self._batch_mac)
                tickets = [self._stage_frame(f) for f in frames]
            self.flush()
            collected: List = []
            for t in tickets:
                try:
                    collected.append(self._collect(t))
                except Exception as e:  # noqa: PERF203 — per-ticket fate
                    collected.append(e)
            ok = [(i, fs) for i, fs in enumerate(collected)
                  if not isinstance(fs, BaseException)]
            if ok:
                self.registry.check(self.key_client, READ)
                verified = framing.verify_batch(
                    [f for _, (f, _, _) in ok], seed=self.seed,
                    seqs=[q for _, (_, q, _) in ok], strict=False,
                    mac_impl=self._batch_mac)
                for (i, (_, _, rbuf)), v in zip(ok, verified):
                    collected[i] = v
                    if isinstance(v, framing.FrameError):
                        self.transport.arena.release(rbuf)
                    elif rbuf is not None:  # recycle when the view dies
                        self.transport.arena.release_on_collect(v, rbuf)
            for item in collected:
                if isinstance(item, BaseException) and first is None:
                    first = item
                out.append(item)
        if first is not None and not return_exceptions:
            raise first
        return out


class MPKLinkTransport(Transport):
    """Shared region + MPK emulation (paper-faithful).

    Establishment (once per session): the client enrolls with the CA (key
    pair + proof-of-possession), the CA verifies certificates and grants a
    channel domain shared with the server; data-plane MAC seed = domain tag
    ⊕ epoch-mix ⊕ DH session key. Each session therefore holds its own
    domain, keys and seed — a frame from one session fails the guard on any
    other.

    Per message: the payload is framed (framing.build_frame — header + MAC)
    and moved through the session's region in CHUNK-sized pieces; every
    chunk performs one PKRU synchronization round trip (writer updates the
    shared PKRU word, reader acknowledges) — the paper's per-chunk key sync.
    The receiver re-derives the MAC and rejects tampered/foreign frames.

    ``syncs_per_message ≈ ceil(frame_bytes / chunk)`` is what produces the
    paper's large-payload cliff; MPKLinkOptTransport batches it to 1
    (the beyond-paper fix, EXPERIMENTS.md §Perf).

    ``registry``/``ca`` may be shared (e.g. by the service gateway) so that
    transport channels and service domains live in ONE key table;
    ``max_keys`` lifts the 16-domain x86 limit for many-client runs
    (documented deviation — the emulation has no hardware key file).
    """

    name = "mpklink"
    CHUNK = 64 * 1024

    def __init__(self, handler: Handler, chunk: Optional[int] = None,
                 mac_impl: Callable = fast_mac, *,
                 registry: Optional[KeyRegistry] = None,
                 ca: Optional[CertificateAuthority] = None,
                 max_keys: Optional[int] = None,
                 server_name: str = "svc-server",
                 timeout: float = 120.0,
                 ring_slots: Optional[int] = None,
                 credit_wait: Optional[float] = None):
        super().__init__(handler, timeout=timeout, ring_slots=ring_slots,
                         credit_wait=credit_wait)
        self.chunk = chunk or self.CHUNK
        self._mac = mac_impl
        self.server_name = server_name
        standalone = registry is None and ca is None
        self.registry = registry or KeyRegistry(max_keys=max_keys or 16, seed=7)
        self.ca = ca or CertificateAuthority(self.registry)
        if server_name not in self.ca._services:
            self._kp_server, _ = enroll(self.ca, server_name)
        self.sync_count = 0                        # aggregate across sessions
        self._sync_lock = threading.Lock()
        if standalone:
            # eager default session: keeps the seed's single-client attribute
            # surface (domain / seed / keys inspectable before start()).
            # With a shared registry/CA (gateway deployments) sessions come
            # only from connect() — no key-table slot or CA identity is
            # consumed for a client that will never be used.
            d = self._make_session("svc-client")
            with self._slock:
                self._sessions.append(d)
            self._default = d
            self._on_new_default()

    def _on_new_default(self):
        d = self._default
        self._kp_client = d._kp
        self.domain = d.domain
        self.key_client = d.key_client
        self.key_server = d.key_server
        self.seed = d.seed

    def _bump_sync(self, n: int = 1):
        with self._sync_lock:
            self.sync_count += n
        framing.STATS.bump(key_syncs=n)

    @property
    def _seq(self) -> int:
        return self._default._seq if self._default is not None else 0

    def _make_session(self, name):
        return MPKLinkSession(self, name)


class MPKLinkOptTransport(MPKLinkTransport):
    """Beyond-paper MPKLink: ONE key synchronization per message (batched
    epoch grant over the whole frame) instead of one per chunk. The MAC and
    capability checks are unchanged — same security envelope, the cliff
    comes out of the sync schedule, not the protection."""

    name = "mpklink_opt"

    def __init__(self, handler: Handler, mac_impl: Callable = fast_mac, **kw):
        kw.setdefault("chunk", 1 << 62)
        super().__init__(handler, mac_impl=mac_impl, **kw)
