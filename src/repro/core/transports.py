"""The paper's IPC transport zoo, reproduced measurably on CPU (§VI).

Two "microservices" run as threads of one master process (exactly the
paper's final design — their separate-process attempt segfaulted, §VI) and
exchange a request/response through one of:

  pipe        two unidirectional OS pipes (the named-pipe setup of §VI;
              anonymous pipes share the same kernel FIFO path, minus the
              filesystem name)
  uds         one bidirectional AF_UNIX stream socket pair
  shm         two raw shared-memory regions (req/resp) with metadata
              signalling and a FIXED capacity — faithfully fails for large
              payloads like the paper's baseline (incapable ≥100k words)
  grpc_sim    the REST/gRPC stand-in: msgpack serialization (protobuf
              analogue) + HTTP/2-style 9-byte frame headers per 16 KiB DATA
              frame + a 64 KiB flow-control window with WINDOW_UPDATE acks
  mpklink     shared memory region + MPK emulation: per-chunk PKRU
              synchronization ping-pong between the threads (the paper's
              key-sync overhead — the large-payload cliff), domain-seeded
              MAC over the message, CA-verified endpoints
  mpklink_opt beyond-paper: ONE key sync per message (batched epoch),
              vectorized MAC — the cliff removed (EXPERIMENTS.md §Perf)

Adaptation notes (single-core container):
  * the paper polls shared metadata; busy-spin on one core inverts results,
    so signalling uses threading.Event — the *count* of synchronization
    round-trips per message is preserved exactly, which is what produces
    the paper's scaling behaviour;
  * thread-based + anonymous buffers mirrors the paper's single-process
    mmap design.
"""
from __future__ import annotations

import os
import socket
import struct
import threading
import time
from typing import Callable, Optional

import msgpack
import numpy as np

from repro.core import framing
from repro.core.ca import CertificateAuthority, enroll
from repro.core.domains import KeyRegistry, READ, WRITE, RW, mac_seed
from repro.kernels.ref import MAC_PRIME, MAC_INIT, _FOLD_POWERS

Handler = Callable[[np.ndarray], np.ndarray]


class TransportError(RuntimeError):
    pass


class CapacityError(TransportError):
    """Raised when a fixed-capacity transport cannot hold the payload."""


# ---------------------------------------------------------------------------
# fast MAC (vectorized twin of framing._mac_np — bit-identical)
# ---------------------------------------------------------------------------

def fast_mac(payload_u32: np.ndarray, seed: int, block_rows: int = 65536) -> int:
    """Horner hash over rows, vectorized: h_n = INIT·P^n + Σ row_r·P^(n-1-r).
    uint64 wraparound keeps the low 32 bits exact (2^32 | 2^64).
    Bit-identical to framing._mac_np (tests/test_framing.py asserts it)."""
    n = payload_u32.shape[0]
    h = (np.full(framing.LANES, MAC_INIT, np.uint64) + np.uint64(seed & 0xFFFFFFFF))
    with np.errstate(over="ignore"):
        for s in range(0, n, block_rows):
            blk = payload_u32[s:s + block_rows].astype(np.uint64)
            m = blk.shape[0]
            # pw = [P^(m-1), ..., P, 1]
            pw = np.full(m, MAC_PRIME, np.uint64)
            pw[0] = 1
            pw = np.cumprod(pw)[::-1]
            p_m = np.uint64((int(pw[0]) * MAC_PRIME) & 0xFFFFFFFFFFFFFFFF)  # P^m
            h = (h * p_m + (blk * pw[:, None]).sum(axis=0, dtype=np.uint64)) \
                & np.uint64(0xFFFFFFFF)
    return int((h * _FOLD_POWERS.astype(np.uint64)).sum(dtype=np.uint64)
               & np.uint64(0xFFFFFFFF))


# ---------------------------------------------------------------------------
# base: request/response over a byte stream
# ---------------------------------------------------------------------------

_LEN = struct.Struct("<Q")


def _write_fd(fd: int, data: memoryview):
    while data:
        n = os.write(fd, data[: 1 << 20])
        data = data[n:]


def _read_fd(fd: int, n: int) -> bytearray:
    buf = bytearray(n)
    view = memoryview(buf)
    got = 0
    while got < n:
        chunk = os.read(fd, min(n - got, 1 << 20))
        if not chunk:
            raise TransportError("pipe closed")
        view[got:got + len(chunk)] = chunk
        got += len(chunk)
    return buf


class _ThreadServer:
    """Runs handler requests on a dedicated 'microservice' thread."""

    def __init__(self, handler: Handler):
        self.handler = handler
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    def start(self):
        self._thread = threading.Thread(target=self._serve, daemon=True)
        self._thread.start()

    def close(self):
        self._stop.set()
        self._wake()
        if self._thread:
            self._thread.join(timeout=5)

    def _wake(self):
        pass

    def _serve(self):
        raise NotImplementedError


# ---------------------------------------------------------------------------
# 1. OS pipes (two unidirectional)
# ---------------------------------------------------------------------------

class PipeTransport(_ThreadServer):
    name = "pipe"

    def __init__(self, handler: Handler):
        super().__init__(handler)
        self._c2s = os.pipe()
        self._s2c = os.pipe()

    def _serve(self):
        while not self._stop.is_set():
            try:
                n = _LEN.unpack(bytes(_read_fd(self._c2s[0], 8)))[0]
            except TransportError:
                return
            if n == 0:
                return
            req = np.frombuffer(_read_fd(self._c2s[0], n), np.uint8)
            resp = self.handler(req)
            raw = resp.view(np.uint8).reshape(-1)
            _write_fd(self._s2c[1], memoryview(_LEN.pack(raw.nbytes)))
            _write_fd(self._s2c[1], memoryview(raw))

    def _wake(self):
        try:
            os.write(self._c2s[1], _LEN.pack(0))
        except OSError:
            pass

    def request(self, payload: np.ndarray) -> np.ndarray:
        raw = payload.view(np.uint8).reshape(-1)
        _write_fd(self._c2s[1], memoryview(_LEN.pack(raw.nbytes)))
        _write_fd(self._c2s[1], memoryview(raw))
        n = _LEN.unpack(bytes(_read_fd(self._s2c[0], 8)))[0]
        return np.frombuffer(_read_fd(self._s2c[0], n), np.uint8)

    def close(self):
        super().close()
        for fd in (*self._c2s, *self._s2c):
            try:
                os.close(fd)
            except OSError:
                pass


# ---------------------------------------------------------------------------
# 2. Unix domain sockets (one bidirectional)
# ---------------------------------------------------------------------------

class UDSTransport(_ThreadServer):
    name = "uds"

    def __init__(self, handler: Handler):
        super().__init__(handler)
        self._client, self._server = socket.socketpair(socket.AF_UNIX, socket.SOCK_STREAM)

    @staticmethod
    def _recv_exact(sock: socket.socket, n: int) -> bytearray:
        buf = bytearray(n)
        view = memoryview(buf)
        got = 0
        while got < n:
            r = sock.recv_into(view[got:], n - got)
            if r == 0:
                raise TransportError("socket closed")
            got += r
        return buf

    def _serve(self):
        while not self._stop.is_set():
            try:
                n = _LEN.unpack(bytes(self._recv_exact(self._server, 8)))[0]
            except (TransportError, OSError):
                return
            if n == 0:
                return
            req = np.frombuffer(self._recv_exact(self._server, n), np.uint8)
            resp = self.handler(req).view(np.uint8).reshape(-1)
            self._server.sendall(_LEN.pack(resp.nbytes))
            self._server.sendall(resp)

    def _wake(self):
        try:
            self._client.sendall(_LEN.pack(0))
        except OSError:
            pass

    def request(self, payload: np.ndarray) -> np.ndarray:
        raw = payload.view(np.uint8).reshape(-1)
        self._client.sendall(_LEN.pack(raw.nbytes))
        self._client.sendall(raw)
        n = _LEN.unpack(bytes(self._recv_exact(self._client, 8)))[0]
        return np.frombuffer(self._recv_exact(self._client, n), np.uint8)

    def close(self):
        super().close()
        self._client.close()
        self._server.close()


# ---------------------------------------------------------------------------
# 3. raw shared memory, fixed capacity (the paper's failing baseline)
# ---------------------------------------------------------------------------

class ShmTransport(_ThreadServer):
    """Two regions (req/resp) + length words + ready events. Capacity is fixed
    at construction — ≥capacity payloads raise CapacityError, reproducing the
    paper's observation that baseline shm "is incapable of handling requests
    involving 100,000 words or more"."""

    name = "shm"
    DEFAULT_CAPACITY = 512 * 1024      # ≈70k words of ~7 chars — fails at 100k

    def __init__(self, handler: Handler, capacity: int = DEFAULT_CAPACITY):
        super().__init__(handler)
        self.capacity = capacity
        self._req = np.zeros(capacity, np.uint8)
        self._resp = np.zeros(capacity, np.uint8)
        self._req_len = 0
        self._resp_len = 0
        self._req_ready = threading.Event()
        self._resp_ready = threading.Event()

    def _serve(self):
        while not self._stop.is_set():
            if not self._req_ready.wait(timeout=0.5):
                continue
            self._req_ready.clear()
            if self._stop.is_set():
                return
            req = self._req[: self._req_len]
            resp = self.handler(req).view(np.uint8).reshape(-1)
            self._resp[: resp.nbytes] = resp
            self._resp_len = resp.nbytes
            self._resp_ready.set()

    def _wake(self):
        self._req_ready.set()

    def request(self, payload: np.ndarray) -> np.ndarray:
        raw = payload.view(np.uint8).reshape(-1)
        if raw.nbytes > self.capacity:
            raise CapacityError(
                f"shm region ({self.capacity}B) cannot hold {raw.nbytes}B payload")
        self._req[: raw.nbytes] = raw
        self._req_len = raw.nbytes
        self._req_ready.set()
        self._resp_ready.wait()
        self._resp_ready.clear()
        return self._resp[: self._resp_len].copy()


# ---------------------------------------------------------------------------
# 4. gRPC simulation (serialization + HTTP/2 framing + flow control)
# ---------------------------------------------------------------------------

class GrpcSimTransport(_ThreadServer):
    """msgpack body + 9-byte frame header per 16 KiB DATA frame + 64 KiB
    flow-control window with WINDOW_UPDATE acks — the protocol overhead the
    paper attributes to network-style IPC for co-located services."""

    name = "grpc_sim"
    FRAME = 16 * 1024
    WINDOW = 64 * 1024
    _HDR = struct.Struct("<IBI")       # length, type, stream_id

    def __init__(self, handler: Handler):
        super().__init__(handler)
        self._client, self._server = socket.socketpair(socket.AF_UNIX, socket.SOCK_STREAM)
        for s in (self._client, self._server):
            s.setsockopt(socket.SOL_SOCKET, socket.SO_SNDBUF, 1 << 20)

    def _send_msg(self, sock: socket.socket, obj):
        body = msgpack.packb(obj, use_bin_type=True)
        sent = 0
        credit = self.WINDOW
        while sent < len(body):
            if credit <= 0:                      # wait for WINDOW_UPDATE
                hdr = UDSTransport._recv_exact(sock, self._HDR.size)
                ln, typ, _ = self._HDR.unpack(bytes(hdr))
                assert typ == 8, "expected WINDOW_UPDATE"
                credit += ln
            n = min(self.FRAME, len(body) - sent, credit)
            sock.sendall(self._HDR.pack(n, 0, 1))
            sock.sendall(body[sent:sent + n])
            sent += n
            credit -= n
        sock.sendall(self._HDR.pack(0, 1, 1))    # END_STREAM

    def _recv_msg(self, sock: socket.socket):
        chunks = []
        consumed = 0
        while True:
            hdr = UDSTransport._recv_exact(sock, self._HDR.size)
            ln, typ, _ = self._HDR.unpack(bytes(hdr))
            if typ == 1:
                break
            if typ == 8:
                continue                          # WINDOW_UPDATE for our own
                                                  # sends — headers only
            chunks.append(bytes(UDSTransport._recv_exact(sock, ln)))
            consumed += ln
            if consumed >= self.WINDOW // 2:     # grant more window
                sock.sendall(self._HDR.pack(consumed, 8, 1))
                consumed = 0
        return msgpack.unpackb(b"".join(chunks), raw=False)

    def _serve(self):
        while not self._stop.is_set():
            try:
                msg = self._recv_msg(self._server)
            except (TransportError, OSError, AssertionError):
                return
            if msg.get("op") == "stop":
                return
            req = np.frombuffer(msg["data"], np.uint8)
            resp = self.handler(req).view(np.uint8).reshape(-1)
            self._send_msg(self._server, {"status": 0, "data": resp.tobytes()})

    def _wake(self):
        try:
            self._send_msg(self._client, {"op": "stop"})
        except OSError:
            pass

    def request(self, payload: np.ndarray) -> np.ndarray:
        raw = payload.view(np.uint8).reshape(-1)
        self._send_msg(self._client, {"op": "count", "data": raw.tobytes()})
        resp = self._recv_msg(self._client)
        return np.frombuffer(resp["data"], np.uint8)

    def close(self):
        super().close()
        self._client.close()
        self._server.close()


# ---------------------------------------------------------------------------
# 5. MPKLink (paper-faithful) and 6. MPKLink-opt (beyond paper)
# ---------------------------------------------------------------------------

class MPKLinkTransport(_ThreadServer):
    """Shared region + MPK emulation (paper-faithful).

    Establishment (once): both services enroll with the CA (key pairs +
    proof-of-possession), the CA verifies certificates and grants a channel
    domain; data-plane MAC seed = domain tag ⊕ epoch-mix ⊕ DH session key.

    Per message: the payload is framed (framing.build_frame — header + MAC)
    and moved through the region in CHUNK-sized pieces; every chunk performs
    one PKRU synchronization round trip (writer updates the shared PKRU
    word, reader acknowledges) — the paper's per-chunk key sync. The
    receiver re-derives the MAC and rejects tampered/foreign frames.

    ``syncs_per_message ≈ ceil(frame_bytes / chunk)`` is what produces the
    paper's large-payload cliff; MPKLinkOptTransport batches it to 1
    (the beyond-paper fix, EXPERIMENTS.md §Perf).
    """

    name = "mpklink"
    CHUNK = 64 * 1024

    def __init__(self, handler: Handler, chunk: Optional[int] = None,
                 mac_impl: Callable = fast_mac):
        super().__init__(handler)
        self.chunk = chunk or self.CHUNK
        self._mac = mac_impl
        # --- control plane: CA handshake -----------------------------------
        self.registry = KeyRegistry(seed=7)
        self.ca = CertificateAuthority(self.registry)
        self._kp_client, _ = enroll(self.ca, "svc-client")
        self._kp_server, _ = enroll(self.ca, "svc-server")
        self.domain, self.key_client, self.key_server = \
            self.ca.grant_channel("svc-client", "svc-server", RW)
        sess = self.ca.session_seed(self._kp_client.private, "svc-server")
        self.seed = mac_seed(self.domain, self.registry.epoch(self.domain)) ^ sess
        # --- data plane: shared regions + PKRU "register file" ---------------
        self._region_req = np.zeros((0, framing.LANES), np.uint32)
        self._region_resp = np.zeros((0, framing.LANES), np.uint32)
        self._pkru = np.zeros(2, np.uint64)        # [pkru_word, epoch]
        self._chunk_ready = threading.Event()
        self._chunk_ack = threading.Event()
        self._resp_ready = threading.Event()
        self._final = False                        # last chunk of a request?
        self._req_rows = 0
        self._resp_rows = 0
        self._seq = 0
        self.sync_count = 0                        # measured key syncs (telemetry)

    # -- one PKRU synchronization round trip (writer side) ---------------------
    def _sync_key(self, key, rights):
        self.registry.check(key, rights)           # staging-time capability check
        self._pkru[0] = self.registry.pkru_word((key,))
        self._pkru[1] = self.registry.epoch(self.domain)
        self.sync_count += 1
        self._chunk_ready.set()
        self._chunk_ack.wait()
        self._chunk_ack.clear()

    def _serve(self):
        while not self._stop.is_set():
            if not self._chunk_ready.wait(timeout=0.5):
                continue
            self._chunk_ready.clear()
            if self._stop.is_set():
                self._chunk_ack.set()
                return
            final = self._final                    # read before acking
            self._chunk_ack.set()                  # reader loads PKRU word
            if not final:
                continue
            # full frame visible → verify + handle + respond
            self.registry.check(self.key_server, READ)
            try:
                req = framing.parse_frame(self._region_req[: self._req_rows],
                                          seed=self.seed, expect_seq=self._seq,
                                          mac_impl=self._mac)
            except framing.FrameError:
                self._resp_rows = 0
                self._resp_ready.set()
                continue
            self.registry.check(self.key_server, WRITE)
            resp = self.handler(req).view(np.uint8).reshape(-1)
            rframe = framing.build_frame(resp, seed=self.seed, seq=self._seq,
                                         mac_impl=self._mac)
            rows = rframe.shape[0]
            if self._region_resp.shape[0] < rows:
                self._region_resp = np.zeros((rows, framing.LANES), np.uint32)
            self._region_resp[:rows] = rframe
            self._resp_rows = rows
            self.sync_count += 1                   # response-side key sync
            self._resp_ready.set()

    def _wake(self):
        self._final = False
        self._chunk_ready.set()
        self._chunk_ack.set()

    def request(self, payload: np.ndarray) -> np.ndarray:
        frame = framing.build_frame(payload, seed=self.seed, seq=self._seq,
                                    mac_impl=self._mac)
        rows = frame.shape[0]
        if self._region_req.shape[0] < rows:
            self._region_req = np.zeros((rows, framing.LANES), np.uint32)
        chunk_rows = max(1, self.chunk // (framing.LANES * 4))
        for s in range(0, rows, chunk_rows):
            e = min(rows, s + chunk_rows)
            self._region_req[s:e] = frame[s:e]
            self._req_rows = rows
            self._final = e >= rows
            self._sync_key(self.key_client, WRITE)
        self._resp_ready.wait()
        self._resp_ready.clear()
        if self._resp_rows == 0:
            raise TransportError("server rejected frame (guard failure)")
        self.registry.check(self.key_client, READ)
        out = framing.parse_frame(self._region_resp[: self._resp_rows],
                                  seed=self.seed, expect_seq=self._seq,
                                  mac_impl=self._mac)
        self._seq += 1
        return out


class MPKLinkOptTransport(MPKLinkTransport):
    """Beyond-paper MPKLink: ONE key synchronization per message (batched
    epoch grant over the whole frame) instead of one per chunk. The MAC and
    capability checks are unchanged — same security envelope, the cliff
    comes out of the sync schedule, not the protection."""

    name = "mpklink_opt"

    def __init__(self, handler: Handler, mac_impl: Callable = fast_mac):
        super().__init__(handler, chunk=1 << 62, mac_impl=mac_impl)
