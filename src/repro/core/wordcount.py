"""The paper's benchmark workload (§VI): a distributed word count.

Service 1 (client) reads text, serializes a request, sends it to Service 2
(server); the server deserializes, counts words, and returns the count.
Text generation is deterministic (seeded) and vectorized; counting is the
classic transition count (space→non-space), vectorized so the handler cost
doesn't drown the IPC cost being measured.
"""
from __future__ import annotations

import numpy as np

_WORD_MIN, _WORD_MAX = 3, 8          # word lengths, single-space separated


def make_text(n_words: int, seed: int = 0) -> np.ndarray:
    """Deterministic ASCII text with exactly ``n_words`` words, as uint8."""
    rng = np.random.default_rng(seed)
    lengths = rng.integers(_WORD_MIN, _WORD_MAX + 1, size=n_words)
    total = int(lengths.sum()) + max(0, n_words - 1)
    out = np.full(total, ord(" "), np.uint8)
    # word start offsets: cumulative lengths + separators
    starts = np.zeros(n_words, np.int64)
    starts[1:] = np.cumsum(lengths[:-1] + 1)
    letters = rng.integers(ord("a"), ord("z") + 1, size=int(lengths.sum()),
                           dtype=np.uint8)
    # scatter letters into non-space slots
    idx = np.arange(total)
    is_space = np.ones(total, bool)
    for off in range(_WORD_MAX):
        sel = starts + off
        ok = off < lengths
        is_space[sel[ok]] = False
    out[~is_space] = letters
    return out


def count_words(text_u8: np.ndarray) -> np.ndarray:
    """uint8 text → (1,) uint64 word count (space→non-space transitions)."""
    if text_u8.size == 0:
        return np.zeros(1, np.uint64)
    nonspace = text_u8 != ord(" ")
    starts = np.count_nonzero(nonspace[1:] & ~nonspace[:-1]) + int(nonspace[0])
    return np.asarray([starts], np.uint64)


def wordcount_handler(req: np.ndarray) -> np.ndarray:
    return count_words(np.frombuffer(req.tobytes(), np.uint8))


def parse_count(resp: np.ndarray) -> int:
    return int(np.frombuffer(resp.tobytes(), np.uint64)[0])
