"""MPKLinkFabric — the paper's protected shared-buffer channels, mapped onto
a TPU mesh.

The baseline model path lets XLA-GSPMD insert generic collectives (the
"network stack"). The fabric is the MPKLink alternative: *explicit*,
pre-established, capability-checked channels between device groups, lowered
to the minimal collective (ppermute / psum_scatter / all_to_all) inside
``shard_map``. Three properties carry over from the paper:

1. **Establishment before use** — a channel is created once (CA-verified
   endpoints, domain allocated, keys issued). Using a channel without its
   key raises AccessViolation *at trace time* — the staging-time PKRU.
2. **Guarded transfer** — optionally every message carries a MAC row seeded
   by domain tag ⊕ epoch; receivers verify on-device (kernels/mpk_guard on
   TPU, mac_ref in the jnp path) and surface an ok-flag that the runtime's
   fault-tolerance layer consumes (a failed guard triggers step retry —
   corrupted-collective detection).
3. **Explicit sync schedule** — ring collectives are built from chained
   ppermutes, so the number of neighbor exchanges per step is a visible,
   tunable quantity (the paper's per-chunk key-sync count), not compiler
   magic. The §Perf hillclimb tunes exactly this.

All functions here must be called INSIDE shard_map with the named axis
present. (jax.lax.psum etc. with axis names.)
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.ca import CertificateAuthority, enroll
from repro.core.domains import (AccessViolation, DomainKey, KeyRegistry,
                                ProtectionDomain, RW, mac_seed)
from repro.kernels.ref import mac_ref
from repro.utils import axis_size, match_vma

LANES = 128


# ---------------------------------------------------------------------------
# channel establishment (host / trace time)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FabricChannel:
    name: str
    axis: str                  # mesh axis the channel spans
    domain: ProtectionDomain
    epoch: int
    guard: bool                # runtime MAC verification on/off

    @property
    def seed(self) -> int:
        return mac_seed(self.domain, self.epoch)


class MPKLinkFabric:
    def __init__(self, mesh, *, guard: bool = False, max_channels: int = 64):
        self.mesh = mesh
        self.guard = guard
        # TPUs have no 16-domain hardware limit; allow more channels (DESIGN.md)
        self.registry = KeyRegistry(max_keys=max_channels)
        self.ca = CertificateAuthority(self.registry)
        self._keys = {}

    def establish(self, name: str, axis: str,
                  guard: Optional[bool] = None) -> Tuple[FabricChannel, DomainKey]:
        """CA-verified channel over a mesh axis. Returns (channel, key)."""
        a, b = f"{name}@{axis}:even", f"{name}@{axis}:odd"
        enroll(self.ca, a)
        enroll(self.ca, b)
        dom, key, _ = self.ca.grant_channel(a, b, RW)
        chan = FabricChannel(name, axis, dom, self.registry.epoch(dom),
                             self.guard if guard is None else guard)
        self._keys[(name, axis)] = key
        return chan, key

    def check(self, chan: FabricChannel, key: DomainKey, rights: int = RW):
        """Trace-time capability check — the zero-cost PKRU analogue."""
        self.registry.check(key, rights)
        if key.domain != chan.domain:
            raise AccessViolation(
                f"key for domain {key.domain.name} used on channel {chan.name}")

    def revoke(self, chan: FabricChannel):
        key = self._keys.pop((chan.name, chan.axis), None)
        if key is not None:
            self.registry.revoke(key)


# ---------------------------------------------------------------------------
# on-device guard (MAC attach / verify)
# ---------------------------------------------------------------------------

def _as_u32_rows(x: jnp.ndarray) -> jnp.ndarray:
    """Bitcast any tensor to (rows, 128) uint32, zero-padded."""
    flat = x.reshape(-1)
    nbits = flat.dtype.itemsize * 8
    if nbits == 32:
        u = jax.lax.bitcast_convert_type(flat, jnp.uint32)
    elif nbits == 16:
        if flat.shape[0] % 2:
            flat = jnp.concatenate([flat, jnp.zeros((1,), flat.dtype)])
        u = jax.lax.bitcast_convert_type(flat.reshape(-1, 2), jnp.uint32)
    elif nbits == 64:
        u = jax.lax.bitcast_convert_type(flat, jnp.uint64)
        u = jnp.stack([(u & 0xFFFFFFFF).astype(jnp.uint32),
                       (u >> 32).astype(jnp.uint32)], -1).reshape(-1)
    else:
        raise ValueError(f"unsupported itemsize {nbits}")
    pad = (-u.shape[0]) % LANES
    if pad:
        u = jnp.concatenate([u, jnp.zeros((pad,), jnp.uint32)])
    return u.reshape(-1, LANES)


def attach_mac(x: jnp.ndarray, seed: int) -> jnp.ndarray:
    """MAC of x's bits under the channel seed (scalar uint32)."""
    return mac_ref(_as_u32_rows(x), jnp.uint32(seed))


def verify_mac(x: jnp.ndarray, mac: jnp.ndarray, seed: int) -> jnp.ndarray:
    """→ ok flag (int32 scalar). Runtime consumes it for retry-on-corruption."""
    return (attach_mac(x, seed) == mac).astype(jnp.int32)


# ---------------------------------------------------------------------------
# guarded collectives (call inside shard_map)
# ---------------------------------------------------------------------------

def _perm(axis_size: int, shift: int):
    return [(i, (i + shift) % axis_size) for i in range(axis_size)]


def neighbor_exchange(fabric: MPKLinkFabric, chan: FabricChannel, key: DomainKey,
                      x: jnp.ndarray, *, shift: int = 1):
    """Ring shift over chan.axis with capability check + optional MAC guard.
    Returns (received, ok_flag)."""
    fabric.check(chan, key)
    n = axis_size(chan.axis)
    perm = _perm(n, shift)
    if not chan.guard:
        return jax.lax.ppermute(x, chan.axis, perm), jnp.int32(1)
    mac = attach_mac(x, chan.seed)
    y = jax.lax.ppermute(x, chan.axis, perm)
    mac_y = jax.lax.ppermute(mac, chan.axis, perm)
    return y, verify_mac(y, mac_y, chan.seed)


def ring_all_gather(fabric: MPKLinkFabric, chan: FabricChannel, key: DomainKey,
                    x: jnp.ndarray, *, axis_index: Optional[jnp.ndarray] = None):
    """All-gather built from n-1 chained neighbor pushes (bandwidth-optimal
    ring; each step is an MPKLink channel hop). Returns (gathered, ok)."""
    fabric.check(chan, key)
    n = axis_size(chan.axis)
    idx = jax.lax.axis_index(chan.axis) if axis_index is None else axis_index

    def body(carry, _):
        buf, cur, ok = carry
        cur, ok_i = neighbor_exchange(fabric, chan, key, cur, shift=1)
        return (buf, cur, ok & ok_i), cur

    init = (x, x, match_vma(jnp.int32(1), x))
    (_, _, ok), rest = jax.lax.scan(body, init, None, length=n - 1)
    # piece j originated at device (idx - j) mod n; roll into position
    parts = jnp.concatenate([x[None], rest], axis=0)         # (n, ...) by hop count
    order = (idx - jnp.arange(n)) % n
    gathered = jnp.zeros((n,) + x.shape, x.dtype).at[order].set(parts)
    return gathered.reshape((n * x.shape[0],) + x.shape[1:]), ok


def reduce_scatter_ring(fabric: MPKLinkFabric, chan: FabricChannel, key: DomainKey,
                        x: jnp.ndarray):
    """Ring reduce-scatter over leading dim (must be divisible by axis size).
    n-1 hops, each hop sends one shard — the collective the §Perf pass uses
    to replace all-reduce where only shards are needed. Returns (shard, ok)."""
    fabric.check(chan, key)
    n = axis_size(chan.axis)
    idx = jax.lax.axis_index(chan.axis)
    shards = x.reshape((n, x.shape[0] // n) + x.shape[1:])

    def body(carry, j):
        acc, ok = carry
        # step j: push the partial for chunk (idx-1-j); what arrives is the
        # partial for chunk (idx-2-j), which is what we push next — after
        # n-1 hops the arriving partial is chunk idx summed over all peers.
        send = jnp.take(shards, (idx - 1 - j) % n, axis=0) + acc
        recv, ok_i = neighbor_exchange(fabric, chan, key, send, shift=1)
        return (recv, ok & ok_i), None

    (acc, ok), _ = jax.lax.scan(
        body, match_vma((jnp.zeros(shards.shape[1:], x.dtype), jnp.int32(1)), x),
        jnp.arange(n - 1))
    own = jnp.take(shards, idx, axis=0)
    return own + acc, ok


def all_to_all(fabric: MPKLinkFabric, chan: FabricChannel, key: DomainKey,
               x: jnp.ndarray, *, split_axis: int, concat_axis: int):
    """EP dispatch/return channel (mixtral/grok token exchange)."""
    fabric.check(chan, key)
    return jax.lax.all_to_all(x, chan.axis, split_axis, concat_axis, tiled=True)


def psum_guarded(fabric: MPKLinkFabric, chan: FabricChannel, key: DomainKey,
                 x: jnp.ndarray):
    fabric.check(chan, key)
    return jax.lax.psum(x, chan.axis)
