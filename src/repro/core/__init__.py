# MPKLink — the paper's primary contribution: protected shared-buffer
# communication for co-located peers. domains.py = software pkey/PKRU,
# framing/signature/ca = message auth + identity, transports.py = the
# measurable CPU reproduction of the paper's IPC zoo, gateway.py = named
# services multiplexed over one transport (per-service domains), fabric.py =
# the distributed (mesh) incarnation used by the training/serving stack.
from repro.core import ca, domains, framing, signature, transports, wordcount
from repro.core.domains import (AccessViolation, DomainKey, KeyRegistry,
                                ProtectionDomain, READ, RW, WRITE, mac_seed)

TRANSPORTS = {
    "pipe": transports.PipeTransport,
    "uds": transports.UDSTransport,
    "shm": transports.ShmTransport,
    "grpc_sim": transports.GrpcSimTransport,
    "mpklink": transports.MPKLinkTransport,
    "mpklink_opt": transports.MPKLinkOptTransport,
}

# process-backed transports (service in a multiprocessing.Process over a
# POSIX shared-memory segment) and the honest REST/socket-RPC baselines —
# kept out of TRANSPORTS so the in-process matrix keeps its semantics;
# gateway name resolution uses the merged ALL_TRANSPORTS
from repro.core import procwire                    # needs transports above
from repro.core.procwire import BASELINE_TRANSPORTS, PROC_TRANSPORTS

ALL_TRANSPORTS = {**TRANSPORTS, **PROC_TRANSPORTS, **BASELINE_TRANSPORTS}

from repro.core import gateway                     # needs TRANSPORTS above
from repro.core.gateway import (CallCoalescer, GatewayClient, Replica,
                                ReplicaRouter, ServiceFleet, ServiceGateway,
                                ServiceHealth, simulate_assignments)
from repro.core import faultwire                   # needs gateway above
from repro.core.faultwire import FaultFabric, FaultPlan, FaultyClient
from repro.core.transports import (ResponseTimeout, ServiceCrashed,
                                   ServiceUnavailable)

__all__ = ["ca", "domains", "framing", "gateway", "faultwire", "procwire",
           "signature",
           "transports", "wordcount", "AccessViolation", "DomainKey",
           "KeyRegistry", "ProtectionDomain", "READ", "RW", "WRITE",
           "mac_seed", "TRANSPORTS", "PROC_TRANSPORTS",
           "BASELINE_TRANSPORTS", "ALL_TRANSPORTS",
           "CallCoalescer", "GatewayClient",
           "Replica", "ReplicaRouter", "ServiceFleet",
           "ServiceGateway", "simulate_assignments",
           "ServiceHealth", "FaultFabric", "FaultPlan", "FaultyClient",
           "ResponseTimeout", "ServiceCrashed", "ServiceUnavailable"]
