"""Service identity + message authentication for MPKLink.

Two layers, mirroring the paper §V:

1. **Service key pairs / CA signatures** (control plane, host Python):
   every microservice registers a public/private key pair with the CA.
   We implement a deterministic Schnorr-style scheme over the multiplicative
   group mod a 61-bit Mersenne prime — NOT cryptographically strong (no
   crypto libs in this container; the paper's artifact likewise used a dev
   scheme), but structurally faithful: sign/verify asymmetry, unforgeability
   against the toy adversary in tests, and the exact CA handshake flow.

2. **Per-message MACs** (data plane, on-device): the Horner-hash MAC from
   kernels/mpk_guard.py, seeded by domain tag ⊕ epoch ⊕ a session key
   derived from BOTH endpoints' identities during channel establishment.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Tuple

P = (1 << 61) - 1          # Mersenne prime 2^61-1
G = 5                       # generator (good enough for the toy group)


def _h(*parts) -> int:
    m = hashlib.sha256()
    for p in parts:
        m.update(str(p).encode())
        m.update(b"|")
    return int.from_bytes(m.digest()[:8], "big") % (P - 1)


@dataclass(frozen=True)
class KeyPair:
    private: int
    public: int

    @staticmethod
    def generate(seed: str) -> "KeyPair":
        priv = _h("priv", seed) or 1
        return KeyPair(priv, pow(G, priv, P))


def sign(priv: int, message: bytes) -> Tuple[int, int]:
    """Deterministic Schnorr: k = H(priv, msg); r = g^k; s = k + H(r, msg)·priv."""
    k = _h("k", priv, message) or 1
    r = pow(G, k, P)
    e = _h("e", r, message)
    s = (k + e * priv) % (P - 1)
    return r, s


def verify(pub: int, message: bytes, sig: Tuple[int, int]) -> bool:
    r, s = sig
    e = _h("e", r, message)
    # g^s == r · pub^e
    return pow(G, s, P) == (r * pow(pub, e, P)) % P


def session_key(priv_a: int, pub_b: int) -> int:
    """Diffie-Hellman shared secret → 32-bit MAC session seed."""
    shared = pow(pub_b, priv_a, P)
    return _h("sess", shared) & 0xFFFFFFFF
