"""Protection domains — the software pkey/PKRU layer.

Intel MPK gives 16 protection keys; a page is tagged with one key and each
thread's PKRU register holds a 2-bit (AD/WD) access field per key, switchable
without a syscall. This module is the staging-time analogue:

* ``ProtectionDomain``  — a pkey: an identity (id 0..15 by default, the x86
  limit, configurable) plus a 32-bit tag word that seeds the data-plane MAC.
* ``DomainKey``         — an unforgeable capability handle to a domain with a
  rights mask (READ/WRITE). Holding the key is the PKRU grant.
* ``KeyRegistry``       — the per-"process" key table: allocates domains,
  issues/revokes keys, and *checks* accesses. Checks happen when the JAX
  program is STAGED (traced), so a violation is impossible at runtime —
  the TPU translation of "permission switch without mprotect" is
  "permission check without any runtime cost at all".
* ``pkru_word()``       — packs the registry's current grants into one
  integer exactly like the PKRU register layout (2 bits per key), used by
  the CPU transports to emulate the paper's key-synchronization traffic.

Revocation is epoch-based: revoking a key bumps the domain epoch; messages
framed under an old epoch fail the guard-kernel MAC check (core/framing.py
mixes the epoch into the MAC seed) — the analogue of flushing stale PKRU
state from other threads.
"""
from __future__ import annotations

import itertools
import threading
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

READ = 0x1
WRITE = 0x2
RW = READ | WRITE

_PKRU_BITS = {0: 0b11, READ: 0b10, WRITE: 0b01, RW: 0b00}
# PKRU semantics: bit0 = access-disable, bit1 = write-disable (0 = allowed)


class AccessViolation(PermissionError):
    """Raised at trace/staging time when a capability check fails."""


@dataclass(frozen=True)
class ProtectionDomain:
    did: int                    # pkey number
    name: str
    tag: int                    # 32-bit tag word fused into the MAC seed

    def __post_init__(self):
        assert 0 <= self.tag < 2 ** 32


@dataclass(frozen=True)
class DomainKey:
    """Capability handle. Unforgeable by construction: only KeyRegistry
    creates these (the nonce is private to the registry)."""
    domain: ProtectionDomain
    rights: int
    nonce: int
    epoch: int

    def allows(self, rights: int) -> bool:
        return (self.rights & rights) == rights


class KeyRegistry:
    """Allocates protection domains and issues capability keys.

    ``max_keys`` defaults to 16 (the x86 MPK limit) so resource exhaustion
    behaves like real hardware; pass a larger value for fabrics that need
    more channels (documented deviation — TPUs have no 16-domain limit).
    """

    def __init__(self, max_keys: int = 16, seed: int = 0x5EED):
        self._max = max_keys
        self._lock = threading.Lock()
        self._domains: Dict[int, ProtectionDomain] = {}
        self._epochs: Dict[int, int] = {}
        self._issued: Dict[int, set] = {}
        self._rng = itertools.count(seed * 2654435761 % 2 ** 31 + 1)
        self._next_id = 0
        self._free: list = []          # freed pkey numbers, reused like pkey_alloc

    # -- domains ------------------------------------------------------------
    def allocate_domain(self, name: str) -> ProtectionDomain:
        with self._lock:
            if self._free:
                did = self._free.pop()
            elif self._next_id < self._max:
                did = self._next_id
                self._next_id += 1
            else:
                raise ResourceWarning(
                    f"out of protection keys ({self._max}) — like pkey_alloc(2) "
                    f"returning ENOSPC")
            tag = (hash((name, did, 0x9E3779B9)) & 0xFFFFFFFF) | 1
            dom = ProtectionDomain(did, name, tag)
            self._domains[did] = dom
            self._epochs[did] = 0
            self._issued[did] = set()
            return dom

    def free_domain(self, dom: ProtectionDomain):
        with self._lock:
            if self._domains.pop(dom.did, None) is not None:
                self._free.append(dom.did)
            self._issued.pop(dom.did, None)
            self._epochs.pop(dom.did, None)

    # -- keys ---------------------------------------------------------------
    def issue_key(self, dom: ProtectionDomain, rights: int = RW) -> DomainKey:
        with self._lock:
            if dom.did not in self._domains:
                raise AccessViolation(f"domain {dom.name} not allocated here")
            nonce = next(self._rng)
            key = DomainKey(dom, rights, nonce, self._epochs[dom.did])
            self._issued[dom.did].add(nonce)
            return key

    def revoke(self, key: DomainKey):
        """Revoke one key and bump the domain epoch (stale frames fail MAC)."""
        with self._lock:
            self._issued.get(key.domain.did, set()).discard(key.nonce)
            if key.domain.did in self._epochs:
                self._epochs[key.domain.did] += 1

    def retire(self, key: DomainKey):
        """Graceful release: forget the nonce WITHOUT bumping the epoch.
        Closing a session is not a security event — other holders of keys
        on the domain keep working; the retired key itself stops checking."""
        with self._lock:
            self._issued.get(key.domain.did, set()).discard(key.nonce)

    def epoch(self, dom: ProtectionDomain) -> int:
        return self._epochs.get(dom.did, -1)

    # -- checks (staging-time PKRU) ------------------------------------------
    def check(self, key: DomainKey, rights: int):
        """The PKRU check. Raises AccessViolation on any failure mode the
        paper's threat model cares about: forged key, revoked key, stale
        epoch, insufficient rights."""
        with self._lock:
            dom = self._domains.get(key.domain.did)
            if dom is None or dom != key.domain:
                raise AccessViolation(f"unknown/forged domain {key.domain}")
            if key.nonce not in self._issued[dom.did]:
                raise AccessViolation(f"revoked or foreign key for {dom.name}")
            if key.epoch != self._epochs[dom.did]:
                raise AccessViolation(
                    f"stale key epoch {key.epoch} != {self._epochs[dom.did]} "
                    f"for {dom.name}")
            if not key.allows(rights):
                raise AccessViolation(
                    f"rights {rights:#x} not granted on {dom.name} "
                    f"(have {key.rights:#x})")

    # -- PKRU emulation for the CPU transports --------------------------------
    def pkru_word(self, keys: Tuple[DomainKey, ...]) -> int:
        """Pack grants into a PKRU-layout word (2 bits/key, 0b11 = no access)."""
        word = 0
        rights_by_did = {}
        for k in keys:
            rights_by_did[k.domain.did] = rights_by_did.get(k.domain.did, 0) | k.rights
        for did in range(self._max if self._max <= 16 else 16):
            bits = _PKRU_BITS[rights_by_did.get(did, 0)]
            word |= bits << (2 * did)
        return word


def mac_seed(dom: ProtectionDomain, epoch: int) -> int:
    """Tag ⊕ epoch mix fed to the guard kernel — stale epochs change the MAC."""
    return (dom.tag ^ (epoch * 0x85EBCA6B)) & 0xFFFFFFFF
