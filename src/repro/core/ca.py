"""MPKLink as Certificate Authority (paper §V).

Each microservice registers a unique public/private key pair; MPKLink-as-CA
verifies digital signatures before a service may join a channel, so
"malicious or unverified microservices are incapable of tampering with
protected memory regions". Channel grants bind (service_a, service_b,
domain) and derive the data-plane MAC session seed from both identities.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.core import signature as sig
from repro.core.domains import (DomainKey, KeyRegistry, ProtectionDomain, RW,
                                AccessViolation)


@dataclass
class ServiceRecord:
    name: str
    public_key: int
    cert: Tuple[int, int]          # CA signature over (name, public_key)
    verified: bool = True


class CertificateAuthority:
    """Registry of services + issuer of channel grants.

    Thread-safe: sessions enroll lazily from whatever thread first uses a
    client, so registration (which scans every record for the alias-refusal
    check) must not race concurrent inserts."""

    def __init__(self, registry: Optional[KeyRegistry] = None, seed: str = "mpklink-ca"):
        self.registry = registry or KeyRegistry()
        self._ca_keys = sig.KeyPair.generate(seed)
        self._services: Dict[str, ServiceRecord] = {}
        self._lock = threading.RLock()

    # -- service lifecycle ----------------------------------------------------
    def register(self, name: str, public_key: int, proof: Tuple[int, int]) -> ServiceRecord:
        """A service proves possession of its private key by signing its own
        registration; the CA then certifies (name, public_key). A revoked
        identity stays revoked: re-registration under the same name is
        refused, otherwise a ban would be one reconnect deep. Keys bind to
        exactly one identity: a (possibly stolen) key already certified for
        another name — revoked or not — cannot mint a fresh identity, so a
        banned client cannot re-enter under an alias."""
        with self._lock:
            existing = self._services.get(name)
            if existing is not None and not existing.verified:
                raise AccessViolation(
                    f"service {name}: identity revoked — re-registration refused")
            if existing is not None and existing.public_key != public_key:
                raise AccessViolation(
                    f"service {name}: name already bound to a different key — "
                    f"identity takeover refused")
            for rec in self._services.values():
                if rec.public_key == public_key and rec.name != name:
                    raise AccessViolation(
                        f"service {name}: key already bound to identity "
                        f"{rec.name!r}"
                        + (" (revoked)" if not rec.verified else "")
                        + " — alias registration refused")
            msg = f"register:{name}:{public_key}".encode()
            if not sig.verify(public_key, msg, proof):
                raise AccessViolation(f"service {name}: bad proof of possession")
            cert = sig.sign(self._ca_keys.private,
                            f"cert:{name}:{public_key}".encode())
            rec = ServiceRecord(name, public_key, cert)
            self._services[name] = rec
            return rec

    def verify_cert(self, rec: ServiceRecord) -> bool:
        msg = f"cert:{rec.name}:{rec.public_key}".encode()
        return sig.verify(self._ca_keys.public, msg, rec.cert)

    def revoke_service(self, name: str):
        with self._lock:
            if name in self._services:
                self._services[name].verified = False

    # -- channel grants ---------------------------------------------------------
    def grant_channel(self, svc_a: str, svc_b: str,
                      rights: int = RW) -> Tuple[ProtectionDomain, DomainKey, DomainKey]:
        """Both endpoints must be registered, verified, cert-valid. Returns the
        shared domain + one capability key per endpoint."""
        with self._lock:
            for name in (svc_a, svc_b):
                rec = self._services.get(name)
                if rec is None:
                    raise AccessViolation(
                        f"service {name} not registered with CA")
                if not rec.verified or not self.verify_cert(rec):
                    raise AccessViolation(
                        f"service {name} failed certificate check")
        dom = self.registry.allocate_domain(f"chan:{svc_a}<->{svc_b}")
        return dom, self.registry.issue_key(dom, rights), self.registry.issue_key(dom, rights)

    def session_seed(self, svc_a_priv: int, svc_b: str) -> int:
        """Data-plane MAC seed derived from both endpoint identities."""
        rec = self._services[svc_b]
        return sig.session_key(svc_a_priv, rec.public_key)


def enroll(ca: CertificateAuthority, name: str) -> Tuple[sig.KeyPair, ServiceRecord]:
    """Convenience: generate a key pair, prove possession, register."""
    kp = sig.KeyPair.generate(name)
    proof = sig.sign(kp.private, f"register:{name}:{kp.public}".encode())
    return kp, ca.register(name, kp.public, proof)
