"""Deterministic fault-injection fabric for MPKLink gateways (chaos layer).

Service-mesh practice treats retries, health checks and circuit breaking as
the layer that makes co-located microservices production-grade; this module
is the *test fabric* that proves the gateway's version of that layer. A
seeded :class:`FaultPlan` schedules faults at request indices; a
:class:`FaultFabric` attached to a :class:`~repro.core.gateway.ServiceGateway`
fires the server-side kinds on the wire path, and a :class:`FaultyClient`
fires the client-side kinds by mutating real gateway envelopes. Every run is
exactly replayable from ``(seed, plan)``: the schedule, the mutations and
the typed outcomes are all pure functions of the plan — no wall clock, no
global RNG.

Fault kinds
-----------

client-side (mutated envelopes, sent through the client's own session):

  corrupt_mac     flip one bit of the frame MAC word (or a payload byte)
  truncate        drop frame rows (or send a non-lane-aligned body)
  reorder_seq     frame carries a future sequence number
  stale_replay    frame carries an already-consumed sequence number — the
                  wire image of replaying a captured frame
  forge_identity  valid frame, forged client id in the route words

server-side (fired on the gateway's wire handler):

  crash_handler   kill the transport service thread mid-request
                  (HandlerCrash — the client must get a typed
                  ServiceCrashed immediately, not a full-deadline stall)
  drop_response   execute, then never send the response (DropResponse —
                  the client's bounded wait must expire: ResponseTimeout)
  delay_response  execute, respond ``plan.delay`` seconds late (must stay
                  under the transport deadline and complete)

Expected outcome per kind is in :data:`EXPECTED`; ``None`` means the
request must still complete correctly. A mutated envelope that the gateway
ACCEPTS raises :class:`FaultLeak` — a failed security invariant, never
swallowed.

Replay: ``FaultPlan.from_spec(plan.spec())`` reconstructs the identical
schedule; ``plan.describe()`` is the one-liner chaos tests print on failure.
The step-by-step replay recipe lives in docs/benchmarks.md; the error types
each kind must surface as are normative in docs/protocol.md §7.
"""
from __future__ import annotations

import multiprocessing
import random
import threading
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core import framing
from repro.core.domains import AccessViolation
from repro.core.gateway import (GW_MAGIC, GatewayClient, ServiceGateway,
                                _ROUTE_BYTES, _route, _OK)
from repro.core.transports import (DropResponse, HandlerCrash, ResponseTimeout,
                                   ServiceCrashed, TransportError,
                                   _raise_remote)

CLIENT_KINDS: Tuple[str, ...] = ("corrupt_mac", "truncate", "reorder_seq",
                                 "stale_replay", "forge_identity")
SERVER_KINDS: Tuple[str, ...] = ("crash_handler", "drop_response",
                                 "delay_response")
ALL_KINDS: Tuple[str, ...] = CLIENT_KINDS + SERVER_KINDS

# kind → exception type the client MUST see (None: must complete correctly)
EXPECTED: Dict[str, Optional[type]] = {
    "corrupt_mac": framing.FrameError,
    "truncate": framing.FrameError,
    "reorder_seq": framing.FrameError,
    "stale_replay": framing.FrameError,
    "forge_identity": AccessViolation,
    "crash_handler": ServiceCrashed,
    "drop_response": ResponseTimeout,
    "delay_response": None,
}


class FaultLeak(AssertionError):
    """An injected security fault was ACCEPTED by the gateway (or surfaced
    as the wrong type) — a broken isolation invariant, not a test flake."""


@dataclass(frozen=True)
class FaultEvent:
    index: int                  # request index the fault fires at
    kind: str
    param: int = 0              # kind-specific knob (bit/row/cid offset)


class FaultPlan:
    """Seeded, fully deterministic fault schedule over ``n_requests``.

    The schedule is a pure function of ``(seed, n_requests, rate, kinds)``:
    fault indices are a seeded sample of the request range and kinds are
    dealt round-robin then seeded-shuffled, so every kind appears within
    ±1 of its fair share. ``spec()``/``from_spec()`` round-trip the plan for
    replaying a failed CI run locally."""

    def __init__(self, seed: int, n_requests: int, rate: float = 0.1,
                 kinds: Optional[Tuple[str, ...]] = None,
                 delay: float = 0.005):
        kinds = tuple(kinds) if kinds else ALL_KINDS
        for k in kinds:
            if k not in ALL_KINDS:
                raise ValueError(f"unknown fault kind {k!r}")
        self.seed = int(seed)
        self.n_requests = int(n_requests)
        self.rate = float(rate)
        self.kinds = kinds
        self.delay = float(delay)
        rng = random.Random(self.seed)
        n_faults = min(self.n_requests, int(round(self.rate * self.n_requests)))
        indices = sorted(rng.sample(range(self.n_requests), n_faults))
        dealt = [kinds[j % len(kinds)] for j in range(n_faults)]
        rng.shuffle(dealt)
        self.events: Dict[int, FaultEvent] = {
            i: FaultEvent(i, k, rng.randrange(1 << 16))
            for i, k in zip(indices, dealt)}

    # -- replay -----------------------------------------------------------
    def spec(self) -> Dict[str, object]:
        """JSON-safe plan parameters; ``from_spec(spec())`` rebuilds the
        identical schedule (committed with every chaos_bench cell)."""
        return {"seed": self.seed, "n_requests": self.n_requests,
                "rate": self.rate, "kinds": list(self.kinds),
                "delay": self.delay}

    @classmethod
    def from_spec(cls, spec: Dict[str, object]) -> "FaultPlan":
        """Reconstruct a plan from :meth:`spec` output — the replay path
        for a failed CI seed (see docs/benchmarks.md)."""
        return cls(spec["seed"], spec["n_requests"], spec["rate"],
                   tuple(spec["kinds"]), spec["delay"])

    def describe(self) -> str:
        """One-line replay recipe; chaos tests print this on failure."""
        return (f"FaultPlan.from_spec({self.spec()!r})  "
                f"# {len(self.events)} faults over {self.n_requests} requests")

    def schedule(self) -> List[FaultEvent]:
        """The planned fault events in firing (request-index) order."""
        return [self.events[i] for i in sorted(self.events)]


def _peek_sid(req: np.ndarray) -> int:
    """Best-effort service id from a gateway envelope (for crash health)."""
    try:
        raw = np.ascontiguousarray(np.asarray(req)).view(np.uint8).reshape(-1)
        if raw.nbytes >= _ROUTE_BYTES:
            route = raw[:_ROUTE_BYTES].view("<u4")
            if int(route[0]) == GW_MAGIC:
                return int(route[1])
    # mpklint: disable=MPK105 reason=best-effort peek; malformed routes -> sid 0
    except Exception:
        pass
    return 0


class FaultFabric:
    """Wraps a gateway's wire handler to fire the server-side fault kinds.

    Attach BEFORE traffic starts; each wire message consumes one schedule
    index (with strict single-client traffic, wire index == request index,
    so client- and server-side kinds share one schedule). ``clock`` is the
    sleep function — injectable so tests can run delay faults at zero wall
    cost."""

    def __init__(self, plan: FaultPlan, clock: Callable[[float], None] = time.sleep):
        self.plan = plan
        self.clock = clock
        self.gw: Optional[ServiceGateway] = None
        self.fired: List[FaultEvent] = []
        self._inner: Optional[Callable] = None
        # the wire-fault index lives in shared memory so process-backed
        # transports keep ONE monotonic schedule across forks and heals: a
        # re-forked service child resumes the count where the dead one
        # stopped instead of replaying the plan from index 0. `fired` stays
        # local to whichever process observed the event — chaos assertions
        # on process-backed transports check client-observable outcomes.
        self._index = multiprocessing.Value("q", 0)
        self._lock = threading.Lock()

    def attach(self, gw: ServiceGateway) -> "FaultFabric":
        """Interpose on ``gw``'s wire handler (live sessions resolve the
        handler per request, so the fabric takes effect immediately).
        One fabric drives one gateway; returns self for chaining."""
        if self._inner is not None:
            raise RuntimeError("fabric already attached")
        self.gw = gw
        self._inner = gw.transport.handler
        gw.transport.handler = self._wire
        return self

    def detach(self):
        """Restore the gateway's original wire handler (idempotent)."""
        if self.gw is not None and self._inner is not None:
            self.gw.transport.handler = self._inner
        self._inner = None

    def _wire(self, req: np.ndarray) -> np.ndarray:
        with self._index.get_lock():
            idx = self._index.value
            self._index.value += 1
        ev = self.plan.events.get(idx)
        kind = ev.kind if ev is not None and ev.kind in SERVER_KINDS else None
        if kind == "crash_handler":
            with self._lock:
                self.fired.append(ev)
            if self.gw is not None:
                self.gw.note_wire_crash(_peek_sid(req))
            raise HandlerCrash(
                f"faultwire: injected service crash at request {idx} "
                f"(seed={self.plan.seed})")
        resp = self._inner(req)
        if kind == "delay_response":
            with self._lock:
                self.fired.append(ev)
            self.clock(self.plan.delay)
        elif kind == "drop_response":
            with self._lock:
                self.fired.append(ev)
            raise DropResponse(
                f"faultwire: dropped response at request {idx} "
                f"(seed={self.plan.seed})")
        return resp


@dataclass
class Outcome:
    """One request's fate under the fabric."""
    index: int
    status: str                         # ok | fault | recovered | error
    kind: Optional[str]                 # injected fault kind, if any
    value: object                       # response array or exception

    @property
    def ok(self) -> bool:
        return self.status in ("ok", "recovered")


class FaultyClient:
    """Drives one service through a :class:`GatewayClient` while injecting
    the plan's client-side faults as mutated-but-real gateway envelopes.

    ``step(payload)`` advances the request index by one and returns an
    :class:`Outcome`; injected security faults are *verified* — the gateway
    must reject them with the :data:`EXPECTED` type, anything else raises
    :class:`FaultLeak`. After liveness faults (crash/drop) the client heals
    (fresh session + channel) so the run continues — exactly what a
    production client stack would do."""

    def __init__(self, client: GatewayClient, fabric: FaultFabric,
                 service: str):
        self.client = client
        self.fabric = fabric
        self.service = service
        self.outcomes: List[Outcome] = []
        self._index = 0

    # -- the injected envelopes ------------------------------------------
    def _mutated_env(self, ev: FaultEvent, payload: np.ndarray) -> np.ndarray:
        """Build the attack envelope for ``ev`` against the CURRENT channel
        state (rebuilt per attempt: healing replaces channel seed/seq)."""
        client, gw = self.client, self.client.gw
        chan = client.open(self.service)
        rng = random.Random((self.fabric.plan.seed << 20) ^ ev.index)
        cid = client.cid
        frame = framing.build_frame(np.asarray(payload), seed=chan.seed,
                                    seq=chan.seq, mac_impl=gw._mac)
        if ev.kind == "corrupt_mac":
            frame = frame.copy()
            if ev.param & 1 and frame.shape[0] > 1:     # payload byte flip
                row = 1 + ev.param % (frame.shape[0] - 1)
                frame[row, ev.param % framing.LANES] ^= \
                    np.uint32(1 << (ev.param % 32))
            else:                                        # MAC word bit flip
                frame[0, 11] ^= np.uint32(1 << (ev.param % 32))
        elif ev.kind == "truncate":
            frame = frame[: max(0, frame.shape[0] - 1 - ev.param % 2)]
        elif ev.kind == "reorder_seq":
            frame = framing.build_frame(np.asarray(payload), seed=chan.seed,
                                        seq=chan.seq + 1 + ev.param % 7,
                                        mac_impl=gw._mac)
        elif ev.kind == "stale_replay":
            stale = chan.seq - 1 - ev.param % 3 if chan.seq > 0 \
                else chan.seq + 9                       # no past yet: future
            frame = framing.build_frame(np.asarray(payload), seed=chan.seed,
                                        seq=max(0, stale), mac_impl=gw._mac)
        elif ev.kind == "forge_identity":
            cid = 0x70000000 + rng.randrange(4096)      # unknown client id
        else:
            raise ValueError(f"not a client-side kind: {ev.kind}")
        return np.concatenate([_route(chan.sid, cid, 0),
                               frame.reshape(-1).view(np.uint8)])

    def _inject(self, ev: FaultEvent, payload: np.ndarray) -> BaseException:
        client = self.client
        # the injected envelope itself travels over the (faulty) wire: when
        # a drifted server-side event (drop/crash — possible once client
        # retries have shifted the wire index) eats it, heal and resend —
        # the server-side event has been consumed, the rejection verdict we
        # are probing for is unaffected
        for attempt in range(4):
            env = self._mutated_env(ev, payload)
            try:
                resp = np.ascontiguousarray(
                    np.asarray(client._session.request(env))) \
                    .view(np.uint8).reshape(-1)
                break
            except TransportError:
                if attempt == 3:
                    raise
                client.heal(self.service)
        route = resp[:_ROUTE_BYTES].view("<u4")
        if int(route[1]) == _OK:
            raise FaultLeak(
                f"gateway ACCEPTED injected {ev.kind} at request {ev.index} "
                f"— replay: {self.fabric.plan.describe()}")
        try:
            _raise_remote(resp[_ROUTE_BYTES:
                               _ROUTE_BYTES + int(route[3])].tobytes())
        except EXPECTED[ev.kind] as e:                   # the REQUIRED type
            return e
        except Exception as e:
            raise FaultLeak(
                f"injected {ev.kind} at request {ev.index} surfaced as "
                f"{type(e).__name__}, expected {EXPECTED[ev.kind].__name__} "
                f"— replay: {self.fabric.plan.describe()}")

    # -- one request under the plan --------------------------------------
    def step(self, payload: np.ndarray) -> Outcome:
        idx = self._index
        self._index += 1
        ev = self.fabric.plan.events.get(idx)
        if ev is not None and ev.kind in CLIENT_KINDS:
            exc = self._inject(ev, payload)
            out = Outcome(idx, "fault", ev.kind, exc)
        else:
            try:
                resp = self.client.call(self.service, payload)
            except (TransportError, AccessViolation,
                    framing.FrameError) as e:
                self.client.heal(self.service)           # keep the run alive
                if ev is not None:
                    expected = EXPECTED[ev.kind]
                    if expected is None or not isinstance(e, expected):
                        raise FaultLeak(
                            f"injected {ev.kind} at request {idx} surfaced "
                            f"as {type(e).__name__}, expected "
                            f"{getattr(expected, '__name__', 'success')} — "
                            f"replay: {self.fabric.plan.describe()}")
                    out = Outcome(idx, "fault", ev.kind, e)
                else:
                    out = Outcome(idx, "error", None, e)
            else:
                out = Outcome(idx, "recovered" if ev is not None else "ok",
                              ev.kind if ev is not None else None, resp)
        self.outcomes.append(out)
        return out

    def counts(self) -> Dict[str, int]:
        """Outcome tally so far: ok / fault (injected, typed as required) /
        recovered (delay faults that completed) / error (anything else —
        chaos gates require this to stay 0)."""
        c: Dict[str, int] = {"ok": 0, "fault": 0, "recovered": 0, "error": 0}
        for o in self.outcomes:
            c[o.status] += 1
        return c
