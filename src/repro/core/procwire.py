"""True inter-process MPKLink: services in ``multiprocessing.Process``
children over POSIX shared memory, plus the paper's honest baselines.

The six in-process transports serve every session with a thread of the
master process — exactly the paper's final single-process design — which
means the paper's headline *inter-process* comparison (MPK-guarded shared
memory vs REST over loopback TCP) had never actually been run. This
module closes that gap with five process-backed transports behind the
same :class:`~repro.core.transports.Session` API:

  shm_proc          raw fixed-capacity shared memory, service in a forked
                    child, slots + control words in a
                    ``multiprocessing.shared_memory`` segment
  mpklink_proc      the paper's MPKLink across a real process boundary:
                    per-chunk PKRU key-sync ping-pong through shared
                    control words, CA-enrolled per-session domains/seeds,
                    sealed frames verified in the child
  mpklink_opt_proc  one key sync per publish (the beyond-paper schedule),
                    same protection envelope
  rest              a REAL loopback HTTP/1.1 REST server (ThreadingHTTPServer
                    in a forked child, persistent connections, one POST per
                    request) — the paper's REST baseline, not a socketpair
                    stand-in
  sockrpc           length-prefixed RPC over loopback TCP (the same
                    ``_LEN``/``_ERR_BIT`` wire protocol as the uds
                    transport, across a real TCP connection to a child)

Process model (normative spec: docs/protocol.md §6):

* **Segments** are created by the client (parent) as
  ``multiprocessing.shared_memory`` blocks named ``mpk_<pid>_<hex8>``.
  The parent is the OWNER: its ``close()`` unlinks the segment
  (idempotently — a second close is a no-op, a missing segment is
  ignored). The service child never creates, closes or unlinks anything:
  the fork inheritance IS its attach, and ``os._exit`` is its detach.
  A ``weakref.finalize`` backstop unlinks owner segments at interpreter
  exit so an unclosed session cannot leak ``/dev/shm`` entries, and
  Python's resource tracker is left with nothing to complain about.
* **Layout**: one segment per session = a control block
  (:data:`PROC_CTRL_WORDS` u32 words: magic/version/stop flag, the PKRU
  key-sync sequence/ack pair, pkru+epoch words, the service drain
  cursor), a ring of :data:`PROC_SLOT_WORDS`-word slot headers, and a
  flat ``(rows, 128)`` u32 data slab managed by a CLIENT-owned
  :class:`framing.FrameArena` (``backing=`` the slab). The client
  allocates BOTH the request slot and a worst-case response slot per
  message and publishes their row offsets in the slot header; the child
  seals its response into the client-provided area. Single-owner
  allocation means no cross-process free protocol exists to get wrong.
* **Memory model**: every shared word is an aligned u32 (single store on
  x86-64); each state transition is ordered by program order on the
  writer (TSO) and followed by a doorbell write — a syscall, hence a
  full barrier — before the other side is woken to read it.
* **Doorbells** are socketpairs (:class:`ProcDoorbell`): ``ring()`` is a
  coalesced non-blocking 1-byte send, ``wait()`` is a bounded
  predicate-probe/select/drain loop. Each bell's unused ends are closed
  after the fork so peer DEATH is an EOF on the survivor's read end —
  a ``kill -9``'d service surfaces as a typed
  :class:`~repro.core.transports.ServiceCrashed` within one poll of the
  wait loop, never a silent deadline stall.
* **Crash invariant**: once the child is dead, in-flight slots (and the
  arena buffers backing them) are never recycled — a dead service may
  have held a sealed slot; handing its rows to a new message would
  alias a frame of unknown provenance. New submits raise
  ``ServiceCrashed``; ``close()`` unlinks the whole segment.
* **Forks are lazy**: the child is forked at the FIRST exchange, not at
  ``connect()``, so everything the parent configures up front (gateway
  channels, fault fabrics, swapped handlers) is in the child's
  snapshot. Control-plane changes made after the fork (epoch bumps, key
  revocations) are NOT visible to a live child — re-establish the
  session (exactly what ``GatewayClient.heal`` does) to pick them up.
  Forks are serialized under a module lock so a concurrent thread
  holding a lock can't be snapshotted mid-critical-section into a
  wedged child.
"""
from __future__ import annotations

import atexit
import base64
import gc
import http.client
import json
import multiprocessing
import os
import select
import signal
import socket
import struct
import threading
import time
import weakref
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core import framing
from repro.core.ca import enroll
from repro.core.domains import READ, RW, WRITE, mac_seed
from repro.core.transports import (CapacityError, DropResponse, Handler,
                                   HandlerCrash, MPKLinkTransport,
                                   ResponseTimeout, ServiceCrashed, Session,
                                   ShmTransport, Transport, TransportError,
                                   _ERR_BIT, _LEN, _pack_error, _raise_remote,
                                   _recv_exact, fast_mac)

# ---------------------------------------------------------------------------
# wire constants (docs/protocol.md §6 quotes these; mpklint MPK201 checks)
# ---------------------------------------------------------------------------

PROC_MAGIC = 0x4D504B50         # "MPKP": process-backed segment marker
PROC_VERSION = 1
PROC_CTRL_WORDS = 32            # control block size (u32 words)
PROC_SLOT_WORDS = 16            # per-slot header size (u32 words)

# control-block word indices
_W_MAGIC, _W_VERSION, _W_STOP, _W_SYNC_SEQ, _W_SYNC_ACK, _W_PKRU_LO, \
    _W_PKRU_HI, _W_EPOCH, _W_SVC_SYNC, _W_HEAD, _W_MODE = range(11)

# per-slot header word indices
_S_STATE, _S_TICKET, _S_REQ_OFF, _S_REQ_ROWS, _S_REQ_NBYTES, _S_RESP_OFF, \
    _S_RESP_CAP, _S_RESP_ROWS, _S_RESP_NBYTES, _S_ERR, _S_SEQ = range(11)

# slot states — same enum as the in-process ring
_FREE, _STAGED, _PUBLISHED, _DONE, _DROPPED = range(5)

_MODE_SHM, _MODE_MPKLINK = 0, 1
_ERR_OK, _ERR_BLOB = 0, 1       # _S_ERR: 0 = sealed response, 1 = error blob

_U32 = 0xFFFFFFFF

# serialize Process.start(): a fork taken while another thread holds a
# lock (gateway _glock, registry lock, ...) would snapshot that lock
# locked-forever into the child
_FORK_LOCK = threading.Lock()

_FORK_CTX = multiprocessing.get_context("fork")


def _pow2ceil(n: int, floor: int = 16) -> int:
    c = floor
    while c < n:
        c <<= 1
    return c


# ---------------------------------------------------------------------------
# shared-memory segment lifecycle (create / attach-by-fork / close / unlink)
# ---------------------------------------------------------------------------

# segments whose close() hit a BufferError (a caller still holds a response
# view aliasing the mapping) — re-tried at the next segment close
_DEFERRED_CLOSE: List[object] = []
_DEFERRED_LOCK = threading.Lock()


def _sweep_deferred_closes() -> None:
    with _DEFERRED_LOCK:
        pending, _DEFERRED_CLOSE[:] = list(_DEFERRED_CLOSE), []
    for shm in pending:
        try:
            shm.close()
        # mpklint: disable=MPK105 reason=close stays deferred while user views alive
        except BufferError:
            with _DEFERRED_LOCK:
                _DEFERRED_CLOSE.append(shm)


def _neutralize(shm) -> None:
    """Last-resort detach for a mapping pinned by user-held views at
    interpreter exit: drop the buffer/mmap references WITHOUT closing
    (the OS reclaims the mapping at process death) and close the fd, so
    ``SharedMemory.__del__`` finds nothing left to do instead of printing
    an un-catchable ``BufferError`` to stderr during shutdown."""
    shm._buf = None
    shm._mmap = None
    fd = getattr(shm, "_fd", -1)
    if fd >= 0:
        try:
            os.close(fd)
        # mpklint: disable=MPK105 reason=fd may already be closed at interpreter exit
        except OSError:
            pass
        shm._fd = -1


def _drain_deferred_at_exit() -> None:
    with _DEFERRED_LOCK:
        pending, _DEFERRED_CLOSE[:] = list(_DEFERRED_CLOSE), []
    for shm in pending:
        try:
            shm.close()
        except BufferError:
            _neutralize(shm)


atexit.register(_drain_deferred_at_exit)


def _finalize_owner_shm(shm) -> None:
    """GC / interpreter-exit backstop for an un-closed creator session:
    unlink the name so /dev/shm cannot leak (unlink also unregisters the
    segment from the resource tracker), then close the mapping —
    neutralizing it if user-held views still pin it, so no ``__del__``
    noise reaches stderr."""
    try:
        shm.unlink()
    # mpklint: disable=MPK105 reason=already unlinked by a clean close
    except FileNotFoundError:
        pass
    try:
        shm.close()
    except BufferError:
        _neutralize(shm)


class _ShmSegment:
    """One POSIX shared-memory segment viewed as a flat u32 array.

    Created (and therefore OWNED) by the client side; the service child
    attaches by fork inheritance and must call :meth:`disown` first thing
    so no child code path can ever unlink the parent's segment."""

    def __init__(self, nwords: int):
        from multiprocessing import shared_memory
        name = f"mpk_{os.getpid()}_{os.urandom(4).hex()}"
        self.shm = shared_memory.SharedMemory(
            name=name, create=True, size=nwords * 4)
        self.name = self.shm.name
        self.u32 = np.frombuffer(self.shm.buf, np.uint32, count=nwords)
        self._owner = True
        self._closed = False
        self._finalizer = weakref.finalize(
            self, _finalize_owner_shm, self.shm)

    def disown(self) -> None:
        """Child side: this process merely attached (via fork) — it must
        never unlink, and its exit detaches implicitly."""
        self._owner = False
        self._finalizer.detach()

    def close(self) -> None:
        """Idempotent close; the owner also unlinks. A mapping pinned by a
        live user-held view defers (and is re-tried later) — the UNLINK
        still happens now, so the name never outlives the session."""
        if self._closed:
            return
        self._closed = True
        self.u32 = None                 # drop our export of the mapping
        _sweep_deferred_closes()
        try:
            self.shm.close()
        except BufferError:             # a response view is still alive
            with _DEFERRED_LOCK:
                _DEFERRED_CLOSE.append(self.shm)
        if self._owner:
            self._finalizer.detach()
            try:
                self.shm.unlink()
            # mpklint: disable=MPK105 reason=idempotent unlink: name already gone
            except FileNotFoundError:
                pass


# ---------------------------------------------------------------------------
# cross-process doorbell
# ---------------------------------------------------------------------------

_DOORBELL_SPIN = 2              # bounded predicate probes before select()
_WAIT_SLICE = 0.1               # max single select() slice (liveness re-check)

# process-local ledger of live doorbell socket fds: every ProcDoorbell end
# registers at creation and deregisters (fileno read BEFORE close — a
# closed socket reports -1) on keep_writer/keep_reader/close. The test
# suite's proc-hygiene fixture asserts this drains to zero, so a leaked
# doorbell fd is caught at the owning test instead of as an eventual
# EMFILE three suites later.
_DOORBELL_FDS: set = set()
_DOORBELL_FDS_LOCK = threading.Lock()


def _track_doorbell(*socks) -> None:
    with _DOORBELL_FDS_LOCK:
        for s in socks:
            fd = s.fileno()
            if fd >= 0:
                _DOORBELL_FDS.add(fd)


def _untrack_doorbell(*socks) -> None:
    with _DOORBELL_FDS_LOCK:
        for s in socks:
            fd = s.fileno()
            if fd >= 0:
                _DOORBELL_FDS.discard(fd)


def open_doorbell_fds() -> int:
    """Number of doorbell socketpair fds currently open in THIS process."""
    with _DOORBELL_FDS_LOCK:
        return len(_DOORBELL_FDS)


_LIVENESS_SLICE = 0.25          # client waits re-consult the is_alive()
                                # backstop at least this often: EOF is the
                                # fast path, but a foreign fd keeping a dead
                                # child's bell open must not stretch crash
                                # detection to the full call deadline

# Every live ProcSession, so a newly forked service child can close the
# OTHER sessions' doorbell fds (fork copies the whole fd table): a sibling
# child holding a dead child's bell write end would otherwise suppress the
# EOF that makes kill -9 detection prompt. Guarded by _FORK_LOCK (forks
# and registration serialize on it).
_LIVE_PROC_SESSIONS: "weakref.WeakSet" = weakref.WeakSet()


class ProcDoorbell:
    """A socketpair doorbell that crosses the process boundary.

    ``ring()`` is a coalesced non-blocking send (a full pipe still means
    "rung"); ``wait(pred, ...)`` probes the predicate, parks in select()
    slices, drains rings, and re-probes — so a single ring covers every
    waiter and a missed byte can never lose a wakeup (the predicate over
    shared words is the truth, the bell is only a hint). After the fork
    each side closes the end it doesn't use, which turns peer death into
    an EOF on the survivor's read end: ``wait`` reports it through
    ``on_eof`` immediately instead of timing out."""

    def __init__(self):
        self._rd, self._wr = socket.socketpair(
            socket.AF_UNIX, socket.SOCK_STREAM)
        _track_doorbell(self._rd, self._wr)
        # the read end BLOCKS with a kernel-bounded slice (SO_RCVTIMEO):
        # one recv syscall is both the park and the drain, where a
        # non-blocking read end needs select + recv + recv-EAGAIN per
        # wake — two extra syscalls on the per-exchange hot path
        self._rd.setsockopt(
            socket.SOL_SOCKET, socket.SO_RCVTIMEO,
            struct.pack("ll", 0, int(_WAIT_SLICE * 1e6)))
        self._wr.setblocking(False)
        self._eof = False

    # -- post-fork fd hygiene ---------------------------------------------
    def keep_writer(self) -> None:
        """This process only rings; close the read end (the peer's EOF
        source is OUR death closing the write end)."""
        _untrack_doorbell(self._rd)
        try:
            self._rd.close()
        # mpklint: disable=MPK105 reason=best-effort fd hygiene after fork
        except OSError:
            pass

    def keep_reader(self) -> None:
        """This process only waits; close the write end so the PEER's
        death (last writer gone) raises EOF here."""
        _untrack_doorbell(self._wr)
        try:
            self._wr.close()
        # mpklint: disable=MPK105 reason=best-effort fd hygiene after fork
        except OSError:
            pass

    def ring(self) -> None:
        try:
            self._wr.send(b"!")
        # mpklint: disable=MPK105 reason=full pipe or dead peer both mean "rung/no waiter"
        except OSError:
            pass

    def _drain(self) -> bool:
        """Consume pending rings without blocking; returns True when the
        peer is gone."""
        try:
            while True:
                data = self._rd.recv(4096, socket.MSG_DONTWAIT)
                if data == b"":
                    self._eof = True
                    return True
        except BlockingIOError:
            return False
        except OSError:
            self._eof = True
            return True

    def wait(self, pred: Callable[[], bool], timeout: float,
             on_eof: Optional[Callable[[], None]] = None) -> bool:
        """Bounded wait for ``pred()``; returns its final value. ``timeout``
        is always honored (long waits park in RCVTIMEO-bounded recv slices
        and re-check; a sub-slice remainder falls back to an exact
        select)."""
        if pred():
            return True
        for _ in range(_DOORBELL_SPIN):
            if pred():
                return True
        deadline = time.monotonic() + max(0.0, timeout)
        while True:
            if pred():
                return True
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                return pred()
            if self._eof:
                if on_eof is not None:
                    on_eof()
                return pred()
            if remaining >= _WAIT_SLICE:
                # hot path: the blocking recv IS the park AND the drain
                try:
                    if self._rd.recv(4096) == b"":
                        self._eof = True
                        if on_eof is not None:
                            on_eof()
                        return pred()
                except (BlockingIOError, TimeoutError):
                    pass                # slice elapsed; re-probe liveness
                except OSError:         # fd closed under us (session close)
                    return pred()
                continue
            # sub-slice remainder: honor the exact deadline via select
            try:
                ready, _, _ = select.select([self._rd], [], [], remaining)
            except OSError:             # fd closed under us (session close)
                return pred()
            if ready and self._drain():
                if on_eof is not None:
                    on_eof()
                return pred()

    def close(self) -> None:
        _untrack_doorbell(self._rd, self._wr)
        for s in (self._rd, self._wr):
            try:
                s.close()
            # mpklint: disable=MPK105 reason=best-effort teardown of already-closed fds
            except OSError:
                pass


# ---------------------------------------------------------------------------
# process-backed session (shared machinery for shm_proc / mpklink*_proc)
# ---------------------------------------------------------------------------

class ProcSession(Session):
    """One client's channel to a service running in a forked child.

    All exchange state lives in the shared segment: a control block, a
    ring of slot headers, and a data slab carved by a client-owned backed
    :class:`framing.FrameArena`. The client stages a request (and a
    worst-case response area) into the slab, publishes the slot, and the
    child serves published slots in ticket order — the same
    submit/flush/poll discipline as the in-process rings, with
    ``request()`` as the fused one-message case. The child is forked
    lazily at the first exchange (see module docstring)."""

    _mode = _MODE_SHM

    def __init__(self, transport: Transport, name: str):
        super().__init__(transport, name)
        self.capacity = transport.capacity
        self._nslots = transport.ring_slots
        # worst-case rows one message side can need (subclass hook)
        self._cap_rows = _pow2ceil(self._side_rows(self.capacity))
        hdr_words = PROC_CTRL_WORDS + self._nslots * PROC_SLOT_WORDS
        hdr_rows = -(-hdr_words // framing.LANES)
        # the slab must cover every LIVE allocation: in-flight requests +
        # worst-case response areas + responses whose views the caller
        # still holds (release_on_collect pins those rows until the view
        # dies). ~4 rings of worst-case slots absorbs ring-windowed
        # batches whose outputs are all retained; beyond that the typed
        # CapacityError tells the caller to drop views (the segment is
        # fixed at creation — unlike the in-process arena it cannot grow)
        slab_rows = (4 * self._nslots + 8) * self._cap_rows
        self._seg = _ShmSegment((hdr_rows + slab_rows) * framing.LANES)
        self._ctrl = self._seg.u32[:PROC_CTRL_WORDS]
        self._slots = self._seg.u32[
            PROC_CTRL_WORDS:hdr_words].reshape(self._nslots, PROC_SLOT_WORDS)
        self._slab = self._seg.u32[
            hdr_rows * framing.LANES:].reshape(slab_rows, framing.LANES)
        # no fill(0): a freshly created POSIX shm segment is kernel-zeroed
        # (ftruncate extends with zero pages), and an eager memset would
        # both burn ~ms of CPU and fault in every page of a slab most
        # sessions never fully touch
        self.arena = framing.FrameArena(backing=self._slab)
        # flat u32 view of the control + slot words: plain-int memoryview
        # loads/stores are ~10x cheaper than numpy scalar indexing, and the
        # word plane is touched a dozen times per exchange on both sides of
        # the fork. The numpy views above stay for slab/bulk operations
        # (and for the cold paths that predate this fast plane).
        self._w = self._seg.shm.buf.cast("I")
        self._ctrl[_W_MAGIC] = PROC_MAGIC
        self._ctrl[_W_VERSION] = PROC_VERSION
        self._ctrl[_W_MODE] = self._mode
        self._pbell_svc = ProcDoorbell()    # client rings → child waits
        self._pbell_cli = ProcDoorbell()    # child rings → client waits
        with _FORK_LOCK:
            _LIVE_PROC_SESSIONS.add(self)
        self._proc: Optional[multiprocessing.process.BaseProcess] = None
        # ticket → (req_buf, resp_buf, seq); buffers of slots a dead child
        # may have held are deliberately NEVER released (crash invariant)
        self._inflight: Dict[int, Tuple] = {}
        self._staged: List[int] = []        # tickets staged, not yet published
        self._staged_bytes = 0
        self._req_cache: Optional[np.ndarray] = None    # recycled request slot
        self._seq = 0
        self.sync_count = 0
        self._svc_sync_seen = 0
        self._sync_slk = threading.Lock()

    # -- subclass hooks ----------------------------------------------------
    @staticmethod
    def _side_rows(capacity: int) -> int:
        """Rows one direction of a capacity-sized message needs."""
        return -(-capacity // (framing.LANES * 4))

    # -- lifecycle ---------------------------------------------------------
    def ensure_started(self):
        """No service thread: the child is forked lazily at the first
        exchange so gateway channels / fault fabrics configured after
        connect() land in the fork snapshot."""

    def _ensure_proc(self):
        if self._proc is not None or self._closed:
            return
        with _FORK_LOCK:
            if self._proc is not None:
                return
            proc = _FORK_CTX.Process(
                target=_service_child_main, args=(self,), daemon=True,
                name=f"{self.transport.name}:{self.name}")
            proc.start()
            self._proc = proc
        # EOF discipline: with these ends closed, child death is an EOF
        # on our bell_cli read end (and our death an EOF on its bell_svc)
        self._pbell_svc.keep_writer()
        self._pbell_cli.keep_reader()

    def _mark_crashed(self):
        self._crashed = True

    def _dead(self) -> bool:
        """Liveness backstop behind the EOF fast path."""
        if self._crashed:
            return True
        p = self._proc
        if p is not None and not p.is_alive():
            self._crashed = True
        return self._crashed

    def close(self):
        """Creator-side close: stop the child (cooperatively, then
        forcefully), drop every internal view of the mapping, close AND
        unlink the segment. Idempotent."""
        if self._closed:
            return
        self._closed = True
        try:
            if self._proc is not None:
                if self._ctrl is not None:
                    self._ctrl[_W_STOP] = 1
                self._pbell_svc.ring()
                self._proc.join(timeout=0.5)
                if self._proc.is_alive():
                    self._proc.terminate()
                    self._proc.join(timeout=0.5)
                if self._proc.is_alive():
                    self._proc.kill()
                    self._proc.join(timeout=0.5)
        finally:
            self._pbell_svc.close()
            self._pbell_cli.close()
            self._teardown()
            self._inflight.clear()
            self.arena = None
            self._ctrl = self._slots = self._slab = None
            if self._w is not None:
                self._w.release()       # drop the word-plane export so the
                self._w = None          # segment mapping can actually close
            self._seg.close()
            self.transport._forget(self)

    # -- slot helpers ------------------------------------------------------
    def _acquire(self, rows: int) -> np.ndarray:
        try:
            return self.arena.acquire(rows)
        except framing.FrameError as e:
            raise CapacityError(str(e)) from None

    def _await_slot(self, deadline: Optional[float]):
        """Credit wait over the SHARED slot state word — same typed-error
        contract as the in-process ``_await_credit`` (CapacityError when
        the credit window expires, ResponseTimeout when the caller's
        tighter per-call budget does)."""
        w, t = self._w, self._tickets
        state_i = (PROC_CTRL_WORDS
                   + (t % self._nslots) * PROC_SLOT_WORDS + _S_STATE)

        def free():
            return w[state_i] == _FREE \
                or self._crashed or self._closed
        if free():
            return
        credit_deadline = time.monotonic() + self.transport.credit_wait
        eff_deadline = credit_deadline if deadline is None \
            else min(credit_deadline, deadline)
        self.flush()
        while True:
            # slice-bounded park: each lap re-consults the is_alive()
            # backstop below, so a kill -9 whose EOF is suppressed by an
            # inherited fd still surfaces within _LIVENESS_SLICE
            self._pbell_cli.wait(
                free, min(_LIVENESS_SLICE,
                          max(0.0, eff_deadline - time.monotonic())),
                on_eof=self._mark_crashed)
            if w[state_i] == _FREE:
                return
            if self._dead():
                raise ServiceCrashed(
                    f"session {self.name!r}: service process died while "
                    f"waiting for a ring credit")
            if self._closed:
                raise TransportError(f"session {self.name!r} is closed")
            if time.monotonic() >= eff_deadline:
                if eff_deadline < credit_deadline:
                    raise ResponseTimeout(
                        f"call budget exhausted while waiting for a ring "
                        f"credit (ring full, {self._nslots} messages in "
                        f"flight)")
                raise CapacityError(
                    f"ring full ({self._nslots} messages in flight) — "
                    f"poll() before submitting more")

    def _stage(self, seal, req_nbytes: int, req_rows: int,
               timeout: Optional[float] = None) -> int:
        """Allocate req+resp slab areas, let ``seal(req_buf) -> (rows,
        nbytes)`` write the request, and stage the slot header."""
        self._check_usable()
        if req_nbytes > self.capacity:
            raise CapacityError(
                f"{self.transport.name} segment ({self.capacity}B) cannot "
                f"hold {req_nbytes}B payload")
        self._ensure_proc()
        self._await_slot(None if timeout is None
                         else time.monotonic() + timeout)
        # request slots have no view-lifetime hazard (poll releases them
        # only after the child set DONE), so the last one short-circuits
        # the arena's lock + sweep round trip
        cached = self._req_cache
        if cached is not None and cached.shape[0] >= req_rows:
            req_buf, self._req_cache = cached, None
        else:
            req_buf = self._acquire(req_rows)
        resp_buf = self._acquire(self._cap_rows)
        rows, nbytes = seal(req_buf)
        t = self._tickets
        seq = self._seq
        w = self._w
        b = PROC_CTRL_WORDS + (t % self._nslots) * PROC_SLOT_WORDS
        w[b + _S_TICKET] = t & _U32
        w[b + _S_REQ_OFF] = self.arena.offset_rows(req_buf)
        w[b + _S_REQ_ROWS] = rows
        w[b + _S_REQ_NBYTES] = nbytes
        w[b + _S_RESP_OFF] = self.arena.offset_rows(resp_buf)
        w[b + _S_RESP_CAP] = resp_buf.shape[0]
        w[b + _S_RESP_ROWS] = 0
        w[b + _S_RESP_NBYTES] = 0
        w[b + _S_ERR] = _ERR_OK
        w[b + _S_SEQ] = seq & _U32
        w[b + _S_STATE] = _STAGED       # written LAST (publish flips it)
        with self._slk:
            self._tickets += 1
            self._seq += 1
        self._outstanding.add(t)
        self._inflight[t] = (req_buf, resp_buf, seq)
        self._staged.append(t)
        self._staged_bytes += rows * framing.LANES * 4
        return t

    # -- pipelined API -----------------------------------------------------
    def submit(self, payload: np.ndarray,
               timeout: Optional[float] = None) -> int:
        raw = np.ascontiguousarray(np.asarray(payload)) \
            .view(np.uint8).reshape(-1)

        def seal(buf: np.ndarray):
            buf.reshape(-1).view(np.uint8)[:raw.nbytes] = raw
            return self._side_rows(max(1, raw.nbytes)), raw.nbytes
        return self._stage(seal, raw.nbytes,
                           self._side_rows(max(1, raw.nbytes)),
                           timeout=timeout)

    def _pre_publish_syncs(self, staged_bytes: int):
        """Subclass hook: key-sync schedule for one publish (mpklink).
        Runs BEFORE the slot states flip so the sync words are visible to
        the child no later than the published slots; the publish's single
        doorbell ring covers the final (deferred) sync round."""

    def flush(self):
        if not self._staged or self._crashed:
            return
        staged, self._staged = self._staged, []
        staged_bytes, self._staged_bytes = self._staged_bytes, 0
        self._pre_publish_syncs(staged_bytes)
        w, nslots = self._w, self._nslots
        for t in staged:
            w[PROC_CTRL_WORDS + (t % nslots) * PROC_SLOT_WORDS
              + _S_STATE] = _PUBLISHED
        self._pbell_svc.ring()

    def _extract(self, b: int, rec: Tuple) -> np.ndarray:
        """Subclass hook: turn a DONE slot's response area into the value
        ``poll`` returns (raises on guard failure). Runs client-side.
        ``b`` is the slot's base index into the ``self._w`` word plane."""
        raise NotImplementedError

    def poll(self, ticket: int, timeout: Optional[float] = None) -> np.ndarray:
        self._check_pollable()
        self.flush()
        if ticket not in self._outstanding:
            raise TransportError(
                f"unknown or already-redeemed ticket {ticket}")
        eff = self.transport.timeout if timeout is None else timeout
        deadline = time.monotonic() + eff
        w = self._w
        b = PROC_CTRL_WORDS + (ticket % self._nslots) * PROC_SLOT_WORDS
        tick = ticket & _U32

        def settled():
            return (w[b + _S_STATE] == _DONE
                    and w[b + _S_TICKET] == tick) \
                or self._crashed or self._closed
        while True:
            # slice-bounded park (see _await_slot): crash detection is
            # bounded by _LIVENESS_SLICE even without the EOF fast path
            self._pbell_cli.wait(
                settled, min(_LIVENESS_SLICE,
                             max(0.0, deadline - time.monotonic())),
                on_eof=self._mark_crashed)
            if w[b + _S_STATE] == _DONE and w[b + _S_TICKET] == tick:
                break
            if self._dead():
                raise ServiceCrashed(
                    f"session {self.name!r}: service process died with "
                    f"ticket {ticket} in flight")
            if self._closed:
                raise TransportError(f"session {self.name!r} is closed")
            if time.monotonic() >= deadline:
                self._poisoned = True
                raise ResponseTimeout(
                    f"{self.transport.name} response timed out after {eff}s")
        self._outstanding.discard(ticket)
        rec = self._inflight.pop(ticket)
        req_buf, resp_buf, _seq = rec
        self._fold_svc_syncs()
        if w[b + _S_ERR] == _ERR_BLOB:
            blob = bytes(resp_buf.reshape(-1).view(np.uint8)
                         [:w[b + _S_RESP_NBYTES]])
            w[b + _S_STATE] = _FREE
            self.arena.release(req_buf)
            self.arena.release(resp_buf)
            _raise_remote(blob)
        try:
            out = self._extract(b, rec)
        except framing.FrameError:
            w[b + _S_STATE] = _FREE
            self.arena.release(req_buf)
            self.arena.release(resp_buf)
            raise
        w[b + _S_STATE] = _FREE
        if self._req_cache is None:
            self._req_cache = req_buf
        else:
            self.arena.release(req_buf)
        # the response view aliases the slab: its slot recycles only after
        # the view (and everything derived from it) is dead
        self.arena.release_on_collect(out, resp_buf)
        return out

    def _fold_svc_syncs(self):
        """Fold the child's response-side key-sync count (a shared
        accounting word) into the transport counters."""

    # -- lockstep API (fused submit→flush→poll over the same slots) --------
    def request(self, payload: np.ndarray,
                timeout: Optional[float] = None) -> np.ndarray:
        self._check_usable()
        eff = self.transport.timeout if timeout is None else timeout
        deadline = time.monotonic() + eff
        t = self.submit(payload, timeout=eff)
        self.flush()
        return self.poll(t, max(1e-3, deadline - time.monotonic()))

    def call_batch(self, payloads, return_exceptions: bool = False):
        """Ring-windowed pipelined batch: batches larger than the slot
        ring run in ring-sized windows — one publish (one key sync on the
        mpklink variants) per window. Per-message failures stay typed."""
        self._check_usable()
        out: List = []
        first: Optional[BaseException] = None
        cap = self._nslots
        for start in range(0, len(payloads), cap):
            tickets = [self.submit(p) for p in payloads[start:start + cap]]
            self.flush()
            for t in tickets:
                try:
                    out.append(self.poll(t))
                except Exception as e:  # noqa: PERF203 — per-ticket fate
                    if first is None:
                        first = e
                    out.append(e)
        if first is not None and not return_exceptions:
            raise first
        return out

    def _notify_crash(self, exc: ServiceCrashed):
        self._crashed = True


class ProcShmSession(ProcSession):
    """shm_proc: raw bytes in the slab, no framing — the paper's failing
    fixed-capacity baseline, now actually inter-process."""

    _mode = _MODE_SHM

    def _extract(self, b: int, rec: Tuple) -> np.ndarray:
        _req_buf, resp_buf, _seq = rec
        out = resp_buf.reshape(-1).view(np.uint8)[
            :self._w[b + _S_RESP_NBYTES]]
        out.flags.writeable = False
        return out


class ProcMPKLinkSession(ProcSession):
    """mpklink_proc / mpklink_opt_proc: CA-enrolled per-session domain,
    sealed frames in the slab, PKRU key-sync ping-pong through shared
    control words — the paper's protocol with the service in another
    process. The chunk schedule is preserved exactly: a publish performs
    ``ceil(published_bytes / chunk)`` client→service sync round trips
    (each one a write of the PKRU/epoch words + a bumped sync sequence
    the child must ack), and each response drain pass costs one
    service-side sync, counted in a shared accounting word."""

    _mode = _MODE_MPKLINK

    def __init__(self, transport: "ProcMPKLinkTransport", name: str):
        self.chunk = transport.chunk
        self._mac = transport._mac
        super().__init__(transport, name)
        self.registry = transport.registry
        self._sync_cache = None         # (epoch, key, rights, lo, hi)
        self._read_check_ep = None      # epoch the client READ check passed at
        self._srv_checked = False       # child-side R/W check memo (snapshot
                                        # registry: the verdict cannot change)
        # control plane (parent-side, before any fork): CA handshake
        self._kp, _ = enroll(transport.ca, name)
        self.domain, self.key_client, self.key_server = \
            transport.ca.grant_channel(name, transport.server_name, RW)
        sess = transport.ca.session_seed(
            self._kp.private, transport.server_name)
        self.seed = mac_seed(self.domain,
                             self.registry.epoch(self.domain)) ^ sess
        # pre-fork: pull the kernels.ref constants + MAC lru caches into
        # THIS process so the child's fork snapshot already has them
        framing.warm_mac_caches(self.seed)

    @staticmethod
    def _side_rows(capacity: int) -> int:
        return framing.frame_rows(capacity)

    def _teardown(self):
        self.registry.free_domain(self.domain)

    def _bump_sync(self):
        with self._sync_slk:
            self.sync_count += 1
        self.transport._bump_sync()

    def _post_sync(self, key, rights) -> int:
        """Client half of one PKRU synchronization: capability check,
        PKRU/epoch words, bumped sync sequence. Returns the sequence the
        child must ack. The check result and PKRU word are cached per
        registry epoch — every registry mutation that could invalidate
        them (revoke, free_domain) bumps the domain epoch, so an unchanged
        epoch means the previous verdict still stands; an epoch change
        re-runs the full check (and raises on a stale key exactly as the
        uncached path did)."""
        ep = self.registry.epoch(self.domain)
        cached = self._sync_cache
        if cached is None or cached[0] != ep or cached[1] is not key \
                or cached[2] != rights:
            self.registry.check(key, rights)
            pkru = int(self.registry.pkru_word((key,)))
            cached = self._sync_cache = (ep, key, rights,
                                         pkru & _U32, (pkru >> 32) & _U32)
        w = self._w
        w[_W_PKRU_LO] = cached[3]
        w[_W_PKRU_HI] = cached[4]
        w[_W_EPOCH] = ep & _U32
        self._bump_sync()
        seqv = (w[_W_SYNC_SEQ] + 1) & _U32
        w[_W_SYNC_SEQ] = seqv
        return seqv

    def _sync_key(self, key, rights):
        """One FULL PKRU synchronization round trip across the process
        boundary: post the sync, ring, then a bounded wait for the
        child's ack (crash-aware: a SIGKILL'd child surfaces as
        ServiceCrashed, not a stall). The chunked schedule uses this for
        every chunk but the last — a WRPKRU must be visible before the
        next chunk may be written."""
        seqv = self._post_sync(key, rights)
        w = self._w
        self._pbell_svc.ring()

        def acked():
            return w[_W_SYNC_ACK] == seqv \
                or self._crashed or self._closed
        while True:
            self._pbell_cli.wait(acked, 0.5, on_eof=self._mark_crashed)
            if w[_W_SYNC_ACK] == seqv:
                return
            if self._dead():
                raise ServiceCrashed(
                    f"session {self.name!r}: service process died during "
                    f"a key-sync round trip")
            if self._closed:
                raise TransportError(
                    f"session {self.name!r} closed during a key sync")

    def _pre_publish_syncs(self, staged_bytes: int):
        """``ceil(staged_bytes / chunk)`` key syncs per publish. All but
        the last are full round trips (the chunk schedule's WRPKRU
        ping-pong); the final one is DEFERRED — its words ride ahead of
        the slot publish and the publish's single doorbell ring, and the
        child acks it before draining (enforced in ``_child_drain``), so
        the common single-chunk case (mpklink_opt) costs exactly one
        process wakeup per exchange instead of two."""
        syncs = max(1, -(-staged_bytes // self.chunk))
        for _ in range(syncs - 1):
            self._sync_key(self.key_client, WRITE)
        self._post_sync(self.key_client, WRITE)

    def submit(self, payload: np.ndarray,
               timeout: Optional[float] = None) -> int:
        payload = np.ascontiguousarray(np.asarray(payload))
        rows = framing.frame_rows(payload.nbytes)
        seq = self._seq

        def seal(buf: np.ndarray):
            r = framing.seal_into(buf, payload, seed=self.seed, seq=seq,
                                  mac_impl=self._mac)
            return r, payload.nbytes
        return self._stage(seal, payload.nbytes, rows, timeout=timeout)

    def request_into(self, nbytes: int, fill,
                     timeout: Optional[float] = None) -> np.ndarray:
        """Zero-copy producer path into the SHARED segment: ``fill(dst)``
        writes the message straight into the request slot's payload rows
        inside the slab — it is never materialized in private memory."""
        self._check_usable()
        eff = self.transport.timeout if timeout is None else timeout
        deadline = time.monotonic() + eff
        rows = framing.frame_rows(nbytes)
        seq = self._seq

        def seal(buf: np.ndarray):
            body = buf[1:rows].reshape(-1).view(np.uint8)[:nbytes]
            fill(body)
            framing.seal_prefilled(buf, nbytes, seed=self.seed, seq=seq,
                                   mac_impl=self._mac)
            return rows, nbytes
        t = self._stage(seal, nbytes, rows, timeout=eff)
        self.flush()
        return self.poll(t, max(1e-3, deadline - time.monotonic()))

    # -- fused lockstep fast path ------------------------------------------
    def request(self, payload: np.ndarray,
                timeout: Optional[float] = None) -> np.ndarray:
        """Lockstep exchange with submit→flush→poll fused: the slot is
        published directly (no STAGED hop, no ticket bookkeeping — nothing
        else can redeem it), with the same wire words, the same key-sync
        schedule, and the same error taxonomy. Mixed use falls back to the
        pipelined path so interleaved submit() tickets keep their publish
        ordering."""
        if self._staged:
            return super().request(payload, timeout=timeout)
        self._check_usable()
        eff = self.transport.timeout if timeout is None else timeout
        deadline = time.monotonic() + eff
        payload = np.ascontiguousarray(np.asarray(payload))
        nbytes = payload.nbytes
        if nbytes > self.capacity:
            raise CapacityError(
                f"{self.transport.name} segment ({self.capacity}B) cannot "
                f"hold {nbytes}B payload")
        self._ensure_proc()
        self._await_slot(deadline)
        rows = framing.frame_rows(nbytes)
        cached = self._req_cache
        if cached is not None and cached.shape[0] >= rows:
            req_buf, self._req_cache = cached, None
        else:
            req_buf = self._acquire(rows)
        resp_buf = self._acquire(self._cap_rows)
        t = self._tickets
        seq = self._seq
        framing.seal_into(req_buf, payload, seed=self.seed, seq=seq,
                          mac_impl=self._mac)
        w = self._w
        b = PROC_CTRL_WORDS + (t % self._nslots) * PROC_SLOT_WORDS
        tick = t & _U32
        w[b + _S_TICKET] = tick
        w[b + _S_REQ_OFF] = self.arena.offset_rows(req_buf)
        w[b + _S_REQ_ROWS] = rows
        w[b + _S_REQ_NBYTES] = nbytes
        w[b + _S_RESP_OFF] = self.arena.offset_rows(resp_buf)
        w[b + _S_RESP_CAP] = resp_buf.shape[0]
        w[b + _S_RESP_ROWS] = 0
        w[b + _S_RESP_NBYTES] = 0
        w[b + _S_ERR] = _ERR_OK
        w[b + _S_SEQ] = seq & _U32
        with self._slk:
            self._tickets += 1
            self._seq += 1
        self._pre_publish_syncs(rows * framing.LANES * 4)
        w[b + _S_STATE] = _PUBLISHED    # written LAST: syncs ride ahead
        self._pbell_svc.ring()

        def settled():
            return (w[b + _S_STATE] == _DONE
                    and w[b + _S_TICKET] == tick) \
                or self._crashed or self._closed
        while True:
            # slice-bounded park (see _await_slot): crash detection is
            # bounded by _LIVENESS_SLICE even without the EOF fast path
            self._pbell_cli.wait(
                settled, min(_LIVENESS_SLICE,
                             max(0.0, deadline - time.monotonic())),
                on_eof=self._mark_crashed)
            if w[b + _S_STATE] == _DONE and w[b + _S_TICKET] == tick:
                break
            if self._dead():
                # crash invariant: buffers of a slot a dead child may
                # still reference are NEVER released back to the arena
                raise ServiceCrashed(
                    f"session {self.name!r}: service process died with "
                    f"ticket {t} in flight")
            if self._closed:
                raise TransportError(f"session {self.name!r} is closed")
            if time.monotonic() >= deadline:
                self._poisoned = True
                raise ResponseTimeout(
                    f"{self.transport.name} response timed out after {eff}s")
        self._fold_svc_syncs()
        if w[b + _S_ERR] == _ERR_BLOB:
            blob = bytes(resp_buf.reshape(-1).view(np.uint8)
                         [:w[b + _S_RESP_NBYTES]])
            w[b + _S_STATE] = _FREE
            self.arena.release(req_buf)
            self.arena.release(resp_buf)
            _raise_remote(blob)
        try:
            out = self._extract(b, (req_buf, resp_buf, seq))
        except framing.FrameError:
            w[b + _S_STATE] = _FREE
            self.arena.release(req_buf)
            self.arena.release(resp_buf)
            raise
        w[b + _S_STATE] = _FREE
        if self._req_cache is None:
            self._req_cache = req_buf
        else:
            self.arena.release(req_buf)
        self.arena.release_on_collect(out, resp_buf)
        return out

    def _extract(self, b: int, rec: Tuple) -> np.ndarray:
        _req_buf, resp_buf, seq = rec
        # READ-check verdict cached per registry epoch (every invalidating
        # mutation — revoke, free_domain — bumps it); an epoch change
        # re-runs the check and raises exactly as the uncached path did
        ep = self.registry.epoch(self.domain)
        if self._read_check_ep != ep:
            self.registry.check(self.key_client, READ)
            self._read_check_ep = ep
        # mpklint: disable=MPK102 reason=sole caller poll() registers arena.release_on_collect(out, resp_buf) before the view escapes
        return framing.verify_view(
            resp_buf[:self._w[b + _S_RESP_ROWS]], seed=self.seed,
            expect_seq=seq, mac_impl=self._mac)

    def _fold_svc_syncs(self):
        seen = self._w[_W_SVC_SYNC]
        delta = (seen - self._svc_sync_seen) & _U32
        if delta:
            self._svc_sync_seen = seen
            with self._sync_slk:
                self.sync_count += delta
            self.transport._bump_sync(int(delta))


# ---------------------------------------------------------------------------
# the service child
# ---------------------------------------------------------------------------

def _service_child_main(session: ProcSession) -> None:
    """Entry point of the forked service process. Runs the drain loop and
    ALWAYS leaves via ``os._exit`` so no inherited finalizer (segment
    unlink, parent sockets, atexit hooks) can run in the child."""
    try:
        # the fork snapshot carries the parent's whole heap (accelerator
        # stack included); freeze it into the permanent generation so a
        # collection in this service never re-scans hundreds of thousands
        # of inherited objects — a gen-2 pass would stall the data plane
        # for ~100ms. New per-request garbage is refcount-reclaimed.
        gc.freeze()
        session._seg.disown()
        # fd hygiene: the fork snapshot carries every OTHER live session's
        # doorbell fds; while this child holds a sibling's bell write end,
        # that sibling's client would never see EOF when its own child is
        # killed. Close all foreign bells so peer-death EOF stays prompt.
        for other in list(_LIVE_PROC_SESSIONS):
            if other is not session:
                other._pbell_svc.close()
                other._pbell_cli.close()
        session._pbell_svc.keep_reader()
        session._pbell_cli.keep_writer()
        _child_loop(session)
    # mpklint: disable=MPK105 reason=child exit path; the parent sees EOF either way
    except BaseException:
        pass
    finally:
        os._exit(0)


def _child_loop(session: ProcSession) -> None:
    w = session._w
    mpk = w[_W_MODE] == _MODE_MPKLINK
    nslots = session._nslots
    orphaned = []

    def pending() -> bool:
        if orphaned or w[_W_STOP]:
            return True
        if w[_W_SYNC_SEQ] != w[_W_SYNC_ACK]:
            return True
        head = w[_W_HEAD]
        b = PROC_CTRL_WORDS + (head % nslots) * PROC_SLOT_WORDS
        return w[b + _S_STATE] == _PUBLISHED \
            and w[b + _S_TICKET] == (head & _U32)

    while True:
        if w[_W_STOP] or orphaned:
            return
        served = _child_drain(session, mpk)
        if served:
            continue
        if w[_W_SYNC_SEQ] != w[_W_SYNC_ACK]:
            # a pending sync with NO published work is a blocking chunk
            # round trip: ack and wake the waiting writer. (A sync that
            # rides a publish is acked inside the drain, ring-free — the
            # deferred final sync is never awaited, so ringing here for
            # it would only wake the client spuriously.)
            w[_W_SYNC_ACK] = w[_W_SYNC_SEQ]
            session._pbell_cli.ring()
            continue
        # 2x the recv slice so the wake path stays on the doorbell's
        # single-syscall blocking-recv branch; stop/orphan responsiveness
        # is unaffected — close() rings the bell after raising STOP, and
        # parent death is an immediate EOF
        session._pbell_svc.wait(pending, _WAIT_SLICE * 2,
                                on_eof=lambda: orphaned.append(True))


def _child_error(session: ProcSession, b: int,
                 exc: BaseException) -> None:
    w = session._w
    blob = _pack_error(exc)
    cap = w[b + _S_RESP_CAP] * framing.LANES * 4
    blob = blob[:cap]
    off = w[b + _S_RESP_OFF]
    area = session._slab[off:off + w[b + _S_RESP_CAP]]
    area.reshape(-1).view(np.uint8)[:len(blob)] = np.frombuffer(
        blob, np.uint8)
    w[b + _S_RESP_NBYTES] = len(blob)
    w[b + _S_ERR] = _ERR_BLOB
    w[b + _S_STATE] = _DONE


def _child_drain(session: ProcSession, mpk: bool) -> bool:
    """Serve published slots in ticket order. One pass = one response-side
    key sync (mpklink mode) and ONE doorbell ring, however many slots
    completed — the process twin of the in-process drain."""
    w, slab = session._w, session._slab
    completed = 0
    while True:
        head = w[_W_HEAD]
        b = PROC_CTRL_WORDS + (head % session._nslots) * PROC_SLOT_WORDS
        if w[b + _S_STATE] != _PUBLISHED \
                or w[b + _S_TICKET] != (head & _U32):
            break
        # a publish's final key sync is deferred onto its doorbell ring:
        # apply (ack) any pending sync BEFORE serving the slot — no slot
        # is ever drained under an unacknowledged PKRU update. No ring:
        # deferred syncs are never awaited, and blocking ones are rung by
        # the loop's own ack branch.
        if w[_W_SYNC_SEQ] != w[_W_SYNC_ACK]:
            w[_W_SYNC_ACK] = w[_W_SYNC_SEQ]
        w[_W_HEAD] = (head + 1) & _U32
        req_off, req_rows = w[b + _S_REQ_OFF], w[b + _S_REQ_ROWS]
        if mpk:
            # the child's registry is a fork snapshot nobody mutates (the
            # documented control-plane limitation), so the R/W check is a
            # pure function — memoize the first passing verdict instead of
            # re-deriving it around every drain
            checked = session._srv_checked
            if not checked:
                session.registry.check(session.key_server, READ)
            try:
                req = framing.verify_view(
                    slab[req_off:req_off + req_rows], seed=session.seed,
                    expect_seq=w[b + _S_SEQ], mac_impl=session._mac)
            except framing.FrameError as e:
                _child_error(session, b, e)
                completed += 1
                continue
            if not checked:
                session.registry.check(session.key_server, WRITE)
                session._srv_checked = True
        else:
            req = slab[req_off:req_off + req_rows] \
                .reshape(-1).view(np.uint8)[:w[b + _S_REQ_NBYTES]]
        try:
            r = session.handler(req)
            # bytes responses (the common RPC shape) wrap zero-copy
            resp = np.frombuffer(r, np.uint8) \
                if isinstance(r, (bytes, bytearray)) \
                else np.ascontiguousarray(r).view(np.uint8).reshape(-1)
        except HandlerCrash:
            # the REAL crash fault: the service process dies by kill -9,
            # mid-drain, possibly holding this sealed slot — the parent
            # sees doorbell EOF and surfaces typed ServiceCrashed
            os.kill(os.getpid(), signal.SIGKILL)
        except DropResponse:            # injected wire drop: this slot
            w[b + _S_STATE] = _DROPPED  # never completes; its poll expires
            continue
        except Exception as e:
            _child_error(session, b, e)
            completed += 1
            continue
        resp_off = w[b + _S_RESP_OFF]
        resp_cap = w[b + _S_RESP_CAP]
        area = slab[resp_off:resp_off + resp_cap]
        if mpk:
            rows = framing.frame_rows(resp.nbytes)
            if rows > resp_cap:
                _child_error(session, b, CapacityError(
                    f"response ({resp.nbytes}B) exceeds the session's "
                    f"{session.capacity}B response area"))
                completed += 1
                continue
            framing.seal_into(area, resp, seed=session.seed,
                              seq=w[b + _S_SEQ], mac_impl=session._mac)
            w[b + _S_RESP_ROWS] = rows
        else:
            if resp.nbytes > resp_cap * framing.LANES * 4:
                _child_error(session, b, CapacityError(
                    f"shm segment ({session.capacity}B) cannot hold "
                    f"{resp.nbytes}B response"))
                completed += 1
                continue
            area.reshape(-1).view(np.uint8)[:resp.nbytes] = resp
        w[b + _S_RESP_NBYTES] = resp.nbytes
        w[b + _S_ERR] = _ERR_OK
        w[b + _S_STATE] = _DONE         # written LAST
        completed += 1
    if completed:
        if mpk:
            # ONE response-side key sync covers the drained pass (shared
            # accounting word; the client folds it into its counters)
            w[_W_SVC_SYNC] = (w[_W_SVC_SYNC] + 1) & _U32
        session._pbell_cli.ring()
    return bool(completed)


# ---------------------------------------------------------------------------
# process-backed transports
# ---------------------------------------------------------------------------

class ProcShmTransport(ShmTransport):
    """shm over a real process boundary (POSIX shared memory segment per
    session, service in a forked child). Same fixed-capacity semantics as
    the in-process shm transport."""

    name = "shm_proc"

    def _make_session(self, name):
        return ProcShmSession(self, name)


class ProcMPKLinkTransport(MPKLinkTransport):
    """MPKLink across a real process boundary: per-chunk PKRU key-sync
    ping-pong through shared control words, sealed frames in a shared
    segment, service in a forked child. ``capacity`` bounds one message
    direction (the segment is sized at session creation — unlike the
    in-process regions it cannot grow)."""

    name = "mpklink_proc"
    DEFAULT_CAPACITY = 256 * 1024

    def __init__(self, handler: Handler, chunk: Optional[int] = None,
                 mac_impl: Callable = fast_mac, *,
                 capacity: int = DEFAULT_CAPACITY, **kw):
        self.capacity = capacity
        super().__init__(handler, chunk=chunk, mac_impl=mac_impl, **kw)

    def _make_session(self, name):
        return ProcMPKLinkSession(self, name)


class ProcMPKLinkOptTransport(ProcMPKLinkTransport):
    """Process-backed mpklink_opt: ONE key sync per publish."""

    name = "mpklink_opt_proc"

    def __init__(self, handler: Handler, mac_impl: Callable = fast_mac, **kw):
        kw.setdefault("chunk", 1 << 62)
        super().__init__(handler, mac_impl=mac_impl, **kw)


# ---------------------------------------------------------------------------
# baseline pair: loopback REST (HTTP/1.1) and length-prefixed TCP RPC
# ---------------------------------------------------------------------------

class _Lifeline:
    """Parent-death watchdog for baseline server children: the child
    selects on the read end; EOF (parent exited or closed the lifeline)
    → ``os._exit``. Orphaned HTTP/RPC servers cannot outlive a test."""

    def __init__(self):
        self._rd, self._wr = socket.socketpair(
            socket.AF_UNIX, socket.SOCK_STREAM)

    def child_watch(self):
        self._wr.close()

        def watch():
            try:
                while self._rd.recv(64) not in (b"", None):
                    pass
            # mpklint: disable=MPK105 reason=any lifeline error means the parent is gone
            except OSError:
                pass
            os._exit(0)
        threading.Thread(target=watch, daemon=True).start()

    def parent_side(self):
        self._rd.close()

    def close(self):
        for s in (self._rd, self._wr):
            try:
                s.close()
            # mpklint: disable=MPK105 reason=best-effort teardown of already-closed fds
            except OSError:
                pass


class _ServerProcessTransport(Transport):
    """Shared machinery for the REST/sockrpc baselines: ONE server process
    per transport (forked lazily, adopting a listener socket the parent
    bound on 127.0.0.1), N client sessions with persistent connections.
    The parent closes its copy of the listener after the fork, so a dead
    server yields immediate connection-refused/reset — classified as
    :class:`ServiceCrashed` — instead of a hang."""

    def __init__(self, handler: Handler, timeout: float = 120.0,
                 ring_slots: Optional[int] = None,
                 credit_wait: Optional[float] = None):
        super().__init__(handler, timeout=timeout, ring_slots=ring_slots,
                         credit_wait=credit_wait)
        self.port: Optional[int] = None
        self._server_proc = None
        self._lifeline: Optional[_Lifeline] = None
        self._server_lock = threading.Lock()
        self._transport_closed = False

    def _child_serve(self, listener: socket.socket) -> None:
        raise NotImplementedError

    def _ensure_server(self):
        with self._server_lock:
            if self._transport_closed:
                raise TransportError(f"transport {self.name} is closed")
            if self._server_proc is not None and self._server_proc.is_alive():
                return
            listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
            listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
            listener.bind(("127.0.0.1", 0))
            listener.listen(128)
            self.port = listener.getsockname()[1]
            lifeline = _Lifeline()

            def child():
                try:
                    gc.freeze()     # same hygiene as the shm service child
                    for sess in list(_LIVE_PROC_SESSIONS):
                        sess._pbell_svc.close()     # inherited foreign bells
                        sess._pbell_cli.close()     # (see _service_child_main)
                    lifeline.child_watch()
                    self._child_serve(listener)
                # mpklint: disable=MPK105 reason=child exit path; clients see connection reset
                except BaseException:
                    pass
                finally:
                    os._exit(0)
            with _FORK_LOCK:
                proc = _FORK_CTX.Process(
                    target=child, daemon=True, name=f"{self.name}:server")
                proc.start()
            listener.close()            # child death ⇒ connection refused
            lifeline.parent_side()
            self._server_proc = proc
            self._lifeline = lifeline

    def kill_server(self):
        """Test hook: SIGKILL the server process (the real crash fault)."""
        with self._server_lock:
            if self._server_proc is not None and self._server_proc.is_alive():
                self._server_proc.kill()
                self._server_proc.join(timeout=1.0)

    def close(self):
        super().close()                 # close sessions first
        with self._server_lock:
            self._transport_closed = True
            if self._lifeline is not None:
                self._lifeline.close()  # EOF → child watchdog exits
            if self._server_proc is not None:
                self._server_proc.join(timeout=0.5)
                if self._server_proc.is_alive():
                    self._server_proc.kill()
                    self._server_proc.join(timeout=0.5)
                self._server_proc = None


class _BaselineSession(Session):
    """Lockstep client session over a private connection to the server
    process; submit/poll/call_batch ride the base lockstep fallback."""

    def ensure_started(self):
        """No in-process service thread — the server lives in the
        transport's child process."""

    def _classify(self, exc: BaseException) -> BaseException:
        self._conn_reset()
        return ServiceCrashed(
            f"session {self.name!r}: server process connection failed "
            f"({type(exc).__name__}: {exc})")

    def _conn_reset(self):
        pass


class RESTSession(_BaselineSession):
    def __init__(self, transport, name):
        super().__init__(transport, name)
        self._conn: Optional[http.client.HTTPConnection] = None

    def _conn_reset(self):
        if self._conn is not None:
            try:
                self._conn.close()
            # mpklint: disable=MPK105 reason=best-effort close of a broken connection
            except OSError:
                pass
            self._conn = None

    def request(self, payload: np.ndarray,
                timeout: Optional[float] = None) -> np.ndarray:
        self._check_usable()
        self.transport._ensure_server()
        eff = self.transport.timeout if timeout is None else timeout
        raw = np.ascontiguousarray(payload).view(np.uint8).reshape(-1)
        try:
            if self._conn is None:
                self._conn = http.client.HTTPConnection(
                    "127.0.0.1", self.transport.port, timeout=eff)
            self._conn.timeout = eff
            if self._conn.sock is not None:
                self._conn.sock.settimeout(eff)
            # an honest REST request: JSON body, binary payload base64'd
            # into it — the serialization cost the paper charges REST
            self._conn.request(
                "POST", "/invoke",
                body=json.dumps(
                    {"payload": base64.b64encode(raw.tobytes())
                     .decode("ascii")}),
                headers={"Content-Type": "application/json"})
            r = self._conn.getresponse()
            body = r.read()
        except socket.timeout:
            self._poisoned = True       # a late response is still in the
            self._conn_reset()          # stream; never reuse this connection
            raise ResponseTimeout(f"rest response timed out after {eff}s")
        except (ConnectionError, http.client.HTTPException, OSError) as e:
            raise self._classify(e) from None
        doc = json.loads(body)
        if r.status != 200:
            _raise_remote(base64.b64decode(doc["error"]))
        return np.frombuffer(base64.b64decode(doc["result"]), np.uint8)

    def _teardown(self):
        self._conn_reset()


class RESTTransport(_ServerProcessTransport):
    """The paper's REST baseline, made honest: a real HTTP/1.1 server
    (``ThreadingHTTPServer``, thread per connection) in its own process
    on loopback TCP; requests are ``POST /invoke`` with a JSON body whose
    binary payload rides base64 (the serialize/deserialize REST
    microservices actually pay), handler errors come back as status 500
    with a typed error blob base64'd into a JSON document, and a handler
    crash kills the whole server process."""

    name = "rest"

    def _child_serve(self, listener: socket.socket) -> None:
        transport = self

        class _Handler(BaseHTTPRequestHandler):
            protocol_version = "HTTP/1.1"
            # real REST stacks (uvicorn, gunicorn) disable Nagle; without
            # this the split header/body writes interact with delayed ACK
            # into a ~40ms per-request stall that would flatter MPKLink
            disable_nagle_algorithm = True

            def do_POST(self):
                n = int(self.headers.get("Content-Length", 0) or 0)
                # the paper's REST model: the message body is a JSON
                # document, the binary payload rides base64 inside it —
                # both directions pay the serialize/deserialize that REST
                # microservices actually pay
                doc = json.loads(self.rfile.read(n))
                req = np.frombuffer(
                    base64.b64decode(doc["payload"]), np.uint8)
                try:
                    resp = np.ascontiguousarray(transport.handler(req)) \
                        .view(np.uint8).reshape(-1)
                except HandlerCrash:
                    os.kill(os.getpid(), signal.SIGKILL)
                except DropResponse:    # injected wire drop: no reply; the
                    self.close_connection = True    # client deadline expires
                    return
                except Exception as e:
                    blob = json.dumps(
                        {"error": base64.b64encode(_pack_error(e))
                         .decode("ascii")}).encode()
                    self.send_response(500)
                    self.send_header("Content-Type", "application/json")
                    self.send_header("Content-Length", str(len(blob)))
                    self.end_headers()
                    self.wfile.write(blob)
                    return
                body = json.dumps(
                    {"result": base64.b64encode(resp.tobytes())
                     .decode("ascii")}).encode()
                self.send_response(200)
                self.send_header("Content-Type", "application/json")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *args):
                pass

        server = ThreadingHTTPServer(
            ("127.0.0.1", 0), _Handler, bind_and_activate=False)
        server.socket.close()
        server.socket = listener
        server.server_address = listener.getsockname()
        server.daemon_threads = True
        server.serve_forever(poll_interval=0.2)

    def _make_session(self, name):
        return RESTSession(self, name)


class SockRPCSession(_BaselineSession):
    def __init__(self, transport, name):
        super().__init__(transport, name)
        self._sock: Optional[socket.socket] = None

    def _conn_reset(self):
        if self._sock is not None:
            try:
                self._sock.close()
            # mpklint: disable=MPK105 reason=best-effort close of a broken connection
            except OSError:
                pass
            self._sock = None

    def request(self, payload: np.ndarray,
                timeout: Optional[float] = None) -> np.ndarray:
        self._check_usable()
        self.transport._ensure_server()
        eff = self.transport.timeout if timeout is None else timeout
        raw = np.ascontiguousarray(payload).view(np.uint8).reshape(-1)
        try:
            if self._sock is None:
                self._sock = socket.create_connection(
                    ("127.0.0.1", self.transport.port), timeout=eff)
                self._sock.setsockopt(socket.IPPROTO_TCP,
                                      socket.TCP_NODELAY, 1)
            self._sock.settimeout(eff)
            self._sock.sendall(_LEN.pack(raw.nbytes))
            self._sock.sendall(raw)
            n = _LEN.unpack(bytes(_recv_exact(self._sock, 8)))[0]
            if n & _ERR_BIT:
                _raise_remote(bytes(_recv_exact(self._sock, n & ~_ERR_BIT)))
            return np.frombuffer(_recv_exact(self._sock, n), np.uint8)
        except socket.timeout:
            self._poisoned = True
            self._conn_reset()
            raise ResponseTimeout(f"sockrpc response timed out after {eff}s")
        except ServiceCrashed:
            # _recv_exact classified a mid-read EOF (killed server) — the
            # same taxonomy as a dead ring-transport service
            self._conn_reset()
            raise
        except (ConnectionError, OSError) as e:
            raise self._classify(e) from None

    def _teardown(self):
        self._conn_reset()


class SockRPCTransport(_ServerProcessTransport):
    """Length-prefixed socket RPC over loopback TCP: the uds transport's
    exact ``_LEN``/``_ERR_BIT`` wire protocol, with a real TCP server
    process (thread per connection) on the other end — what a minimal
    hand-rolled RPC microservice actually deploys as."""

    name = "sockrpc"

    def _child_serve(self, listener: socket.socket) -> None:
        def serve_conn(conn: socket.socket):
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            while True:
                try:
                    n = _LEN.unpack(bytes(_recv_exact(conn, 8)))[0]
                    req = np.frombuffer(_recv_exact(conn, n), np.uint8)
                except (TransportError, OSError):
                    return
                try:
                    resp = np.ascontiguousarray(self.handler(req)) \
                        .view(np.uint8).reshape(-1)
                except HandlerCrash:
                    os.kill(os.getpid(), signal.SIGKILL)
                except DropResponse:    # injected wire drop: no reply
                    continue
                except Exception as e:
                    blob = _pack_error(e)
                    try:
                        conn.sendall(_LEN.pack(len(blob) | _ERR_BIT))
                        conn.sendall(blob)
                    except OSError:
                        return
                    continue
                try:
                    conn.sendall(_LEN.pack(resp.nbytes))
                    conn.sendall(resp)
                except OSError:
                    return

        while True:
            conn, _addr = listener.accept()
            threading.Thread(target=serve_conn, args=(conn,),
                             daemon=True).start()

    def _make_session(self, name):
        return SockRPCSession(self, name)


# ---------------------------------------------------------------------------
# registries (kept SEPARATE from transports.TRANSPORTS: the in-process
# matrix keeps its in-process semantics; gateway name resolution merges)
# ---------------------------------------------------------------------------

PROC_TRANSPORTS = {
    ProcShmTransport.name: ProcShmTransport,
    ProcMPKLinkTransport.name: ProcMPKLinkTransport,
    ProcMPKLinkOptTransport.name: ProcMPKLinkOptTransport,
}

BASELINE_TRANSPORTS = {
    RESTTransport.name: RESTTransport,
    SockRPCTransport.name: SockRPCTransport,
}
