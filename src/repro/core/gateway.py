"""MPKLink service gateway: named services multiplexed over one transport.

The transports in :mod:`repro.core.transports` move bytes between ONE client
and ONE handler. The gateway is the routing/multiplexing layer the paper's
microservice story needs on top: a single co-located process exposes N
**named services**, each behind its own **protection domain**, and M
concurrent clients call them through one transport.

Wire format (one gateway envelope per transport message; the normative
spec lives in docs/protocol.md):

  request   [GW_MAGIC, service_id, client_id, token]  (4×u32 route words)
            + MPKLink frame (framing.build_frame) MAC-seeded with the
              (client, service) channel seed and per-channel sequence
  response  [GW_MAGIC, status, service_id, err_len]
            + status 0: response frame under the same channel seed/seq
            + status 1: msgpack {"type", "msg"} error blob (typed re-raise
              client-side — AccessViolation / FrameError / CapacityError)

Batch envelope (the pipelined data plane — N messages, ONE round trip,
ONE vectorized MAC pass per side):

  request   [GW_BATCH_MAGIC, service_id, client_id, n_items]
            + n_items frames concatenated row-wise, sequence numbers
              chan.seq .. chan.seq+n-1 (each frame is self-describing, so
              the server carves the concatenation with framing.split_frames
              and verifies all MACs in one framing.verify_batch pass)
  response  [GW_MAGIC, 2 (batch-ok), service_id, n_items]
            + per item: [GW_MAGIC, status, byte_len, 0] + body (status 0:
              response frame, sealed batch-wide in one framing.seal_batch
              pass; status 1: msgpack error blob, padded to 4B) — so one
              failed message stays a typed per-item error while the rest of
              the batch completes.
            Whole-batch failures (unknown service, no channel, desynced
            frame walk) use the plain single-message error envelope.

Scatter envelope (the sharded parallel executor — N messages for N
*different* services, ONE round trip, handlers executed concurrently
across the gateway's worker shards):

  request   [GW_SCAT_MAGIC, client_id, n_items, 0]
            + per item: [GW_MAGIC, service_id, token, 0] + one frame
              (self-sizing via its header) sealed with THAT service's
              channel seed; same-channel items carry consecutive sequences
              in item order
  response  [GW_MAGIC, 3 (scatter-ok), client_id, n_items]
            + per item: the batch envelope's item layout (status 0 frame /
              status 1 typed error blob)

With ``workers=N`` the gateway runs N shard threads; each service is
pinned to shard ``sid % N``, so one scatter envelope's items fan out
across shards and a slow service no longer head-of-line blocks its
neighbours — while per-channel order, sequence discipline, idempotency
dedup and breaker semantics stay EXACTLY the single-call ones (a channel's
items replay the single-call pipeline serially on its service's shard).
Scatter items use the batch envelope's positional sequence discipline:
every consumed item advances its channel, success or failure.

Isolation model (the paper's §V, finally with >2 endpoints):

* every service gets its own :class:`ProtectionDomain` in the gateway's
  shared :class:`KeyRegistry`; the service holds an RW key on it;
* a client must enroll with the gateway CA (key pair + proof of
  possession) and *open* a channel per service: the CA re-verifies the
  client certificate (and the service's allow-list) before issuing the
  client a capability key on that service's domain;
* the channel MAC seed = service-domain tag ⊕ epoch-mix ⊕ DH session key
  of (client, service) — so a frame built with service A's channel seed is
  rejected by service B's guard (FrameError), and a client holding no key
  for B is rejected at the capability check (AccessViolation). A foreign
  client can never read another service's region, only its own;
* revocation bumps the service-domain epoch: stale keys fail the PKRU
  check and stale frames fail the MAC — the analogue of flushing stale
  PKRU state from every thread that ever cached the key.

Dispatch runs on the per-session service threads of the underlying
transport, so N clients drive N concurrent request streams; per-channel
sequence numbers keep each stream's framing order independent. For the
mpklink transports the gateway shares its registry/CA with the transport,
putting link-level channel domains and service domains in ONE key table
(one software PKRU file per process, like the hardware).
"""
from __future__ import annotations

import itertools
import random
import queue
import threading
import time
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set, Tuple, Union

import numpy as np

from repro.core import framing
from repro.core.ca import CertificateAuthority, enroll
from repro.core.domains import (AccessViolation, DomainKey, KeyRegistry,
                                ProtectionDomain, RW, READ, WRITE, mac_seed)
from repro.core.transports import (DeadlineExpired, HandlerCrash,
                                   MPKLinkTransport, Overloaded, RateLimited,
                                   ResponseTimeout, ServiceCrashed,
                                   ServiceUnavailable, Transport,
                                   TransportError, _pack_error, _raise_remote,
                                   fast_mac)

Handler = Callable[[np.ndarray], np.ndarray]

GW_MAGIC = 0x4D504B47               # "MPKG"
GW_BATCH_MAGIC = 0x4D504B42         # "MPKB" — batch request envelope
GW_SCAT_MAGIC = 0x4D504B53          # "MPKS" — scatter (multi-service) envelope
_ROUTE_BYTES = 16                   # 4 × u32 route words
_OK, _ERR, _BOK, _SOK = 0, 1, 2, 3  # _BOK/_SOK: batch/scatter response follows
_MAX_SCATTER = 1024                 # items per scatter envelope

# replica fleet states (normative: docs/protocol.md §8) — the drain state
# machine is strictly forward: ACTIVE → DRAINING → QUIESCED, with DEAD
# reachable from ACTIVE/DRAINING on a detected process crash. A replica's
# session/segment resources are recycled only from QUIESCED (the fleet
# twin of procwire's crash invariant: in-flight slots never recycle).
REPLICA_ACTIVE = 0
REPLICA_DRAINING = 1
REPLICA_QUIESCED = 2
REPLICA_DEAD = 3
_REPLICA_STATE_NAMES = {REPLICA_ACTIVE: "active",
                        REPLICA_DRAINING: "draining",
                        REPLICA_QUIESCED: "quiesced",
                        REPLICA_DEAD: "dead"}
FLEET_CHOICES = 2                   # power-of-two-choices candidate count
HEDGE_RESERVOIR = 128               # dispatch-latency samples behind the
                                    # adaptive hedge-delay quantile
REKEY_LIMIT = 8                     # consecutive stale-epoch re-keys one
                                    # call survives: each corresponds to a
                                    # distinct membership/revocation epoch
                                    # bump racing the call (a supervisor
                                    # heal is two — release + join); a
                                    # banned client fails inside reopen()
                                    # itself, so this cannot spin


# ---------------------------------------------------------------------------
# propagated deadlines (normative: docs/protocol.md §9)
#
# A client call's remaining budget rides the envelope in the MAC-covered
# lane-10 deadline word (framing.DEADLINE_LANE). The gateway's execution
# cores convert it to an absolute time.monotonic() deadline at arrival,
# shed already-expired work BEFORE execution with a typed DeadlineExpired,
# and expose the deadline to in-process hops (fleet dispatch, EngineService)
# through a thread-local — so every wait downstream derives from the
# propagated budget instead of a fresh constant.
# ---------------------------------------------------------------------------

_BUDGET = threading.local()


def current_deadline() -> Optional[float]:
    """Absolute ``time.monotonic()`` deadline of the request the calling
    thread is currently executing under the gateway (None = no deadline).
    Set by the execution cores around every handler invocation from the
    envelope's lane-10 budget word."""
    return getattr(_BUDGET, "deadline", None)


def remaining_budget() -> Optional[float]:
    """Seconds left on the current request's propagated deadline (None =
    no deadline; may be <= 0 when already expired). In-process handlers
    (EngineService, fleet dispatch) clamp their waits with this."""
    d = current_deadline()
    return None if d is None else d - time.monotonic()


def _push_deadline(deadline: Optional[float]) -> Optional[float]:
    prev = getattr(_BUDGET, "deadline", None)
    _BUDGET.deadline = deadline
    return prev


def _pop_deadline(prev: Optional[float]) -> None:
    _BUDGET.deadline = prev


def current_identity() -> Optional[str]:
    """CA identity (client name) of the request the calling thread is
    currently executing under the gateway (None = not in a request, or an
    identity-less hop). Set by the execution cores around every handler
    invocation; downstream hops (fleet dispatch WFQ) key their per-tenant
    deficit counters on it (docs/protocol.md §10)."""
    return getattr(_BUDGET, "identity", None)


def current_priority() -> int:
    """Priority class of the request the calling thread is currently
    executing (the verified frame's MAC-covered lane-12 word; cohort paths
    publish the most-urgent class present). ``PRIO_NORMAL`` outside a
    request. In-process handlers (EngineService admission) order their
    queues with this (docs/protocol.md §10)."""
    return getattr(_BUDGET, "priority", framing.PRIO_NORMAL)


def _push_qos(identity: Optional[str], priority: int) -> tuple:
    prev = (getattr(_BUDGET, "identity", None),
            getattr(_BUDGET, "priority", framing.PRIO_NORMAL))
    _BUDGET.identity = identity
    _BUDGET.priority = priority
    return prev


def _pop_qos(prev: tuple) -> None:
    _BUDGET.identity, _BUDGET.priority = prev


# priority classes ordered by urgency: HIGH expedites, BULK yields.
# Rank order (lower = more urgent) is the ONE comparison every QoS
# consumer (coalescer window, serving admission) shares.
_PRIO_RANK = {framing.PRIO_HIGH: 0, framing.PRIO_NORMAL: 1,
              framing.PRIO_BULK: 2}


def priority_rank(priority: int) -> int:
    """Scheduling rank of a priority class — lower is more urgent.
    Unknown classes rank as PRIO_NORMAL (defensive: verified frames can
    only carry the three spec classes)."""
    return _PRIO_RANK.get(int(priority), 1)


def _frame_deadline(frame: np.ndarray) -> Optional[float]:
    """Absolute deadline from a VERIFIED frame's lane-10 budget word
    (relative-budget propagation: the receiver restarts the remaining
    budget at arrival, the cross-process-safe convention since monotonic
    clocks don't compare across processes)."""
    us = framing.frame_deadline_us(frame)
    return None if us == 0 else time.monotonic() + us / 1e6


def _frame_priority(frame: np.ndarray) -> int:
    """Priority class from a VERIFIED frame's lane-12 word (MAC-covered —
    a tampered class cannot reach scheduling decisions)."""
    return framing.frame_priority(frame)


class RetryBudget:
    """Token-bucket cap on EXTRA attempts (liveness retries + hedges) so
    retry storms cannot amplify an outage (docs/protocol.md §9).

    Each primary call earns ``ratio`` tokens (capped at ``burst``); every
    extra attempt spends one whole token via :meth:`take`. With the
    default ratio 0.1 a client in steady state retries at most ~10% extra
    load, with bursts of up to ``burst`` back-to-back retries when the
    bucket is full. Thread-safe: one budget may be shared by a client's
    retries and a fleet's hedges — total extra attempts stay bounded by
    the one bucket."""

    def __init__(self, ratio: float = 0.1, burst: int = 3,
                 initial: Optional[float] = None):
        if ratio < 0 or burst < 1:
            raise ValueError("retry budget needs ratio >= 0, burst >= 1")
        self.ratio = float(ratio)
        self.burst = float(burst)
        self._tokens = self.burst if initial is None else float(initial)
        self._lock = threading.Lock()
        self.spent = 0                  # extra attempts granted
        self.denied = 0                 # extra attempts refused

    def note_primary(self) -> None:
        """A primary attempt happened: earn ``ratio`` tokens. Earning is
        unconditional — a bucket that ran dry refills from later primaries
        (every layer that drives primaries through a budget MUST call this
        on completion, not only on the admission branch; a dry bucket that
        never earns again disables its retries/hedges forever)."""
        with self._lock:
            self._tokens = min(self.burst, self._tokens + self.ratio)

    def take(self) -> bool:
        """Spend one token for an extra attempt. → False (and the caller
        must NOT retry/hedge) when the bucket is dry."""
        with self._lock:
            if self._tokens >= 1.0:
                self._tokens -= 1.0
                self.spent += 1
                return True
            self.denied += 1
            return False

    def tokens(self) -> float:
        with self._lock:
            return self._tokens


class TokenBucket:
    """Per-identity admission token bucket (docs/protocol.md §10).

    Continuous refill at ``rate`` tokens/second up to ``burst`` capacity,
    lazily computed from the monotonic clock (no refill thread). One
    request costs one token (batch/scatter envelopes cost one per item).
    :meth:`try_take` never blocks: it either admits (→ 0.0) or returns the
    ``retry_after`` seconds until the bucket holds enough tokens for this
    take — the hint sealed into the typed :class:`RateLimited` shed, so a
    well-behaved tenant converges onto its configured rate instead of
    hammering the admission check."""

    def __init__(self, rate: float, burst: float):
        if rate <= 0 or burst < 1:
            raise ValueError("token bucket needs rate > 0, burst >= 1")
        self.rate = float(rate)
        self.burst = float(burst)
        self._tokens = self.burst
        self._stamp = time.monotonic()
        self._lock = threading.Lock()
        self.admitted = 0
        self.shed = 0

    def try_take(self, n: int = 1) -> float:
        """Charge ``n`` tokens. → 0.0 when admitted, else the seconds
        until the bucket refills enough for an ``n``-token take."""
        with self._lock:
            now = time.monotonic()
            self._tokens = min(self.burst,
                               self._tokens + (now - self._stamp) * self.rate)
            self._stamp = now
            if self._tokens >= n:
                self._tokens -= n
                self.admitted += n
                return 0.0
            self.shed += n
            return (n - self._tokens) / self.rate

    def tokens(self) -> float:
        with self._lock:
            return self._tokens


# Deficit replenished per round-robin round per unit weight, in request
# cost units (docs/protocol.md §10). Small enough that interleaving stays
# fine-grained, large enough that a weight-1 flow clears a single-item
# turn in one round.
WFQ_QUANTUM = 4


class WeightedFairQueue:
    """Deficit-round-robin work queue across flows (tenants / services).

    Classic DRR (docs/protocol.md §10): each flow with queued work holds a
    deficit counter; the flow at the head of the active ring dequeues while
    its head item's cost fits its deficit, a flow that cannot afford its
    head item earns ``quantum x weight(flow)`` and rotates to the ring
    tail, and a flow that empties leaves the ring forfeiting its remaining
    deficit (no banked credit for idle flows). Long-run service share is
    proportional to weight, and one flow's backlog can delay another flow
    by at most one max-cost item per round — the isolation property the
    sharded executor needs against a noisy tenant.

    Thread-safe; :meth:`pop` blocks. After :meth:`close`, pops drain
    whatever is queued and then return ``None`` (the shard shutdown
    contract)."""

    def __init__(self, weight_of: Optional[Callable[[object], float]] = None,
                 quantum: float = WFQ_QUANTUM):
        if quantum <= 0:
            raise ValueError("quantum must be > 0")
        self._weight_of = weight_of or (lambda key: 1.0)
        self.quantum = float(quantum)
        self._cv = threading.Condition()
        self._flows: "OrderedDict[object, deque]" = OrderedDict()
        self._deficit: Dict[object, float] = {}
        self._size = 0
        self._closed = False
        self.pushed = 0
        self.popped = 0
        self.rounds = 0                 # quantum replenishments handed out

    def push(self, item, key=None, cost: float = 1) -> None:
        with self._cv:
            q = self._flows.get(key)
            if q is None:
                q = self._flows[key] = deque()
                self._deficit[key] = 0.0
            q.append((item, max(0.0, float(cost))))
            self._size += 1
            self.pushed += 1
            self._cv.notify()

    def _pop_locked(self):
        while self._flows:
            key, q = next(iter(self._flows.items()))
            item, cost = q[0]
            if self._deficit[key] >= cost:
                q.popleft()
                self._deficit[key] -= cost
                self._size -= 1
                self.popped += 1
                if not q:               # empty flows forfeit their deficit
                    del self._flows[key]
                    del self._deficit[key]
                return (item, key)
            # head flow can't afford its item: one round's quantum, rotate.
            # Terminates: the deficit grows every visit, the cost doesn't.
            weight = max(1e-9, float(self._weight_of(key)))
            self._deficit[key] += self.quantum * weight
            self._flows.move_to_end(key)
            self.rounds += 1
        return None

    def pop(self, timeout: Optional[float] = None):
        """→ ``(item, key)`` in DRR order; ``None`` once closed AND
        drained (or on ``timeout``)."""
        end = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while True:
                got = self._pop_locked()
                if got is not None:
                    return got
                if self._closed:
                    return None
                if end is None:
                    self._cv.wait()
                else:
                    rem = end - time.monotonic()
                    if rem <= 0:
                        return None
                    self._cv.wait(rem)

    def qsize(self) -> int:
        with self._cv:
            return self._size

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()


class _FairGate:
    """DRR turnstile bounding concurrent in-flight cost across tenants —
    the :class:`WeightedFairQueue` discipline applied to the fleet's
    replica in-flight slots instead of a work queue (docs/protocol.md
    §10). ``acquire(tenant, cost)`` blocks until the gate grants the
    cost under ``capacity``; grants among waiting tenants follow the same
    per-tenant deficit counters, so one tenant's cohort backlog cannot
    monopolize the replica slots: the moment a second tenant queues, slots
    free up to it in weight proportion. A cost larger than ``capacity``
    is clamped to it (charged identically on release), so an oversized
    cohort admits alone rather than deadlocking."""

    def __init__(self, capacity: float, *,
                 weight_of: Optional[Callable[[object], float]] = None,
                 quantum: float = WFQ_QUANTUM):
        if capacity < 1:
            raise ValueError("fair gate needs capacity >= 1")
        self.capacity = float(capacity)
        self._weight_of = weight_of or (lambda key: 1.0)
        self.quantum = float(quantum)
        self._cv = threading.Condition()
        self._inflight = 0.0
        self._waiting: "OrderedDict[object, deque]" = OrderedDict()
        self._deficit: Dict[object, float] = {}
        self.granted = 0
        self.queued_waits = 0           # acquires that had to park
        self.rounds = 0

    def _charge(self, cost: float) -> float:
        return min(max(1.0, float(cost)), self.capacity)

    def _grant_locked(self) -> None:
        while self._waiting and self._inflight < self.capacity:
            key, q = next(iter(self._waiting.items()))
            ticket = q[0]               # [granted, charge]
            charge = ticket[1]
            if self._inflight + charge > self.capacity:
                return                  # head of ring waits for a release
            if self._deficit[key] >= charge:
                q.popleft()
                self._deficit[key] -= charge
                if not q:
                    del self._waiting[key]
                    del self._deficit[key]
                self._inflight += charge
                ticket[0] = True
                self.granted += 1
                continue
            weight = max(1e-9, float(self._weight_of(key)))
            self._deficit[key] += self.quantum * weight
            self._waiting.move_to_end(key)
            self.rounds += 1

    def acquire(self, key, cost: float = 1,
                deadline: Optional[float] = None) -> bool:
        """Block until ``cost`` (clamped to capacity) is granted under the
        DRR discipline. → False when ``deadline`` passes first (nothing
        charged — the caller sheds typed)."""
        charge = self._charge(cost)
        with self._cv:
            if not self._waiting and self._inflight + charge <= self.capacity:
                self._inflight += charge    # fast path: nobody parked
                self.granted += 1
                return True
            ticket = [False, charge]
            q = self._waiting.get(key)
            if q is None:
                q = self._waiting[key] = deque()
                self._deficit[key] = 0.0
            q.append(ticket)
            self.queued_waits += 1
            self._grant_locked()
            while not ticket[0]:
                if deadline is None:
                    self._cv.wait()
                    continue
                rem = deadline - time.monotonic()
                if rem <= 0:
                    break
                self._cv.wait(rem)
                if ticket[0]:
                    return True
            if ticket[0]:
                return True
            # timed out while parked: withdraw the ticket (never granted)
            q = self._waiting.get(key)
            if q is not None:
                try:
                    q.remove(ticket)
                except ValueError:
                    pass
                if not q:
                    self._waiting.pop(key, None)
                    self._deficit.pop(key, None)
            return False

    def release(self, cost: float = 1) -> None:
        with self._cv:
            self._inflight -= self._charge(cost)
            self._grant_locked()
            self._cv.notify_all()

    def inflight(self) -> float:
        with self._cv:
            return self._inflight


def _route(a: int, b: int, c: int) -> np.ndarray:
    return np.array([GW_MAGIC, a, b, c], "<u4").view(np.uint8)


def _batch_route(sid: int, cid: int, n: int) -> np.ndarray:
    return np.array([GW_BATCH_MAGIC, sid, cid, n], "<u4").view(np.uint8)


def _scatter_route(cid: int, n: int) -> np.ndarray:
    return np.array([GW_SCAT_MAGIC, cid, n, 0], "<u4").view(np.uint8)


def _seal_envelope(route4, arr: np.ndarray, *, seed: int, seq: int,
                   mac_impl, deadline_us: int = 0,
                   priority: int = 0) -> np.ndarray:
    """``[4 route words] + sealed frame`` assembled in ONE preallocated
    buffer — the frame is sealed in place behind the route words, so an
    envelope costs exactly one payload write (no build/concat chain).
    Honors ``framing.ZERO_COPY`` for A/B benchmarking."""
    if not framing.ZERO_COPY:
        frame = framing.build_frame(arr, seed=seed, seq=seq,
                                    mac_impl=mac_impl,
                                    deadline_us=deadline_us,
                                    priority=priority)
        return np.concatenate([np.array(route4, "<u4").view(np.uint8),
                               frame.reshape(-1).view(np.uint8)])
    arr = np.ascontiguousarray(np.asarray(arr))
    rows = framing.frame_rows(arr.nbytes)
    env = np.empty(_ROUTE_BYTES + rows * framing.LANES * 4, np.uint8)
    u = env.view("<u4")
    u[:4] = route4
    framing.seal_into(u[4:].reshape(rows, framing.LANES), arr, seed=seed,
                      seq=seq, mac_impl=mac_impl, deadline_us=deadline_us,
                      priority=priority)
    return env


class _Shard:
    """One executor worker of the sharded gateway: a FIFO queue drained by
    a dedicated thread. Services are pinned to shards (``sid % workers``),
    so one service's work keeps its arrival order (per-channel ordering)
    while different services execute concurrently on different shards.

    Fault-injection signals (``HandlerCrash``/``DropResponse``) and any
    other ``BaseException`` are captured and re-raised on the *dispatching*
    session thread, so crash semantics are identical to inline execution
    (the session thread dies, the client gets an immediate typed
    ``ServiceCrashed``) and the shard itself keeps serving."""

    def __init__(self, idx: int,
                 weight_of: Optional[Callable[[object], float]] = None):
        self.idx = idx
        self.executed = 0
        # DRR across tenants (docs/protocol.md §10): work is keyed by the
        # submitting identity, so one tenant's scatter backlog interleaves
        # fairly with other tenants' instead of head-of-line blocking the
        # shard thread. Unkeyed work (key=None) is its own weight-1 flow.
        self._q = WeightedFairQueue(weight_of=weight_of)
        self._closed = False
        self._lock = threading.Lock()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name=f"gw-shard-{idx}")
        self._thread.start()

    def _exec(self, item):
        fn, box, done = item
        try:
            box.append((True, fn()))
        except BaseException as e:          # noqa: B036 — relayed, not eaten
            box.append((False, e))
        finally:
            # the shard thread and close()-racing callers both execute
            # items — an unguarded += here drops counts
            with self._lock:
                self.executed += 1
            done.set()

    def _run(self):
        while True:
            got = self._q.pop()
            if got is None:
                # close(): the WFQ drained everything already queued before
                # reporting empty, so no dispatcher waits on a dead shard
                return
            self._exec(got[0])

    def submit(self, fn, key=None, cost: float = 1):
        """Enqueue ``fn`` under tenant flow ``key`` with DRR ``cost``
        (item count for cohort groups); returns (box, done) — wait on
        ``done``, then ``box[0]`` is (ok, result-or-exception). A scatter
        racing ``close()`` executes inline on the caller (same semantics,
        no parallelism) instead of queueing behind the shutdown drain."""
        box: list = []
        done = threading.Event()
        item = (fn, box, done)
        with self._lock:
            if not self._closed:
                self._q.push(item, key=key, cost=cost)
                return box, done
        self._exec(item)                    # shard gone: run on the caller
        return box, done

    def close(self):
        with self._lock:
            self._closed = True
            self._q.close()

    def queued(self) -> int:
        return self._q.qsize()


def _own_result(res):
    """Snapshot a response that aliases transport storage. The zero-copy
    data plane verifies responses to views of the session's region/arena
    slot, which stay valid only until the session's NEXT exchange — a
    contract fine for the transport layer but a silent-corruption footgun
    for GatewayClient users (r1's bytes would flip under them when r2 is
    issued). Client-facing results are therefore always OWNED arrays;
    the in-place zero-copy wins (seal/verify/envelope assembly) are on
    the wire path and unaffected."""
    if isinstance(res, np.ndarray) \
            and (res.base is not None or not res.flags.owndata):
        return res.copy()
    return res


def _as_frameable(arr: np.ndarray) -> np.ndarray:
    """Handlers may return any dtype/rank; frame unsupported ones as raw
    bytes. This must never fail: response sealing happens AFTER the
    channel sequence has advanced, so a sealing error would desync the
    channel permanently instead of surfacing as a typed per-item error."""
    arr = np.ascontiguousarray(arr)
    if np.dtype(arr.dtype) not in framing._DTYPE_CODES or arr.ndim > 4:
        arr = arr.view(np.uint8).reshape(-1)
    return arr


class ServiceHealth:
    """Per-service failure tracking + circuit breaker.

    States: ``closed`` (healthy) → ``open`` after ``threshold`` consecutive
    handler failures (requests are shed with a typed
    :class:`ServiceUnavailable` instead of hanging) → ``half_open`` after
    ``probe_after`` sheds (ONE probe request is let through; success closes
    the circuit, failure re-opens it). Counting sheds instead of wall-clock
    keeps chaos runs exactly replayable from a seed."""

    def __init__(self, threshold: int = 3, probe_after: int = 8):
        self.threshold = threshold
        self.probe_after = probe_after
        self.state = "closed"
        self.consecutive_failures = 0
        self.failures = 0               # lifetime handler failures
        self.crashes = 0                # lifetime handler-thread crashes
        self.sheds = 0                  # lifetime circuit rejections
        self.restarts = 0               # lifetime handler restarts
        self._shed_run = 0              # sheds since the circuit last opened
        self._lock = threading.Lock()

    def admit(self, service: str):
        """Gate a request. Raises ServiceUnavailable while the circuit is
        open (except for the half-open probe)."""
        with self._lock:
            if self.state == "closed":
                return
            if self.state == "open":
                if self._shed_run >= self.probe_after:
                    self.state = "half_open"    # this request is the probe
                    return
                self._shed_run += 1
                self.sheds += 1
                raise ServiceUnavailable(
                    f"service {service!r} circuit open "
                    f"({self.consecutive_failures} consecutive failures); "
                    f"shedding load ({self._shed_run}/{self.probe_after} "
                    f"before probe)")
            # half_open: another caller's probe is in flight; let it race —
            # both outcomes resolve the state below

    def success(self):
        with self._lock:
            self.consecutive_failures = 0
            self.state = "closed"
            self._shed_run = 0

    def failure(self, crashed: bool = False) -> bool:
        """Record a handler failure. → True when the breaker trips (the
        gateway then restarts the service if it can, else opens the
        circuit)."""
        with self._lock:
            self.failures += 1
            self.crashes += int(crashed)
            self.consecutive_failures += 1
            if self.state == "half_open":
                self.state = "open"
                self._shed_run = 0
                return True
            if self.state == "closed" \
                    and self.consecutive_failures >= self.threshold:
                return True
            return False

    def trip(self):
        with self._lock:
            self.state = "open"
            self._shed_run = 0

    def reset(self):
        with self._lock:
            self.state = "closed"
            self.consecutive_failures = 0
            self._shed_run = 0
            self.restarts += 1

    def snapshot(self) -> Dict[str, object]:
        with self._lock:
            return {"state": self.state,
                    "consecutive_failures": self.consecutive_failures,
                    "failures": self.failures, "crashes": self.crashes,
                    "sheds": self.sheds, "restarts": self.restarts}


class _Brownout:
    """Hysteretic overload controller for one service (protocol.md §9).

    Tracks an inflight gauge (admission → completion) and an EWMA of
    service time. Admission with the gauge at/above ``high_water`` — or,
    when configured, EWMA service time at/above ``high_water_ms`` —
    ENGAGES brownout: new admissions are shed with a typed
    :class:`Overloaded` carrying a ``retry_after`` backlog-drain estimate,
    instead of queueing into timeout collapse. Recovery is hysteretic:
    once engaged, sheds continue until the gauge drains to ``low_water``
    (and the EWMA, when gated on it, falls below ``high_water_ms``), so
    the controller cannot flap at the boundary."""

    def __init__(self, high_water: int = 64, low_water: Optional[int] = None,
                 high_water_ms: Optional[float] = None,
                 alpha: float = 0.2):
        if low_water is None:
            low_water = max(1, high_water // 2)
        if not (0 < low_water <= high_water):
            raise ValueError("brownout needs 0 < low_water <= high_water")
        self.high_water = int(high_water)
        self.low_water = int(low_water)
        self.high_water_ms = high_water_ms
        self.alpha = float(alpha)
        self.inflight = 0
        self.ewma_ms = 0.0
        self.engaged = False
        self.sheds = 0                  # admissions turned away
        self.engagements = 0            # times the high-water mark tripped
        self._lock = threading.Lock()

    def _over_high(self) -> bool:
        return (self.inflight >= self.high_water
                or (self.high_water_ms is not None
                    and self.ewma_ms >= self.high_water_ms))

    def _under_low(self) -> bool:
        return (self.inflight <= self.low_water
                and (self.high_water_ms is None
                     or self.ewma_ms < self.high_water_ms))

    def admit(self, name: str, weight: int = 1) -> None:
        """Gate an admission; on success the gauge is charged ``weight``
        and the caller MUST pair it with :meth:`done`."""
        with self._lock:
            if self.engaged:
                if self._under_low():
                    self.engaged = False
            elif self._over_high():
                self.engaged = True
                self.engagements += 1
            if self.engaged:
                self.sheds += weight
                retry_after = self.inflight * self.ewma_ms / 1e3
                raise Overloaded(
                    f"service {name!r} overloaded ({self.inflight} inflight, "
                    f"ewma {self.ewma_ms:.1f}ms; high water "
                    f"{self.high_water}); browning out new admissions",
                    retry_after=retry_after)
            self.inflight += weight

    def done(self, weight: int, elapsed_ms: float, ok: bool = True) -> None:
        with self._lock:
            self.inflight = max(0, self.inflight - weight)
            if ok:
                per = elapsed_ms / max(1, weight)
                a = self.alpha
                self.ewma_ms = per if self.ewma_ms == 0.0 else \
                    (1.0 - a) * self.ewma_ms + a * per

    def snapshot(self) -> Dict[str, object]:
        with self._lock:
            return {"engaged": self.engaged, "inflight": self.inflight,
                    "ewma_ms": round(self.ewma_ms, 3), "sheds": self.sheds,
                    "engagements": self.engagements,
                    "high_water": self.high_water,
                    "low_water": self.low_water}


@dataclass
class _Service:
    sid: int
    name: str
    handler: Handler
    domain: ProtectionDomain
    server_key: DomainKey
    allow: Optional[Set[str]]       # client-name allow-list; None = any cert
    factory: Optional[Callable[[], Handler]] = None   # restart hook
    # overload brownout controller (None = admission never browns out);
    # installed via ServiceGateway.enable_brownout
    brownout: Optional[_Brownout] = None
    # optional native batch entry point: takes a list of payloads, returns a
    # same-length list of responses (EngineService.handler_batch feeds the
    # continuous-batching decode loop through this)
    batch_handler: Optional[Callable] = None
    health: ServiceHealth = field(default_factory=ServiceHealth)
    # cid → (idempotency token → response payload): a retried request whose
    # original DID execute is answered from here, never re-executed. The
    # window is per-client so one client's traffic can never evict another
    # client's pending-retry token (a client is serial: its own window only
    # needs to cover its own last few calls)
    done: "OrderedDict[int, OrderedDict[int, np.ndarray]]" = \
        field(default_factory=OrderedDict)
    done_lock: threading.Lock = field(default_factory=threading.Lock)


_DONE_TOKENS = 16                   # dedup window depth per client
_DONE_CLIENTS = 256                 # client buckets kept per service (LRU)


@dataclass
class Channel:
    """One (client, service) grant: capability key + MAC seed + sequences.

    The two sequence counters advance in lock-step because the transport
    session is strictly request/response. If the transport fails between the
    server's increment and the client's (e.g. a response timeout), the
    channel is desynced — but the transport session poisons itself on
    timeout, so every later call fails loudly instead of mis-parsing;
    recovery is a fresh client."""
    cid: int
    sid: int
    service: str
    seed: int
    client_key: DomainKey
    seq: int = 0                    # client-side next sequence number
    server_seq: int = 0             # server-side expected sequence number
    slock: threading.Lock = field(default_factory=threading.Lock)


class ServiceGateway:
    """Dispatch table of named services over a single transport."""

    def __init__(self, transport: Union[str, type] = "mpklink_opt", *,
                 max_keys: int = 256, mac_impl: Callable = fast_mac,
                 workers: int = 0,
                 transport_kwargs: Optional[dict] = None):
        self.registry = KeyRegistry(max_keys=max_keys, seed=0x6A7E)
        self.ca = CertificateAuthority(self.registry)
        self._mac = mac_impl
        # batch-path MAC: None selects framing's fused vectorized pass
        # (bit-identical to fast_mac); a custom impl is honored per frame
        # so batched and single exchanges can never disagree
        self._batch_mac = None if mac_impl is fast_mac else mac_impl
        self._services: Dict[str, _Service] = {}
        self._by_sid: Dict[int, _Service] = {}
        self._channels: Dict[Tuple[int, int], Channel] = {}
        self._glock = threading.Lock()
        self._sid_counter = itertools.count(1)
        self._cid_counter = itertools.count(1)
        # workers=N: the sharded parallel executor — scatter envelopes fan
        # their items across N shard threads (service sid % N). workers=0
        # executes scatter items inline (sequentially) on the dispatching
        # session thread; single/batch envelopes are unaffected either way
        self.workers = workers
        # per-identity QoS state (docs/protocol.md §10): token buckets gate
        # admission, weights steer the WFQ shards / fleet fair gates, and
        # _cid_names resolves an envelope's client id back to its CA
        # identity (the tenant key) without re-walking the channel table
        self._tenant_buckets: Dict[str, TokenBucket] = {}
        self._tenant_weights: Dict[str, float] = {}
        self._cid_names: Dict[int, str] = {}
        self._shards: List[_Shard] = [
            _Shard(i, weight_of=self._tenant_weight) for i in range(workers)]
        self._mux: Optional["CallCoalescer"] = None
        self._fleets: Dict[str, "ServiceFleet"] = {}
        self.stats = {"requests": 0, "responses": 0, "macs_verified": 0,
                      "rejected": 0, "deduped": 0, "sheds": 0,
                      "restarts": 0, "crashes": 0, "scatter_envelopes": 0,
                      "expired": 0, "overloaded": 0, "rate_limited": 0}

        if isinstance(transport, str):
            from repro.core import ALL_TRANSPORTS
            transport = ALL_TRANSPORTS[transport]
        kwargs = dict(transport_kwargs or {})
        if isinstance(transport, type) and issubclass(transport, MPKLinkTransport):
            # one key table for link channels AND service domains
            kwargs.setdefault("registry", self.registry)
            kwargs.setdefault("ca", self.ca)
        self.transport: Transport = transport(self._dispatch, **kwargs)

    # -- service lifecycle --------------------------------------------------
    def register_service(self, name: str, handler: Handler,
                         allow: Optional[Set[str]] = None, *,
                         factory: Optional[Callable[[], Handler]] = None,
                         batch_handler: Optional[Callable] = None,
                         failure_threshold: int = 3,
                         probe_after: int = 8) -> int:
        """Enroll a service with the CA and give it its own protection
        domain. ``allow`` restricts which client names may open channels.
        ``factory`` makes the service self-healing: after
        ``failure_threshold`` consecutive handler failures the gateway
        replaces the handler with ``factory()``, bumps the domain epoch and
        lets still-certified clients re-key transparently. Without a
        factory the circuit opens instead and requests are shed with
        :class:`ServiceUnavailable` until a probe succeeds.
        ``batch_handler`` (list of payloads → same-length list of
        responses) lets a batch envelope execute as ONE native call —
        EngineService passes its handler_batch here so a batched prompt
        submission joins the decode slot grid as a single cohort."""
        with self._glock:
            if name in self._services:
                raise ValueError(f"service {name!r} already registered")
            enroll(self.ca, name)
            dom = self.registry.allocate_domain(f"svc:{name}")
            svc = _Service(next(self._sid_counter), name, handler, dom,
                           self.registry.issue_key(dom, RW),
                           set(allow) if allow is not None else None,
                           factory=factory, batch_handler=batch_handler,
                           health=ServiceHealth(failure_threshold,
                                                probe_after))
            self._services[name] = svc
            self._by_sid[svc.sid] = svc
            return svc.sid

    def restart_service(self, name: str) -> None:
        """Self-healing restart: swap in a fresh handler (via the service's
        factory, when present), bump the service-domain epoch so every
        outstanding key/frame on the domain goes stale (the PKRU-flush
        analogue), and re-key the service. Still-certified clients re-key
        transparently on their next call."""
        with self._glock:
            svc = self._services[name]     # lookup under the same lock the
            if svc.factory is not None:    # registration path mutates under
                svc.handler = svc.factory()
            self.registry.revoke(svc.server_key)          # epoch bump
            svc.server_key = self.registry.issue_key(svc.domain, RW)
            self.stats["restarts"] += 1
        svc.health.reset()

    def _rekey_service(self, name: str) -> None:
        """Bump the service-domain epoch and re-key the service WITHOUT
        swapping the handler — the fleet-membership analogue of
        :meth:`restart_service`'s key rotation. Every outstanding client
        key/frame on the domain goes stale; still-certified clients re-key
        transparently on their next call (ONE re-key, then traffic flows)."""
        with self._glock:
            svc = self._services[name]
            self.registry.revoke(svc.server_key)          # epoch bump
            svc.server_key = self.registry.issue_key(svc.domain, RW)

    # -- replica fleets ------------------------------------------------------
    def register_replica(self, name: str, handler: Handler, *,
                         transport: Union[str, type] = "mpklink_opt_proc",
                         transport_kwargs: Optional[dict] = None,
                         allow: Optional[Set[str]] = None,
                         router_seed: int = 0x524F5554,
                         failure_threshold: int = 3,
                         probe_after: int = 8) -> int:
        """Add one replica to service ``name``'s fleet (creating the fleet
        — and registering the service — on the first call). Returns the
        replica id.

        One service name maps to N replicas; each replica runs ``handler``
        behind its OWN transport instance (proc-backed by default: the
        handler executes in a forked child over a per-session POSIX shm
        segment) with its own key registry, protection domain and epoch —
        a frame sealed for one replica's link fails every other replica's
        guard. The gateway-side fleet routes each request to one replica
        via seeded power-of-two-choices least-loaded routing (in-flight +
        EWMA service time, :class:`ReplicaRouter`); batch envelopes and
        auto-coalesced cohorts land WHOLE on one replica
        (:meth:`ServiceFleet.dispatch_batch` is the service's
        ``batch_handler``), so a cohort joins one replica's ring as one
        pipelined unit.

        Joining an existing fleet under live traffic bumps the service
        domain epoch (the membership change is a re-key event): every
        client re-keys transparently ONCE through the CA, after which the
        new replica is in the routing set. ``allow``/breaker options apply
        on the first call only (they configure the service, not the
        replica)."""
        with self._glock:
            fleet = self._fleets.get(name)
            creating = fleet is None
            if creating:
                if name in self._services:
                    raise ValueError(
                        f"service {name!r} already registered without a "
                        f"fleet — fleets and plain handlers don't mix")
                fleet = ServiceFleet(self, name, router_seed=router_seed)
                self._fleets[name] = fleet
        if creating:
            self.register_service(name, fleet.dispatch, allow,
                                  batch_handler=fleet.dispatch_batch,
                                  failure_threshold=failure_threshold,
                                  probe_after=probe_after)
        rid = fleet.add(handler, transport=transport,
                        transport_kwargs=transport_kwargs)
        if not creating:
            # join under live traffic: epoch bump → one transparent re-key
            self._rekey_service(name)
        return rid

    def fleet(self, name: str) -> "ServiceFleet":
        with self._glock:
            return self._fleets[name]

    def drain_replica(self, name: str, rid: int,
                      timeout: Optional[float] = 30.0) -> bool:
        """Drain one replica under live traffic: the router stops picking
        it immediately, admitted in-flight work completes, and its
        session/segment resources are recycled only once quiesced (the
        crash invariant). Blocks up to ``timeout`` for quiescence; → True
        when the replica reached QUIESCED (its resources are then released
        and the service epoch is bumped so the fleet membership change is
        a re-key event), False when it is still DRAINING (nothing is
        recycled; call again to keep waiting)."""
        fleet = self.fleet(name)
        if fleet.drain(rid, timeout=timeout):
            self._rekey_service(name)
            return True
        return False

    def fleet_stats(self) -> Dict[str, List[Dict[str, object]]]:
        """Per-service replica snapshots (for supervisors/monitoring and
        :func:`repro.runtime.elastic.plan_fleet_scaling`)."""
        with self._glock:
            fleets = dict(self._fleets)
        return {name: f.snapshot() for name, f in fleets.items()}

    def health(self) -> Dict[str, Dict[str, object]]:
        """Per-service health snapshot (for supervisors/monitoring)."""
        with self._glock:
            services = list(self._services.values())
        return {s.name: s.health.snapshot() for s in services}

    def start(self) -> "ServiceGateway":
        self.transport.start()
        return self

    def enable_coalescing(self, *, max_batch: int = 64,
                          max_wait_us: float = 300.0,
                          name: str = "gw:coalescer") -> "CallCoalescer":
        """Turn on the transparent auto-batching mux: concurrent inline
        ``GatewayClient.call()``s arriving within an adaptive window are
        folded into ONE scatter envelope / ONE transport round trip (see
        :class:`CallCoalescer` and docs/protocol.md §5.4). Register every
        service BEFORE calling this if services use allow-lists — the mux
        carrier identity (``name``) must be allowed, else those services'
        calls silently keep the direct path. Returns the mux (also wired
        into every client's ``call()``)."""
        if self._mux is not None:
            raise RuntimeError("coalescing already enabled on this gateway")
        self._mux = CallCoalescer(self, max_batch=max_batch,
                                  max_wait_us=max_wait_us, name=name)
        return self._mux

    def enable_brownout(self, service: str, *, high_water: int = 64,
                        low_water: Optional[int] = None,
                        high_water_ms: Optional[float] = None) -> _Brownout:
        """Install the hysteretic overload controller on ``service``
        (docs/protocol.md §9): admissions past ``high_water`` concurrent
        requests (or past ``high_water_ms`` EWMA service time, when given)
        are shed with a typed :class:`Overloaded` carrying a
        ``retry_after`` hint, instead of queueing into timeout collapse;
        sheds continue until the backlog drains to ``low_water`` (default
        ``high_water // 2`` — the hysteresis band). Returns the
        controller (``snapshot()`` for observability)."""
        with self._glock:
            svc = self._services[service]
            if svc.brownout is not None:
                raise RuntimeError(
                    f"brownout already enabled for service {service!r}")
            bo = _Brownout(high_water=high_water, low_water=low_water,
                           high_water_ms=high_water_ms)
            svc.brownout = bo
            return bo

    # -- multi-tenant QoS (docs/protocol.md §10) -----------------------------
    def set_rate_limit(self, identity: str, *, rate: float,
                       burst: Optional[float] = None) -> TokenBucket:
        """Install (or replace) the per-identity token bucket: ``identity``
        (the CA name) may sustain ``rate`` requests/second with bursts up
        to ``burst`` (default ``rate``). Envelopes past the bucket shed
        with typed :class:`RateLimited` carrying the refill ``retry_after``
        — BEFORE the breaker, brownout or any queue is charged, so a
        rate-limited tenant consumes nothing but the admission check."""
        bucket = TokenBucket(rate, burst if burst is not None else rate)
        with self._glock:
            self._tenant_buckets[identity] = bucket
        return bucket

    def set_tenant_weight(self, identity: str, weight: float) -> None:
        """Set ``identity``'s WFQ weight (default 1.0) — its long-run share
        of shard execution and fleet in-flight slots relative to other
        backlogged tenants (docs/protocol.md §10)."""
        if weight <= 0:
            raise ValueError("tenant weight must be > 0")
        with self._glock:
            self._tenant_weights[identity] = float(weight)

    def _tenant_weight(self, key) -> float:
        return self._tenant_weights.get(key, 1.0)

    def _admit_identity_name(self, name: Optional[str], n: int = 1) -> None:
        """Token-bucket admission for ``n`` request units under CA identity
        ``name``. Raises :class:`RateLimited` (with ``retry_after``) on
        shed; identities with no configured bucket always admit."""
        if name is None:
            return
        bucket = self._tenant_buckets.get(name)
        if bucket is None:
            return
        wait = bucket.try_take(n)
        if wait > 0.0:
            self._bump_n("rate_limited", n)
            raise RateLimited(
                f"identity {name!r} rate limited "
                f"({bucket.rate:g}/s, burst {bucket.burst:g})",
                retry_after=wait)

    def _admit_identity(self, cid: int, n: int = 1) -> None:
        """Envelope-side admission: resolve the client id to its CA
        identity and charge its bucket (see :meth:`_admit_identity_name`)."""
        self._admit_identity_name(self._cid_names.get(cid), n)

    def qos_stats(self) -> Dict[str, Dict[str, float]]:
        """Per-tenant bucket observability: admitted/shed counts and the
        current token level."""
        with self._glock:
            buckets = dict(self._tenant_buckets)
        return {name: {"rate": b.rate, "burst": b.burst,
                       "tokens": b.tokens(), "admitted": b.admitted,
                       "shed": b.shed}
                for name, b in buckets.items()}

    def close(self):
        if self._mux is not None:
            self._mux.close()
            self._mux = None
        self.transport.close()
        for sh in self._shards:
            sh.close()
        with self._glock:
            fleets = list(self._fleets.values())
        for f in fleets:
            f.close()

    def shard_stats(self) -> List[Dict[str, int]]:
        """Executor observability: per-shard executed/queued counts."""
        return [{"shard": sh.idx, "executed": sh.executed,
                 "queued": sh.queued()} for sh in self._shards]

    # -- client lifecycle ---------------------------------------------------
    def connect(self, client_name: str, *, retries: int = 0,
                backoff: float = 0.005,
                retry_budget: Optional["RetryBudget"] = None
                ) -> "GatewayClient":
        return GatewayClient(self, client_name, retries=retries,
                             backoff=backoff, retry_budget=retry_budget)

    def _open_channel(self, client: "GatewayClient", service: str) -> Channel:
        """Control plane: CA-checked issue of a client key on the service's
        domain + derivation of the per-(client, service) MAC seed."""
        svc = self._services.get(service)
        if svc is None:
            raise AccessViolation(f"unknown service {service!r}")
        if svc.allow is not None and client.name not in svc.allow:
            raise AccessViolation(
                f"client {client.name!r} not authorized for service {service!r}")
        rec = self.ca._services.get(client.name)
        if rec is None or not rec.verified or not self.ca.verify_cert(rec):
            raise AccessViolation(
                f"client {client.name!r} failed certificate check")
        key = self.registry.issue_key(svc.domain, RW)
        seed = mac_seed(svc.domain, self.registry.epoch(svc.domain)) \
            ^ self.ca.session_seed(client._kp.private, service)
        chan = Channel(client.cid, svc.sid, service, seed, key)
        with self._glock:
            old = self._channels.get((client.cid, svc.sid))
            self._channels[(client.cid, svc.sid)] = chan
            # cid → CA identity, the tenant key for QoS admission/WFQ
            self._cid_names[client.cid] = client.name
        if old is not None:             # re-key: retire the replaced grant
            self.registry.retire(old.client_key)
        return chan

    def revoke(self, client: "GatewayClient", service: Optional[str] = None):
        """Revoke a client's channel key(s). Bumps the service-domain epoch,
        so every stale key/frame on that domain fails the guard afterwards
        (other clients must re-open — the PKRU-flush analogue)."""
        with self._glock:
            doomed = [(k, ch) for k, ch in self._channels.items()
                      if k[0] == client.cid
                      and (service is None or ch.service == service)]
        for k, ch in doomed:
            self.registry.revoke(ch.client_key)
            with self._glock:
                self._channels.pop(k, None)
            client._channels.pop(ch.service, None)
            # the epoch bump stales every key on the domain, including the
            # service's own — the co-located service re-syncs immediately
            # (clients must re-open through the CA; GatewayClient.call does
            # this transparently for still-certified clients)
            svc = self._by_sid[ch.sid]
            svc.server_key = self.registry.issue_key(svc.domain, RW)

    def _release_client(self, client: "GatewayClient"):
        """Graceful disconnect: retire the client's keys (no epoch bump —
        closing is not a security event) and drop its routing entries, so a
        closed client's cid can never dispatch again."""
        with self._glock:
            doomed = [(k, ch) for k, ch in self._channels.items()
                      if k[0] == client.cid]
            for k, ch in doomed:
                self._channels.pop(k, None)
            self._cid_names.pop(client.cid, None)
        for _, ch in doomed:
            self.registry.retire(ch.client_key)

    # -- data plane (runs on the transport's per-session service threads) ----
    def _bump(self, *stats: str):
        with self._glock:
            for s in stats:
                self.stats[s] += 1

    def _bump_n(self, stat: str, n: int):
        with self._glock:
            self.stats[stat] += n

    def _service_failure(self, svc: _Service, crashed: bool = False):
        """Record a handler failure; when the breaker trips, self-heal by
        restarting (factory available) or open the circuit and shed."""
        if crashed:
            self._bump("crashes")
        if svc.health.failure(crashed=crashed):
            if svc.factory is not None:
                self.restart_service(svc.name)
            else:
                svc.health.trip()

    def note_wire_crash(self, sid: int):
        """A transport-level crash was observed for a request routed to
        ``sid`` before it reached dispatch (fault fabrics call this so the
        gateway's health view includes wire-level kills)."""
        svc = self._by_sid.get(sid)
        if svc is not None:
            self._service_failure(svc, crashed=True)

    def _dedup_get(self, svc: _Service, cid: int, token: int):
        if not token:
            return None
        with svc.done_lock:
            bucket = svc.done.get(cid)
            return bucket.get(token) if bucket is not None else None

    def _dedup_put(self, svc: _Service, cid: int, token: int,
                   resp: np.ndarray):
        if not token:
            return
        if resp.base is not None or not resp.flags.owndata:
            # the window may outlive the transport region / arena slot the
            # response views — snapshot it so a recycled slot can never
            # mutate a cached answer
            resp = resp.copy()
        with svc.done_lock:
            bucket = svc.done.setdefault(cid, OrderedDict())
            bucket[token] = resp
            while len(bucket) > _DONE_TOKENS:
                bucket.popitem(last=False)
            svc.done.move_to_end(cid)
            while len(svc.done) > _DONE_CLIENTS:
                svc.done.popitem(last=False)

    def _run_guarded(self, svc: _Service, payload: np.ndarray,
                     deadline: Optional[float] = None,
                     identity: Optional[str] = None,
                     priority: int = framing.PRIO_NORMAL) -> np.ndarray:
        """Run the handler behind the circuit breaker with failure
        accounting — the one execution core shared by the single, batch
        and scatter paths, so breaker semantics cannot diverge.

        Deadline shed comes FIRST and outside the try block: expired work
        is dropped before execution (docs/protocol.md §9) and a shed is
        neither a handler failure (no breaker charge) nor a brownout
        admission. Rate-limit sheds (docs/protocol.md §10) happen in the
        dispatch layer BEFORE this core is reached, so a ``RateLimited``
        tenant never charges the breaker or brownout either. While the
        handler runs, the propagated deadline and the caller's QoS context
        (CA identity + frame priority class) are published thread-locally
        (``current_deadline`` / ``current_identity`` / ``current_priority``)
        so downstream hops (fleet dispatch, EngineService admission)
        compute against them."""
        if deadline is not None and time.monotonic() >= deadline:
            self._bump("expired")
            raise DeadlineExpired(
                f"service {svc.name!r}: propagated deadline expired "
                "before execution")
        svc.health.admit(svc.name)      # circuit breaker: shed, don't hang
        bo = svc.brownout
        if bo is not None:
            try:
                bo.admit(svc.name)      # raises typed Overloaded when shed
            except Overloaded:
                self._bump("overloaded")
                raise
        prev = _push_deadline(deadline)
        qprev = _push_qos(identity, priority)
        t0 = time.perf_counter()
        ok = False
        try:
            resp = _as_frameable(np.asarray(svc.handler(payload)))
            ok = True
        except HandlerCrash:
            # kills the transport service thread (by design) — record it,
            # then let it propagate past the per-request except nets
            self._service_failure(svc, crashed=True)
            raise
        except Exception:
            self._service_failure(svc)
            raise
        finally:
            _pop_qos(qprev)
            _pop_deadline(prev)
            if bo is not None:
                bo.done(1, (time.perf_counter() - t0) * 1e3, ok=ok)
        svc.health.success()
        return resp

    def _invoke(self, svc: _Service, chan: Channel, cid: int, token: int,
                fseq: int, payload: np.ndarray,
                deadline: Optional[float] = None,
                priority: int = framing.PRIO_NORMAL) -> np.ndarray:
        """Run the service handler behind the circuit breaker + dedup cache.
        Returns the response payload; updates ``chan.server_seq``."""
        cached = self._dedup_get(svc, cid, token)
        if cached is not None:
            # the original executed but its response was lost in flight:
            # answer from the dedup window, never re-execute. The window
            # only ever moves FORWARD — a replayed old envelope gets its
            # (already-delivered) answer but cannot rewind the channel
            # and desync legitimate in-order traffic
            self._bump("deduped")
            chan.server_seq = max(chan.server_seq,
                                  (fseq + 1) & 0xFFFFFFFF)
            return cached
        if fseq != chan.server_seq:
            raise framing.FrameError(
                f"sequence mismatch (got {fseq}, want {chan.server_seq})")
        resp = self._run_guarded(svc, payload, deadline,
                                 identity=self._cid_names.get(cid),
                                 priority=priority)
        self._dedup_put(svc, cid, token, resp)
        chan.server_seq = (fseq + 1) & 0xFFFFFFFF
        return resp

    def _invoke_batch(self, svc: _Service, chan: Channel, parsed,
                      deadlines=None, priorities=None,
                      identity: Optional[str] = None) -> list:
        """Execute a verified batch. ``parsed`` holds payload arrays with
        FrameError objects in failed positions (verify_batch strict=False);
        those pass through untouched. Every consumed item advances
        ``chan.server_seq`` positionally — success or failure — matching
        the client's batch-wide sequence advance (unlike the single path,
        where a failed exchange advances neither side). Health/circuit
        accounting: per item on the loop path, once per batch on the
        native ``batch_handler`` path. ``deadlines`` (absolute monotonic,
        positional, ``None`` = unbounded) shed expired items pre-execution
        with a per-slot ``DeadlineExpired``; the batch handler runs under
        the cohort's TIGHTEST live deadline (thread-local), matching the
        coalescer's budget model. ``priorities`` (positional lane-12
        classes) publish the cohort's MOST URGENT live class thread-locally
        on the native path — same "tightest wins" rule as the deadline."""
        if deadlines is None:
            deadlines = [None] * len(parsed)
        if priorities is None:
            priorities = [framing.PRIO_NORMAL] * len(parsed)
        results = list(parsed)
        now = time.monotonic()
        good = []
        for i, p in enumerate(parsed):
            if isinstance(p, framing.FrameError):
                continue
            if deadlines[i] is not None and now >= deadlines[i]:
                self._bump("expired")
                results[i] = DeadlineExpired(
                    f"service {svc.name!r}: propagated deadline expired "
                    "before execution")
                continue
            good.append((i, p))
        if svc.batch_handler is not None and good:
            bo = svc.brownout
            live = [d for i, _ in good
                    if (d := deadlines[i]) is not None]
            prev = _push_deadline(min(live) if live else None)
            qprev = _push_qos(identity,
                              min((priorities[i] for i, _ in good),
                                  key=priority_rank))
            t0 = time.perf_counter()
            bok = False
            admitted = False
            try:
                svc.health.admit(svc.name)
                if bo is not None:
                    try:
                        bo.admit(svc.name, weight=len(good))
                    except Overloaded:
                        self._bump("overloaded")
                        raise
                    admitted = True
                outs = svc.batch_handler([p for _, p in good])
                if len(outs) != len(good):
                    raise TransportError(
                        f"batch handler returned {len(outs)} responses "
                        f"for {len(good)} requests")
                svc.health.success()
                bok = True
                # a batch handler may return a typed exception INSTANCE in
                # an item's slot (a fleet replica's per-item remote error)
                # — it becomes that item's typed error, like the loop path
                for (i, _), o in zip(good, outs):
                    results[i] = o if isinstance(o, BaseException) \
                        else _as_frameable(np.asarray(o))
            except HandlerCrash:
                self._service_failure(svc, crashed=True)
                raise
            except ServiceUnavailable as e:     # circuit shed, not a
                self._bump("sheds")             # handler failure
                for i, _ in good:
                    results[i] = e
            except Exception as e:
                self._service_failure(svc)
                for i, _ in good:
                    results[i] = e
            finally:
                _pop_qos(qprev)
                _pop_deadline(prev)
                if bo is not None and admitted:
                    bo.done(len(good), (time.perf_counter() - t0) * 1e3,
                            ok=bok)
        else:
            for i, p in good:
                try:
                    results[i] = self._run_guarded(svc, p, deadlines[i],
                                                   identity=identity,
                                                   priority=priorities[i])
                except ServiceUnavailable as e:
                    self._bump("sheds")
                    results[i] = e
                except Exception as e:      # failure already recorded
                    results[i] = e
        chan.server_seq = (chan.server_seq + len(parsed)) & 0xFFFFFFFF
        return results

    def _dispatch_batch(self, raw: np.ndarray) -> np.ndarray:
        """Serve one batch envelope: route/capability checks once, frame
        walk (split_frames), ONE vectorized MAC verify, per-item execution,
        ONE vectorized response seal. Per-item failures come back as typed
        error blobs in that item's slot; whole-batch failures use the
        single-message error envelope."""
        sid = 0
        try:
            route = raw[:_ROUTE_BYTES].view("<u4")
            sid, cid, n_items = int(route[1]), int(route[2]), int(route[3])
            svc = self._by_sid.get(sid)
            if svc is None:
                raise AccessViolation(f"unknown service id {sid}")
            chan = self._channels.get((cid, sid))
            if chan is None:
                raise AccessViolation(
                    f"client {cid} holds no key for service {svc.name!r}")
            # token-bucket admission: one unit per item, BEFORE the channel
            # lock or any sequence slot is consumed — a rate-limited batch
            # sheds whole with typed RateLimited and leaves nothing charged
            self._admit_identity(cid, n_items)
            with chan.slock:
                self.registry.check(chan.client_key, WRITE)
                self.registry.check(svc.server_key, READ)
                body = raw[_ROUTE_BYTES:]
                if body.nbytes == 0 or body.nbytes % (framing.LANES * 4):
                    raise framing.FrameError(
                        "malformed batch — truncated or not lane-aligned")
                frames = framing.split_frames(
                    body.view("<u4").reshape(-1, framing.LANES))
                if len(frames) != n_items:
                    raise framing.FrameError(
                        f"batch declares {n_items} frames, found {len(frames)}")
                start = chan.server_seq
                seqs = [(start + i) & 0xFFFFFFFF for i in range(len(frames))]
                parsed = framing.verify_batch(frames, seed=chan.seed,
                                              seqs=seqs, strict=False,
                                              mac_impl=self._batch_mac)
                n_ok = sum(1 for p in parsed
                           if not isinstance(p, framing.FrameError))
                self._bump_n("requests", len(frames))
                self._bump_n("macs_verified", n_ok)
                self._bump_n("rejected", len(frames) - n_ok)
                # deadline words are MAC-covered: only trust them on
                # frames that verified (FrameError slots get None)
                deadlines = [None if isinstance(p, framing.FrameError)
                             else _frame_deadline(f)
                             for f, p in zip(frames, parsed)]
                priorities = [framing.PRIO_NORMAL
                              if isinstance(p, framing.FrameError)
                              else _frame_priority(f)
                              for f, p in zip(frames, parsed)]
                results = self._invoke_batch(svc, chan, parsed, deadlines,
                                             priorities,
                                             self._cid_names.get(cid))
                try:
                    self.registry.check(svc.server_key, WRITE)
                    self.registry.check(chan.client_key, READ)
                except AccessViolation as e:
                    # the epoch moved UNDER this batch (e.g. its own
                    # failures tripped a self-healing restart). Handlers
                    # already ran, so the client must NOT transparently
                    # re-key and resend — tag the rejection so call_batch's
                    # stale-epoch retry stands down (batches carry no
                    # idempotency token; a resend would double-execute)
                    raise AccessViolation(f"post-execution: {e}") from None
                ok_idx = [i for i, r in enumerate(results)
                          if not isinstance(r, BaseException)]
                rframes = framing.seal_batch(
                    [results[i] for i in ok_idx], seed=chan.seed,
                    seqs=[seqs[i] for i in ok_idx],
                    mac_impl=self._batch_mac) if ok_idx else []
            parts = [_route(_BOK, sid, len(results))]
            rit = iter(rframes)
            for r in results:
                if isinstance(r, BaseException):
                    blob = _pack_error(r)
                    pad = (-len(blob)) % 4
                    parts.append(_route(_ERR, len(blob), 0))
                    parts.append(np.frombuffer(blob + b"\0" * pad, np.uint8))
                else:
                    rf = next(rit).reshape(-1).view(np.uint8)
                    parts.append(_route(_OK, rf.nbytes, 0))
                    parts.append(rf)
            self._bump_n("responses", len(ok_idx))
            self._bump_n("rejected",
                         len(results) - len(ok_idx)
                         - sum(1 for p in parsed
                               if isinstance(p, framing.FrameError)))
            return np.concatenate(parts)
        except Exception as e:
            self._bump(*(("rejected", "sheds")
                         if isinstance(e, ServiceUnavailable)
                         else ("rejected",)))
            blob = _pack_error(e)
            return np.concatenate(
                [_route(_ERR, sid, len(blob)), np.frombuffer(blob, np.uint8)])

    def _scatter_group(self, cid: int, sid: int, members) -> list:
        """Execute one channel's scatter items — the single-call pipeline
        (capability checks, MAC verify, dedup window, breaker) — with the
        batch envelope's positional sequence discipline: every consumed
        item advances the channel, success or failure, so one bad item
        cannot desync its neighbours. ``members`` is [(item_index, token,
        frame), ...] in envelope order; returns [(item_index,
        response_frame | exception), ...]. Runs on the service's shard
        (concurrently with other services' groups) or inline when
        workers=0 — same semantics either way.

        Cohort admission: when the service registered a ``batch_handler``,
        the group's runnable items (verified, fresh, not dedup-answered)
        execute as ONE native batch call behind ONE breaker admission —
        exactly the batch envelope's execution model, which is how an
        auto-coalesced cohort of inline inference calls joins
        EngineService's continuous-batching decode grid as one unit.
        Per-item typed errors are unchanged either way."""
        svc = self._by_sid.get(sid)
        if svc is None:
            e = AccessViolation(f"unknown service id {sid}")
            return [(idx, e) for idx, _, _ in members]
        chan = self._channels.get((cid, sid))
        if chan is None:
            e = AccessViolation(
                f"client {cid} holds no key for service {svc.name!r}")
            return [(idx, e) for idx, _, _ in members]
        out = []
        ok: list = []                   # (idx, seq, response payload)
        identity = self._cid_names.get(cid)
        with chan.slock:
            base = chan.server_seq
            saw_fresh = False
            parseable = 0
            runnable: list = []         # (idx, token, fseq, payload, dl, pr)
            try:
                for k, (idx, token, frame) in enumerate(members):
                    try:
                        self.registry.check(chan.client_key, WRITE)
                        self.registry.check(svc.server_key, READ)
                        # MAC first, sequence word read afterwards: like
                        # the single path, the dedup window is consulted
                        # BEFORE the sequence check, so a replayed
                        # envelope (lost response + same-token retry) is
                        # answered from the window instead of tripping a
                        # mismatch
                        payload = framing.parse_frame(
                            frame, seed=chan.seed, expect_seq=None,
                            mac_impl=self._mac)
                        fseq = int(frame[0][2])
                        parseable += 1
                        if fseq == (base + k) & 0xFFFFFFFF:
                            saw_fresh = True    # at-position item: this is
                        self._bump("macs_verified")     # a FRESH envelope
                        cached = self._dedup_get(svc, cid, token)
                        if cached is not None:
                            self._bump("deduped")
                            ok.append((idx, fseq, cached))
                            continue
                        if fseq != (base + k) & 0xFFFFFFFF:
                            raise framing.FrameError(
                                f"sequence mismatch (got {fseq}, want "
                                f"{(base + k) & 0xFFFFFFFF})")
                        runnable.append((idx, token, fseq, payload,
                                         _frame_deadline(frame),
                                         _frame_priority(frame)))
                    except ServiceUnavailable as e:
                        self._bump("sheds")
                        out.append((idx, e))
                    except Exception as e:
                        out.append((idx, e))
                if svc.batch_handler is not None and runnable:
                    # shed expired items BEFORE the cohort admission, so
                    # one stale straggler cannot ride the native batch
                    now = time.monotonic()
                    live = []
                    for item in runnable:
                        if item[4] is not None and now >= item[4]:
                            self._bump("expired")
                            out.append((item[0], DeadlineExpired(
                                f"service {svc.name!r}: propagated "
                                "deadline expired before execution")))
                        else:
                            live.append(item)
                    if live:
                        self._scatter_run_batch(svc, chan, cid, live,
                                                ok, out, identity)
                else:
                    for idx, token, fseq, payload, dl, pr in runnable:
                        try:
                            # re-consult the window: an EARLIER item of this
                            # very envelope may have executed this token
                            # (duplicate tokens in one envelope must not
                            # double-execute, same as sequential items)
                            resp = self._dedup_get(svc, cid, token)
                            if resp is not None:
                                self._bump("deduped")
                            else:
                                resp = self._run_guarded(svc, payload, dl,
                                                         identity=identity,
                                                         priority=pr)
                                self._dedup_put(svc, cid, token, resp)
                            self.registry.check(svc.server_key, WRITE)
                            self.registry.check(chan.client_key, READ)
                            ok.append((idx, fseq, resp))
                        except ServiceUnavailable as e:
                            self._bump("sheds")
                            out.append((idx, e))
                        except Exception as e:
                            out.append((idx, e))
            finally:
                # positional discipline, decided per ENVELOPE: any item
                # sitting at its expected position marks the envelope
                # fresh, and a fresh envelope consumes len(members) slots
                # unconditionally — success, handler failure, or a corrupt
                # item ANYWHERE (the client advances for every item, so a
                # failing tail must not leave the server behind). A pure
                # replay (every parseable item stale) moves nothing:
                # forward-only, a resend can never rewind or further
                # desync the channel. Also runs on a crash unwinding,
                # where the session dies and the client re-keys via heal()
                if saw_fresh or parseable == 0:
                    chan.server_seq = (base + len(members)) & 0xFFFFFFFF
            if ok:                      # ONE fused seal pass per group
                rframes = framing.seal_batch(
                    [r for _, _, r in ok], seed=chan.seed,
                    seqs=[q for _, q, _ in ok], mac_impl=self._batch_mac)
                out.extend((idx, rf) for (idx, _, _), rf in zip(ok, rframes))
        return out

    def _scatter_run_batch(self, svc: _Service, chan: Channel, cid: int,
                           runnable: list, ok: list, out: list,
                           identity: Optional[str] = None) -> None:
        """Execute a scatter channel-group's runnable items as ONE native
        ``batch_handler`` call (the batch envelope's execution model):
        one breaker admission, one cohort submission — per-item dedup
        recording and post-execution capability checks preserved. The
        cohort's tightest deadline AND most-urgent priority class publish
        thread-locally for the handler's downstream hops. Called under
        ``chan.slock``."""
        # duplicate tokens inside one envelope execute ONCE (the sequential
        # semantics): only each token's first occurrence enters the native
        # batch; later duplicates are answered from its response below
        first_of: Dict[int, int] = {}       # token → index into `unique`
        unique: list = []
        slot_of: list = []                  # runnable position → unique pos
        for item in runnable:
            token = item[1]
            if token and token in first_of:
                slot_of.append(first_of[token])
                continue
            if token:
                first_of[token] = len(unique)
            slot_of.append(len(unique))
            unique.append(item)
        outs = None
        bo = svc.brownout
        live = [d for item in unique if (d := item[4]) is not None]
        prev = _push_deadline(min(live) if live else None)
        qprev = _push_qos(identity,
                          min((item[5] for item in unique),
                              key=priority_rank))
        t0 = time.perf_counter()
        bok = False
        admitted = False
        try:
            svc.health.admit(svc.name)
            if bo is not None:
                try:
                    bo.admit(svc.name, weight=len(unique))
                except Overloaded:
                    self._bump("overloaded")
                    raise
                admitted = True
            outs = svc.batch_handler([p for _, _, _, p, _, _ in unique])
            if len(outs) != len(unique):
                raise TransportError(
                    f"batch handler returned {len(outs)} responses "
                    f"for {len(unique)} requests")
            svc.health.success()
            bok = True
        except HandlerCrash:
            self._service_failure(svc, crashed=True)
            raise
        except ServiceUnavailable as e:     # circuit shed, not a failure
            self._bump("sheds")
            out.extend((idx, e) for idx, *_ in runnable)
            return
        except Exception as e:
            self._service_failure(svc)
            out.extend((idx, e) for idx, *_ in runnable)
            return
        finally:
            _pop_qos(qprev)
            _pop_deadline(prev)
            if bo is not None and admitted:
                bo.done(len(unique), (time.perf_counter() - t0) * 1e3,
                        ok=bok)
        for (idx, token, fseq, _, _, _), k in zip(runnable, slot_of):
            if isinstance(outs[k], BaseException):
                # per-item typed error from the batch handler (a fleet
                # replica's remote failure): this item's fate, not dedup'd
                out.append((idx, outs[k]))
                continue
            try:
                resp = _as_frameable(np.asarray(outs[k]))
                self._dedup_put(svc, cid, token, resp)
                self.registry.check(svc.server_key, WRITE)
                self.registry.check(chan.client_key, READ)
                ok.append((idx, fseq, resp))
            except Exception as e:          # noqa: PERF203 — per-item fate
                out.append((idx, e))

    def _dispatch_scatter(self, raw: np.ndarray) -> np.ndarray:
        """Serve one scatter envelope: carve the per-item (route + frame)
        walk, group items by (client, service) channel preserving envelope
        order, execute every group on its service's shard — concurrently
        across shards, inline when workers=0 — and assemble per-item
        responses in the batch envelope's item layout. Whole-envelope
        failures (desynced walk, bad counts) use the single error
        envelope and consume no sequence numbers."""
        cid = 0
        try:
            u = raw.view("<u4")
            cid, n_items = int(u[1]), int(u[2])
            if n_items <= 0 or n_items > _MAX_SCATTER:
                raise framing.FrameError(
                    f"scatter envelope declares {n_items} items")
            items = []
            ofs = 4
            for _ in range(n_items):
                if ofs + 4 + framing.LANES > u.size:
                    raise framing.FrameError("truncated scatter envelope")
                if int(u[ofs]) != GW_MAGIC:
                    raise framing.FrameError(
                        f"scatter item walk desynced at word {ofs}")
                sid, token = int(u[ofs + 1]), int(u[ofs + 2])
                hdr = ofs + 4
                if int(u[hdr]) != framing.MAGIC:
                    raise framing.FrameError(
                        "scatter item is not an MPKLink frame")
                rows = framing.frame_rows(int(u[hdr + 3]))
                end = hdr + rows * framing.LANES
                if end > u.size:
                    raise framing.FrameError(
                        f"scatter item declares {rows} rows past envelope end")
                items.append((sid, token,
                              u[hdr:end].reshape(rows, framing.LANES)))
                ofs = end
            if ofs != u.size:
                raise framing.FrameError("trailing bytes after scatter items")
            # token-bucket admission, one unit per item: the whole envelope
            # sheds typed BEFORE any group runs or any channel's sequence
            # slots are consumed (a RateLimited scatter is fully replayable)
            self._admit_identity(cid, n_items)
            self._bump("scatter_envelopes")
            self._bump_n("requests", n_items)
            groups: "OrderedDict[int, list]" = OrderedDict()
            for idx, (sid, token, frame) in enumerate(items):
                groups.setdefault(sid, []).append((idx, token, frame))
            results: list = [None] * n_items
            pending = []
            tenant = self._cid_names.get(cid)
            for sid, members in groups.items():
                fn = (lambda s=sid, m=members: self._scatter_group(cid, s, m))
                if self._shards:
                    # WFQ flow = the submitting tenant, cost = group size:
                    # one tenant's cohort backlog interleaves fairly with
                    # other tenants' work on the shard (protocol.md §10)
                    pending.append(
                        self._shards[sid % len(self._shards)]
                        .submit(fn, key=tenant, cost=len(members)))
                else:
                    pending.append(([(True, fn())], None))
            for box, done in pending:
                if done is not None:
                    done.wait()
                ok, val = box[0]
                if not ok:
                    raise val       # HandlerCrash / DropResponse relayed
                for idx, r in val:
                    results[idx] = r
            parts = [np.array([GW_MAGIC, _SOK, cid, n_items], "<u4")
                     .view(np.uint8)]
            n_ok = 0
            for r in results:
                if isinstance(r, BaseException):
                    blob = _pack_error(r)
                    pad = (-len(blob)) % 4
                    parts.append(_route(_ERR, len(blob), 0))
                    parts.append(np.frombuffer(blob + b"\0" * pad, np.uint8))
                else:
                    rf = r.reshape(-1).view(np.uint8)
                    parts.append(_route(_OK, rf.nbytes, 0))
                    parts.append(rf)
                    n_ok += 1
            self._bump_n("responses", n_ok)
            self._bump_n("rejected", n_items - n_ok)
            return np.concatenate(parts)
        except Exception as e:
            self._bump(*(("rejected", "sheds")
                         if isinstance(e, ServiceUnavailable)
                         else ("rejected",)))
            blob = _pack_error(e)
            return np.concatenate(
                [_route(_ERR, cid, len(blob)), np.frombuffer(blob, np.uint8)])

    def _dispatch(self, req: np.ndarray) -> np.ndarray:
        sid = 0
        try:
            raw = np.ascontiguousarray(np.asarray(req)) \
                .view(np.uint8).reshape(-1)
            if raw.nbytes < _ROUTE_BYTES:
                raise framing.FrameError("short gateway envelope")
            route = raw[:_ROUTE_BYTES].view("<u4")
            if int(route[0]) == GW_BATCH_MAGIC:
                return self._dispatch_batch(raw)
            if int(route[0]) == GW_SCAT_MAGIC:
                return self._dispatch_scatter(raw)
            if int(route[0]) != GW_MAGIC:
                raise framing.FrameError("not a gateway envelope (bad magic)")
            sid, cid, token = int(route[1]), int(route[2]), int(route[3])
            svc = self._by_sid.get(sid)
            if svc is None:
                raise AccessViolation(f"unknown service id {sid}")
            chan = self._channels.get((cid, sid))
            if chan is None:
                raise AccessViolation(
                    f"client {cid} holds no key for service {svc.name!r}")
            # per-identity token bucket (docs/protocol.md §10): shed typed
            # BEFORE the channel lock / sequence slot — a rate-limited call
            # charges nothing downstream (no breaker, brownout or dedup)
            self._admit_identity(cid)
            with chan.slock:
                # PKRU staging checks: the client may write the request
                # region, the service may read it (revocation/epoch enforced)
                self.registry.check(chan.client_key, WRITE)
                self.registry.check(svc.server_key, READ)
                body = raw[_ROUTE_BYTES:]
                if body.nbytes == 0 or body.nbytes % (framing.LANES * 4):
                    raise framing.FrameError(
                        "malformed frame — truncated or not lane-aligned")
                frame = body.view("<u4").reshape(-1, framing.LANES)
                # MAC/seed/header verification first (expect_seq=None: the
                # sequence check is downstream so an idempotent retry of an
                # already-executed request can be answered from the dedup
                # window); the unverified sequence word is read afterwards
                payload = framing.parse_frame(
                    frame, seed=chan.seed, expect_seq=None,
                    mac_impl=self._mac)
                fseq = int(frame[0][2])
                self._bump("requests", "macs_verified")
                resp = self._invoke(svc, chan, cid, token, fseq, payload,
                                    _frame_deadline(frame),
                                    _frame_priority(frame))
                self.registry.check(svc.server_key, WRITE)
                self.registry.check(chan.client_key, READ)
                # response frame sealed in place behind the route words —
                # ONE buffer, no build/concat chain
                env = _seal_envelope([GW_MAGIC, _OK, sid, 0], resp,
                                     seed=chan.seed, seq=fseq,
                                     mac_impl=self._mac)
            self._bump("responses")
            return env
        except Exception as e:
            self._bump(*(("rejected", "sheds")
                         if isinstance(e, ServiceUnavailable)
                         else ("rejected",)))
            blob = _pack_error(e)
            return np.concatenate(
                [_route(_ERR, sid, len(blob)), np.frombuffer(blob, np.uint8)])


class GatewayClient:
    """One CA-enrolled client: its own transport session plus per-service
    channels. ``call()`` is thread-safe but serial per client — open one
    client per concurrent caller (that's the session model).

    Resilience: every call carries an idempotency token; with ``retries``
    > 0 a call that fails with a *liveness* error (session crash/response
    timeout — never a security rejection) heals the transport session,
    re-keys the channel and resends the SAME token, so a retried request
    whose original did execute is answered from the gateway's dedup window
    instead of running twice."""

    def __init__(self, gw: ServiceGateway, name: str, *, retries: int = 0,
                 backoff: float = 0.005,
                 retry_budget: Optional["RetryBudget"] = None):
        self.gw = gw
        self.name = name
        self.retries = retries
        self.backoff = backoff
        # optional token bucket capping TOTAL extra attempts (liveness
        # retries here + fleet hedges downstream); share ONE instance
        # across clients to bound a whole tenant (docs/protocol.md §9)
        self.retry_budget = retry_budget
        self._kp, _ = enroll(gw.ca, name)
        self.cid = next(gw._cid_counter)
        # the transport session is created lazily on first wire use: a
        # client whose calls all ride the coalescing mux never opens its
        # own wire (at 256 fan-in callers that is 256 spared service
        # threads), yet keeps one for direct envelopes on demand
        self._session_obj: Optional[object] = None
        self._direct = False            # True: never route through the mux
        self._channels: Dict[str, Channel] = {}
        self._lock = threading.Lock()
        self._tokens = itertools.count(1)   # 0 = "no token" on the wire
        self.macs_verified = 0          # response MACs this client checked
        self.retried = 0                # liveness retries this client made

    @property
    def _session(self):
        s = self._session_obj
        if s is None:
            s = self._session_obj = self.gw.transport.connect(f"gw:{self.name}")
        return s

    @_session.setter
    def _session(self, s):
        self._session_obj = s

    def open(self, service: str) -> Channel:
        with self._lock:
            chan = self._channels.get(service)
            if chan is None:
                chan = self.gw._open_channel(self, service)
                self._channels[service] = chan
            return chan

    def reopen(self, service: str) -> Channel:
        """Drop the cached channel and open a fresh one (new key at the
        current epoch) — the recovery path after a domain-epoch bump."""
        with self._lock:
            self._channels.pop(service, None)
        return self.open(service)

    def heal(self, service: Optional[str] = None):
        """Recover from a dead/poisoned transport session: reconnect the
        session and (optionally) re-open the service channel so both sides
        restart from a fresh key + sequence 0."""
        s = self._session_obj
        if s is not None and (s._crashed or s._closed or s._poisoned):
            self._reconnect()
        if service is not None:
            self.reopen(service)

    def _reconnect(self):
        s = self._session_obj
        if s is not None:
            try:
                s.close()
            # mpklint: disable=MPK105 reason=best-effort close of a dead session during heal
            except Exception:
                pass
        self._session_obj = self.gw.transport.connect(f"gw:{self.name}")

    def _spend_retry(self) -> bool:
        """Charge the retry budget for one EXTRA attempt (True = granted).
        No budget installed = unlimited (the pre-budget behavior)."""
        return self.retry_budget is None or self.retry_budget.take()

    def _retry_sleep(self, attempts: int,
                     deadline: Optional[float]) -> None:
        delay = self.backoff * attempts
        if deadline is not None:
            delay = min(delay, max(0.0, deadline - time.monotonic()))
        if delay > 0:
            time.sleep(delay)

    def call(self, service: str, payload: np.ndarray, *,
             token: Optional[int] = None,
             timeout: Optional[float] = None,
             priority: int = framing.PRIO_NORMAL) -> np.ndarray:
        """One inline request/response. With coalescing enabled on the
        gateway (:meth:`ServiceGateway.enable_coalescing`), a plain call
        (``retries == 0``, no pinned token) is transparently folded into
        the mux's next cohort envelope — AFTER this client's own CA/ACL
        channel check, so per-client authorization is enforced exactly as
        on the direct path. ``token`` pins the idempotency token (a manual
        replay of an earlier call) and takes the direct path.

        ``timeout`` is the call's TOTAL budget: it spans every retry, is
        sealed into the envelope's MAC-covered deadline word, and rides
        hop-by-hop to the replica (docs/protocol.md §9) — an expired call
        sheds with a typed :class:`DeadlineExpired` wherever it happens to
        be, instead of burning a fixed per-hop transport timeout.

        ``priority`` (``framing.PRIO_HIGH`` / ``PRIO_NORMAL`` /
        ``PRIO_BULK``) is sealed into the frame's MAC-covered lane-12 word
        (docs/protocol.md §10): HIGH bypasses the coalescer wait window,
        BULK donates its latency budget to batch filling."""
        payload = np.asarray(payload)
        deadline = None if timeout is None \
            else time.monotonic() + timeout
        if self.retry_budget is not None:
            self.retry_budget.note_primary()
        mux = self.gw._mux
        if (mux is not None and token is None
                and self.retries == 0
                and not self._direct and mux.accepts(service)):
            self.open(service)          # the CALLER's own CA/ACL gate
            # the cohort rides the CARRIER's cid on the wire, so the
            # tenant bucket must be charged HERE, against the true caller
            # — otherwise the mux would launder rate limits (§10)
            self.gw._admit_identity_name(self.name)
            return mux.call(service, payload, deadline=deadline,
                            priority=priority)
        if token is None:
            token = next(self._tokens) & 0xFFFFFFFF \
                or (next(self._tokens) & 0xFFFFFFFF)
        attempts = 0
        rekeys = 0
        while True:
            if deadline is not None and time.monotonic() >= deadline:
                raise DeadlineExpired(
                    f"call to {service!r}: deadline expired "
                    f"after {attempts} retr{'y' if attempts == 1 else 'ies'}")
            chan = self.open(service)
            try:
                return self._call_once(chan, payload, token,
                                       deadline=deadline, priority=priority)
            except AccessViolation as e:
                # someone's revocation (or a supervisor's release/join)
                # bumped the service-domain epoch; a still-certified
                # client just re-keys through the CA and retries — up to
                # REKEY_LIMIT times, because a supervisor healing
                # repeated kills bumps the epoch once per membership
                # change and a call can race several (a banned client
                # fails the certificate check in reopen()). No budget
                # charge: a re-key is recovery bookkeeping, not an extra
                # execution attempt
                if "stale key epoch" not in str(e) or rekeys >= REKEY_LIMIT:
                    raise
                rekeys += 1
                self.reopen(service)
            except DeadlineExpired:
                raise               # retrying expired work is pointless
            except Overloaded as e:
                attempts += 1
                if attempts > self.retries or not self._spend_retry():
                    raise
                self.retried += 1
                # honor the server's brownout hint, clamped to the budget
                delay = max(self.backoff * attempts, e.retry_after)
                if deadline is not None:
                    delay = min(delay,
                                max(0.0, deadline - time.monotonic()))
                if delay > 0:
                    time.sleep(delay)
            except ServiceUnavailable:
                attempts += 1
                if attempts > self.retries or not self._spend_retry():
                    raise
                self.retried += 1
                self._retry_sleep(attempts, deadline)
            except (ServiceCrashed, ResponseTimeout):
                attempts += 1
                if attempts > self.retries or not self._spend_retry():
                    raise
                self.retried += 1
                rekeyed = False
                self.heal(service)      # fresh session + channel, same token
                self._retry_sleep(attempts, deadline)

    def call_batch(self, service: str, payloads,
                   return_exceptions: bool = False) -> list:
        """Pipelined batch call: N messages in ONE gateway envelope / ONE
        transport round trip, sealed client-side and verified server-side
        in one vectorized MAC pass each. Returns responses in payload
        order; a failed message surfaces as its typed exception (in-place
        with ``return_exceptions``, else the first one is raised after the
        batch has drained). Batch calls carry no idempotency token and are
        not auto-retried — a liveness failure (crash/timeout) poisons the
        session as usual and ``heal()`` recovers; whole-batch security
        rejections advance neither side's sequence. Like ``call()``, a
        stale-key-epoch rejection (revocation / self-healing restart)
        re-keys through the CA transparently and retries once."""
        payloads = [np.asarray(p) for p in payloads]
        if not payloads:
            return []
        rekeyed = False
        while True:
            chan = self.open(service)
            try:
                return self._call_batch_once(chan, payloads,
                                             return_exceptions)
            except AccessViolation as e:
                # transparently re-key ONLY for pre-execution rejections:
                # a "post-execution" tag means the batch already ran under
                # the old epoch — resending it would double-execute
                if "stale key epoch" not in str(e) or rekeyed \
                        or "post-execution" in str(e):
                    raise
                rekeyed = True
                self.reopen(service)

    def mint_tokens(self, n: int) -> list:
        """``n`` fresh idempotency tokens — pass the SAME list back to
        :meth:`call_many` on a manual retry so already-executed items are
        answered from the dedup window instead of running twice."""
        with self._lock:
            # both draws masked: an unmasked wraparound fallback would
            # truncate on the u32 wire word to a possibly-live token
            return [next(self._tokens) & 0xFFFFFFFF
                    or (next(self._tokens) & 0xFFFFFFFF)
                    for _ in range(n)]

    def call_many(self, items, return_exceptions: bool = False,
                  tokens=None, deadlines=None, priorities=None) -> list:
        """Scatter call: N (service, payload) pairs in ONE envelope / ONE
        transport round trip, executed across the gateway's worker shards —
        with ``workers=N`` the items' handlers run concurrently per
        service, so a slow service no longer head-of-line blocks the rest
        of the scatter (the sequential alternative is N ``call()`` round
        trips). Returns responses in item order; a failed item surfaces as
        its typed exception (in place with ``return_exceptions``, else the
        first one is raised after the scatter has drained). Every item
        consumes a sequence number on its channel, success or failure —
        batch discipline. Scatter calls are NOT auto-retried; to make a
        manual retry idempotent, pre-mint tokens (:meth:`mint_tokens`) and
        pass the same ``tokens`` list to every attempt — items whose
        original executed are then answered from the gateway's dedup
        window, never re-executed (omitting ``tokens`` mints fresh ones,
        so a bare re-issue re-executes). A stale-epoch rejection surfaces
        per item; recovery is ``reopen(service)`` + reissue.

        ``deadlines`` (positional, absolute ``time.monotonic()`` values or
        ``None``) seals each item's remaining budget into its frame's
        MAC-covered deadline word; the WIRE round trip is bounded by the
        cohort's tightest member so one short-deadline item cannot be held
        hostage by the transport default (docs/protocol.md §9).

        ``priorities`` (positional lane-12 classes, default ``PRIO_NORMAL``)
        seals each item's priority into its frame's MAC-covered word
        (docs/protocol.md §10)."""
        items = [(s, np.ascontiguousarray(np.asarray(p))) for s, p in items]
        if not items:
            return []
        if tokens is not None and len(tokens) != len(items):
            raise ValueError(f"{len(tokens)} tokens for {len(items)} items")
        if deadlines is not None and len(deadlines) != len(items):
            raise ValueError(
                f"{len(deadlines)} deadlines for {len(items)} items")
        if priorities is None:
            priorities = [framing.PRIO_NORMAL] * len(items)
        elif len(priorities) != len(items):
            raise ValueError(
                f"{len(priorities)} priorities for {len(items)} items")
        timeout: Optional[float] = None
        dl_us = [0] * len(items)
        if deadlines is not None:
            now = time.monotonic()
            rems = [None if d is None else d - now for d in deadlines]
            live = [r for r in rems if r is not None]
            if live:
                timeout = max(min(live), 0.001)
            dl_us = [0 if r is None else framing.deadline_to_us(r)
                     for r in rems]
        for service, _ in items:            # channel setup (CA-checked)
            self.open(service)
        if tokens is None:
            tokens = self.mint_tokens(len(items))
        with self._lock:
            chans = {s: self._channels[s] for s, _ in items}
            counts: Dict[str, int] = {}
            seqs = []
            for service, _ in items:
                k = counts.get(service, 0)
                seqs.append((chans[service].seq + k) & 0xFFFFFFFF)
                counts[service] = k + 1
            if framing.ZERO_COPY:
                # whole envelope staged straight into the transport (the
                # shared region on mpklink): route words + per-item route
                # + frames sealed in place, with ONE fused MAC pass per
                # channel (seeds differ across services, so the fusion is
                # per-group)
                rows_list = [framing.frame_rows(p.nbytes) for _, p in items]
                total = _ROUTE_BYTES + sum(
                    _ROUTE_BYTES + r * framing.LANES * 4 for r in rows_list)

                def fill(dst, items=items, seqs=seqs, tokens=tokens,
                         rows_list=rows_list, chans=chans, dl_us=dl_us,
                         priorities=priorities):
                    u = dst.view("<u4")
                    u[:4] = [GW_SCAT_MAGIC, self.cid, len(items), 0]
                    ofs = 4
                    groups: Dict[str, list] = {}
                    for (service, p), seq, token, rows, du, pr in zip(
                            items, seqs, tokens, rows_list, dl_us,
                            priorities):
                        chan = chans[service]
                        u[ofs:ofs + 4] = [GW_MAGIC, chan.sid, token, 0]
                        buf = u[ofs + 4: ofs + 4 + rows * framing.LANES] \
                            .reshape(rows, framing.LANES)
                        groups.setdefault(service, []).append(
                            (buf, p, seq, du, pr))
                        ofs += 4 + rows * framing.LANES
                    for service, members in groups.items():
                        framing.seal_into_batch(
                            [b for b, _, _, _, _ in members],
                            [p for _, p, _, _, _ in members],
                            seed=chans[service].seed,
                            seqs=[q for _, _, q, _, _ in members],
                            mac_impl=self.gw._batch_mac,
                            deadlines_us=[d for _, _, _, d, _ in members],
                            priorities=[r for _, _, _, _, r in members])

                # mpklint: disable=MPK002 reason=client lock IS the per-session serializer (spec: sessions are serial per client)
                raw = self._session.request_into(total, fill,
                                                 timeout=timeout)
            else:
                parts = [_scatter_route(self.cid, len(items))]
                for (service, p), seq, token, du, pr in zip(
                        items, seqs, tokens, dl_us, priorities):
                    chan = chans[service]
                    parts.append(np.array([GW_MAGIC, chan.sid, token, 0],
                                          "<u4").view(np.uint8))
                    frame = framing.build_frame(p, seed=chan.seed, seq=seq,
                                                mac_impl=self.gw._mac,
                                                deadline_us=du, priority=pr)
                    parts.append(frame.reshape(-1).view(np.uint8))
                # mpklint: disable=MPK002 reason=client lock IS the per-session serializer (spec: sessions are serial per client)
                raw = self._session.request(np.concatenate(parts),
                                            timeout=timeout)
            resp = np.ascontiguousarray(np.asarray(raw)) \
                .view(np.uint8).reshape(-1)
            if resp.nbytes < _ROUTE_BYTES:
                raise TransportError("malformed gateway response (truncated)")
            route = resp[:_ROUTE_BYTES].view("<u4")
            if int(route[0]) != GW_MAGIC:
                raise TransportError("malformed gateway response (bad magic)")
            if int(route[1]) == _ERR:       # whole-envelope failure: no item
                _raise_remote(resp[_ROUTE_BYTES:         # consumed a seq
                                   _ROUTE_BYTES + int(route[3])].tobytes())
            if int(route[1]) != _SOK or int(route[3]) != len(items):
                raise TransportError("malformed gateway scatter response")
            results: list = [None] * len(items)
            ofs = _ROUTE_BYTES
            ok_by_svc: Dict[str, list] = {}     # service → (i, rframe, seq)
            for i, ((service, _), seq) in enumerate(zip(items, seqs)):
                if resp.nbytes < ofs + _ROUTE_BYTES:
                    raise TransportError("truncated gateway scatter response")
                ih = resp[ofs: ofs + _ROUTE_BYTES].view("<u4")
                if int(ih[0]) != GW_MAGIC:
                    raise TransportError("desynced gateway scatter response")
                status, nb = int(ih[1]), int(ih[2])
                body = resp[ofs + _ROUTE_BYTES: ofs + _ROUTE_BYTES + nb]
                ofs += _ROUTE_BYTES + nb + ((-nb) % 4)
                if status == _OK:
                    ok_by_svc.setdefault(service, []).append(
                        (i, body.view("<u4").reshape(-1, framing.LANES), seq))
                else:
                    try:
                        _raise_remote(body.tobytes())
                    except Exception as e:
                        results[i] = e
            # ONE fused verify pass per channel; a corrupted item becomes
            # ITS typed FrameError (strict=False) — the rest of the scatter
            # drains and the sequence advance below keeps every channel
            # aligned with the server's positional discipline
            for service, members in ok_by_svc.items():
                verified = framing.verify_batch(
                    [f for _, f, _ in members], seed=chans[service].seed,
                    seqs=[q for _, _, q in members], strict=False,
                    mac_impl=self.gw._batch_mac)
                for (i, _, _), v in zip(members, verified):
                    results[i] = _own_result(v)
                    if not isinstance(v, framing.FrameError):
                        self.macs_verified += 1
            for service, k in counts.items():   # every item consumed a seq
                chans[service].seq += k
        if not return_exceptions:
            for r in results:
                if isinstance(r, BaseException):
                    raise r
        return results

    def _call_batch_once(self, chan: Channel, payloads,
                         return_exceptions: bool) -> list:
        with self._lock:
            n = len(payloads)
            if framing.ZERO_COPY:
                # whole batch envelope staged straight into the transport
                # (the shared region on mpklink): route words + N frames
                # sealed in place with ONE fused MAC pass
                ps = [np.ascontiguousarray(np.asarray(p)) for p in payloads]
                rows_list = [framing.frame_rows(p.nbytes) for p in ps]
                env_nbytes = _ROUTE_BYTES + sum(
                    r * framing.LANES * 4 for r in rows_list)

                def fill(dst, ps=ps, rows_list=rows_list, chan=chan):
                    u = dst.view("<u4")
                    u[:4] = [GW_BATCH_MAGIC, chan.sid, self.cid, n]
                    bufs, ofs = [], 4
                    for r in rows_list:
                        bufs.append(u[ofs: ofs + r * framing.LANES]
                                    .reshape(r, framing.LANES))
                        ofs += r * framing.LANES
                    framing.seal_into_batch(
                        bufs, ps, seed=chan.seed,
                        seqs=[chan.seq + i for i in range(n)],
                        mac_impl=self.gw._batch_mac)

                # mpklint: disable=MPK002 reason=client lock IS the per-session serializer (spec: sessions are serial per client)
                raw = self._session.request_into(env_nbytes, fill)
            else:
                frames = framing.seal_batch(payloads, seed=chan.seed,
                                            start_seq=chan.seq,
                                            mac_impl=self.gw._batch_mac)
                env = np.concatenate(
                    [_batch_route(chan.sid, self.cid, n)]
                    + [f.reshape(-1).view(np.uint8) for f in frames])
                # mpklint: disable=MPK002 reason=client lock IS the per-session serializer (spec: sessions are serial per client)
                raw = self._session.request(env)
            resp = np.ascontiguousarray(np.asarray(raw)) \
                .view(np.uint8).reshape(-1)
            if resp.nbytes < _ROUTE_BYTES:
                raise TransportError("malformed gateway response (truncated)")
            route = resp[:_ROUTE_BYTES].view("<u4")
            if int(route[0]) != GW_MAGIC:
                raise TransportError("malformed gateway response (bad magic)")
            if int(route[1]) == _ERR:       # whole-batch failure: no item
                _raise_remote(resp[_ROUTE_BYTES:         # consumed a seq
                                   _ROUTE_BYTES + int(route[3])].tobytes())
            if int(route[1]) != _BOK or int(route[3]) != n:
                raise TransportError("malformed gateway batch response")
            start, ofs = chan.seq, _ROUTE_BYTES
            results: list = [None] * n
            ok_frames, ok_pos = [], []
            for i in range(n):
                if resp.nbytes < ofs + _ROUTE_BYTES:
                    raise TransportError("truncated gateway batch response")
                ih = resp[ofs: ofs + _ROUTE_BYTES].view("<u4")
                if int(ih[0]) != GW_MAGIC:
                    raise TransportError("desynced gateway batch response")
                status, nb = int(ih[1]), int(ih[2])
                body = resp[ofs + _ROUTE_BYTES: ofs + _ROUTE_BYTES + nb]
                ofs += _ROUTE_BYTES + nb + ((-nb) % 4)
                if status == _OK:
                    ok_frames.append(body.view("<u4")
                                     .reshape(-1, framing.LANES))
                    ok_pos.append(i)
                else:
                    try:
                        _raise_remote(body.tobytes())
                    except Exception as e:
                        results[i] = e
            if ok_frames:                   # ONE vectorized verify pass
                verified = framing.verify_batch(
                    ok_frames, seed=chan.seed,
                    seqs=[start + i for i in ok_pos], strict=False,
                    mac_impl=self.gw._batch_mac)
                for p, v in zip(ok_pos, verified):
                    results[p] = _own_result(v)
                    if not isinstance(v, framing.FrameError):
                        self.macs_verified += 1
            chan.seq += n                   # every item consumed a sequence
        if not return_exceptions:
            for r in results:
                if isinstance(r, BaseException):
                    raise r
        return results

    def _call_once(self, chan: Channel, payload: np.ndarray,
                   token: int = 0,
                   deadline: Optional[float] = None,
                   priority: int = framing.PRIO_NORMAL) -> np.ndarray:
        # the remaining budget (not a fresh constant) bounds this attempt's
        # wire timeout and is sealed into the envelope's deadline word —
        # the hop-by-hop propagation contract (docs/protocol.md §9). The
        # wire wait stays clamped to the transport's per-attempt bound so
        # a lost response costs ONE attempt's wait, not the whole budget
        # (the remaining retries still get their share)
        timeout: Optional[float] = None
        deadline_us = 0
        if deadline is not None:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise DeadlineExpired(
                    f"call on channel {chan.service!r}: deadline expired "
                    "before send")
            deadline_us = framing.deadline_to_us(remaining)
            timeout = min(remaining, self.gw.transport.timeout)
        with self._lock:
            if framing.ZERO_COPY:
                # fully zero-copy send: route words + the sealed gateway
                # frame are written straight into the transport's staging
                # storage (the shared region on mpklink) — the envelope is
                # never materialized in its own buffer
                p = np.ascontiguousarray(np.asarray(payload))
                frows = framing.frame_rows(p.nbytes)
                env_nbytes = _ROUTE_BYTES + frows * framing.LANES * 4

                def fill(dst, p=p, frows=frows, chan=chan, token=token,
                         deadline_us=deadline_us, priority=priority):
                    u = dst.view("<u4")
                    u[:4] = [GW_MAGIC, chan.sid, self.cid, token]
                    framing.seal_into(
                        u[4:].reshape(frows, framing.LANES), p,
                        seed=chan.seed, seq=chan.seq, mac_impl=self.gw._mac,
                        deadline_us=deadline_us, priority=priority)

                # mpklint: disable=MPK002 reason=client lock IS the per-session serializer (spec: sessions are serial per client)
                raw = self._session.request_into(env_nbytes, fill,
                                                 timeout=timeout)
            else:
                env = _seal_envelope([GW_MAGIC, chan.sid, self.cid, token],
                                     payload, seed=chan.seed, seq=chan.seq,
                                     mac_impl=self.gw._mac,
                                     deadline_us=deadline_us,
                                     priority=priority)
                # mpklint: disable=MPK002 reason=client lock IS the per-session serializer (spec: sessions are serial per client)
                raw = self._session.request(env, timeout=timeout)
            resp = np.ascontiguousarray(np.asarray(raw)) \
                .view(np.uint8).reshape(-1)
            if resp.nbytes < _ROUTE_BYTES:
                raise TransportError("malformed gateway response (truncated)")
            route = resp[:_ROUTE_BYTES].view("<u4")
            if int(route[0]) != GW_MAGIC:
                raise TransportError("malformed gateway response (bad magic)")
            if int(route[1]) != _OK:
                _raise_remote(resp[_ROUTE_BYTES:
                                   _ROUTE_BYTES + int(route[3])].tobytes())
            rframe = resp[_ROUTE_BYTES:].view("<u4") \
                .reshape(-1, framing.LANES)
            out = framing.parse_frame(rframe, seed=chan.seed,
                                      expect_seq=chan.seq,
                                      mac_impl=self.gw._mac)
            chan.seq += 1
            self.macs_verified += 1
            return _own_result(out)

    def close(self):
        self.gw._release_client(self)
        with self._lock:
            self._channels.clear()
        if self._session_obj is not None:
            self._session_obj.close()


# ---------------------------------------------------------------------------
# transparent call coalescing (the auto-batching mux)
# ---------------------------------------------------------------------------

class _PendingCall:
    """One caller's parked inline call while it rides a cohort."""

    __slots__ = ("service", "payload", "token", "deadline", "priority",
                 "event", "result", "error")

    def __init__(self, service: str, payload: np.ndarray, token: int,
                 deadline: Optional[float] = None,
                 priority: int = framing.PRIO_NORMAL):
        self.service = service
        self.payload = payload
        self.token = token
        self.deadline = deadline        # absolute monotonic, None = no budget
        self.priority = priority        # lane-12 class (protocol.md §10)
        self.event = threading.Event()
        self.result: Optional[np.ndarray] = None
        self.error: Optional[BaseException] = None


class CallCoalescer:
    """Transparent auto-batching for inline gateway calls.

    64 independent clients issuing inline ``call()``s pay one transport
    round trip (key syncs + doorbell wakeups + scalar MAC) EACH. The mux
    removes that per-message constant without asking callers to change:
    concurrent calls arriving within an **adaptive window** are folded
    into ONE scatter envelope (``GW_SCAT_MAGIC``) on a dedicated carrier
    client — one round trip, one fused MAC pass per channel group on each
    side, one wakeup per cohort — and the per-item responses are handed
    back to their callers. A single-service cohort degenerates server-side
    to the batch pipeline (one channel group: one fused verify, ONE native
    ``batch_handler`` call when the service registered one — an
    EngineService cohort joins the decode grid as one unit, one fused
    seal).

    Semantics are the inline ones, preserved bit-for-bit:

    * **ordering** — a caller is serial (it blocks for its result), and a
      channel group executes in envelope order, so per-caller order holds;
    * **authorization** — ``GatewayClient.call`` opens the CALLER's own
      channel (CA + allow-list check) before folding; services that refuse
      the carrier identity simply keep the direct path (:meth:`accepts`);
    * **idempotency/dedup** — every folded call carries a carrier-minted
      token; the liveness fallback replays the SAME tokens inline, so an
      item whose cohort envelope executed but whose response was lost is
      answered from the gateway dedup window, never re-executed;
    * **breaker** — items execute under the same ``_run_guarded`` /
      admission core; a shed surfaces as that item's typed
      ``ServiceUnavailable``;
    * **crash** — a cohort envelope that dies on the wire surfaces per
      item: the mux heals the carrier session and replays each item inline
      (same token), so a poisoned item fails typed while its cohort-mates
      recover; a stale-epoch rejection re-keys through the CA and retries
      once, exactly like ``call()``.

    Adaptive window: the drainer waits
    ``min(max_wait_us, (max_batch - 1) * EWMA(inter-arrival gap))`` for a
    cohort to fill — long enough to collect ~``max_batch`` arrivals at the
    observed rate — and waits nothing at all when arrivals are sparser
    than ``max_wait_us`` apart (coalescing cannot pay there; latency is
    not taxed). The window is recomputed per cohort, so the mux tracks
    load swings. The normative rules live in docs/protocol.md §5.4.
    """

    def __init__(self, gw: ServiceGateway, *, max_batch: int = 64,
                 max_wait_us: float = 300.0, name: str = "gw:coalescer",
                 ewma_alpha: float = 0.2):
        if max_batch < 1 or max_batch > _MAX_SCATTER:
            raise ValueError(f"max_batch must be in [1, {_MAX_SCATTER}]")
        self.gw = gw
        self.max_batch = int(max_batch)
        self.max_wait_us = float(max_wait_us)
        self._alpha = float(ewma_alpha)
        # retries=2: the liveness-fallback replays ride the carrier's own
        # bounded retry (same pinned token each attempt → dedup-protected),
        # so a fault landing on a REPLAY heals too instead of surfacing
        self._carrier = gw.connect(name, retries=2)
        self._carrier._direct = True        # the carrier never re-enters
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._pending: List[_PendingCall] = []
        self._ewma_gap: Optional[float] = None
        self._last_arrival: Optional[float] = None
        self._accepted: set = set()         # services the carrier may fold
        self._refused: set = set()          # services that refuse the carrier
        self._stop = threading.Event()
        self.stats: Dict[str, int] = {
            "cohorts": 0, "coalesced_calls": 0, "max_cohort": 0,
            "fallback_items": 0, "rekeys": 0}
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="gw-coalescer")
        self._thread.start()

    # -- caller side --------------------------------------------------------
    def accepts(self, service: str) -> bool:
        """True when calls to ``service`` can ride the mux — i.e. the
        carrier identity is authorized for it. Checked against the CA once
        and cached BOTH ways: the positive path must not touch the carrier
        (whose lock is held across a cohort's whole wire round trip — an
        uncached probe would serialize arriving callers behind the
        in-flight cohort instead of letting the next cohort form)."""
        if self._stop.is_set():
            return False
        if service in self._accepted:
            return True
        if service in self._refused:
            return False
        try:
            self._carrier.open(service)
            self._accepted.add(service)
            return True
        except AccessViolation:
            self._refused.add(service)
            return False

    def call(self, service: str, payload: np.ndarray,
             deadline: Optional[float] = None,
             priority: int = framing.PRIO_NORMAL) -> np.ndarray:
        """Fold one inline call into the next cohort; block for ITS result
        (or raise its typed error). The caller's wait bound DERIVES from
        its propagated deadline when it has one — remaining budget, plus
        one wire attempt for the cohort that may already be in flight,
        plus the batching window and fixed slack — so a 1 s-deadline call
        fails typed in about a second. Without a deadline the bound is
        two transport attempts (the cohort's wire trip + the liveness
        fallback's shared replay budget) plus window and slack: every
        term is a budget some layer actually spends, no bare constants
        (docs/protocol.md §9). ``priority`` steers the batching window
        (§10): a PRIO_HIGH arrival collapses the wait to zero — the cohort
        dispatches with whatever has gathered — while an all-PRIO_BULK
        cohort always waits the full ``max_wait_us`` to fill."""
        if self._stop.is_set():
            raise TransportError("coalescer is closed")
        entry = _PendingCall(service, np.asarray(payload),
                             self._carrier.mint_tokens(1)[0], deadline,
                             priority)
        with self._cond:
            # re-check under the lock: close() sets _stop under it too, so
            # an entry can never slip in after close() drained the queue
            # (it would otherwise strand until the full event-wait bound)
            if self._stop.is_set():
                raise TransportError("coalescer is closed")
            now = time.monotonic()
            if self._last_arrival is not None:
                gap = now - self._last_arrival
                self._ewma_gap = gap if self._ewma_gap is None else \
                    (1.0 - self._alpha) * self._ewma_gap + self._alpha * gap
            self._last_arrival = now
            self._pending.append(entry)
            self._cond.notify_all()
        window_slack = self.max_wait_us / 1e6 + 1.0
        if deadline is not None:
            bound = max(0.0, deadline - time.monotonic()) \
                + self.gw.transport.timeout + window_slack
        else:
            bound = self.gw.transport.timeout * 2 + window_slack
        if not entry.event.wait(bound):
            raise ResponseTimeout(
                f"coalesced call to {service!r} stalled past the transport "
                f"deadline")
        if entry.error is not None:
            raise entry.error
        return entry.result

    def _window_s(self) -> float:
        cap = self.max_wait_us / 1e6
        gap = self._ewma_gap
        if gap is None:
            return cap
        if gap >= cap:                  # arrivals sparser than the window:
            return 0.0                  # coalescing can't pay — don't wait
        return min(cap, gap * (self.max_batch - 1))

    def _priority_window_s(self) -> float:
        """The batching window under the cohort's priority mix
        (docs/protocol.md §10). Called under the condition lock.

        * any PRIO_HIGH pending → 0 — a latency-sensitive call never
          donates its budget to batch filling; the cohort goes now;
        * all PRIO_BULK → the full ``max_wait_us`` cap — throughput
          traffic always waits out the window so cohorts fill;
        * mixed/normal → the adaptive EWMA window (§5.4), unchanged.
        """
        ranks = [priority_rank(e.priority) for e in self._pending]
        if min(ranks) == _PRIO_RANK[framing.PRIO_HIGH]:
            return 0.0
        if max(ranks) == min(ranks) == _PRIO_RANK[framing.PRIO_BULK]:
            return self.max_wait_us / 1e6
        return self._window_s()

    def _has_high(self) -> bool:
        return any(priority_rank(e.priority)
                   == _PRIO_RANK[framing.PRIO_HIGH] for e in self._pending)

    # -- drainer ------------------------------------------------------------
    def _run(self):
        while True:
            with self._cond:
                while not self._pending:
                    if self._stop.is_set():
                        return
                    self._cond.wait(0.5)
                deadline = time.monotonic() + self._priority_window_s()
                while (len(self._pending) < self.max_batch
                       and not self._stop.is_set()):
                    if self._has_high():
                        break           # a HIGH arrival ends the window NOW
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        break
                    self._cond.wait(remaining)
                if len(self._pending) > self.max_batch:
                    # overflow cohort: urgent classes board first, arrival
                    # order preserved within a class (stable selection);
                    # the bumped tail keeps its relative order for the
                    # next cohort
                    take = sorted(sorted(
                        range(len(self._pending)),
                        key=lambda i: (priority_rank(
                            self._pending[i].priority), i))
                        [: self.max_batch])
                    batch = [self._pending[i] for i in take]
                    for i in reversed(take):
                        del self._pending[i]
                else:
                    batch = self._pending[:]
                    self._pending.clear()
            try:
                self._execute(batch)
            except BaseException as e:  # noqa: B036 — never strand a caller
                for entry in batch:
                    if not entry.event.is_set():
                        if entry.error is None and entry.result is None:
                            entry.error = TransportError(
                                f"coalescer dispatch failed: "
                                f"{type(e).__name__}: {e}")
                        entry.event.set()

    # the carrier already hands back owned results (_own_result at the
    # GatewayClient boundary); kept as a second line of defense so a mux
    # result can never alias storage the next cohort's exchange recycles
    _own = staticmethod(_own_result)

    def _execute(self, batch: List[_PendingCall]):
        self.stats["cohorts"] += 1
        self.stats["coalesced_calls"] += len(batch)
        self.stats["max_cohort"] = max(self.stats["max_cohort"], len(batch))
        items = [(e.service, e.payload) for e in batch]
        tokens = [e.token for e in batch]
        deadlines = [e.deadline for e in batch]
        priorities = [e.priority for e in batch]
        rekeyed = False
        while True:
            try:
                results = [self._own(r) for r in self._carrier.call_many(
                    items, return_exceptions=True, tokens=tokens,
                    deadlines=deadlines, priorities=priorities)]
                break
            except AccessViolation as e:
                # pre-dispatch stale epoch (carrier channel open): re-key
                # through the CA once and resend — the envelope never ran
                if "stale key epoch" not in str(e) or rekeyed:
                    results = [e] * len(batch)
                    break
                rekeyed = True
                self.stats["rekeys"] += 1
                for svc in dict.fromkeys(e2.service for e2 in batch):
                    self._carrier.reopen(svc)
            except (ServiceCrashed, ResponseTimeout, TransportError):
                # the WHOLE envelope died on the wire. Heal the carrier and
                # replay every item inline with its ORIGINAL token: items
                # the envelope did execute are answered from the gateway
                # dedup window (never re-executed); the rest run fresh —
                # per-item inline semantics, bit-for-bit
                results = self._fallback(batch)
                break
        for entry, res in zip(batch, results):
            if isinstance(res, AccessViolation) \
                    and "stale key epoch" in str(res):
                # per-item stale epoch (revocation landed mid-cohort):
                # transparent re-key + single inline retry, like call()
                try:
                    self._carrier.reopen(entry.service)
                    res = self._own(self._carrier.call(
                        entry.service, entry.payload, token=entry.token,
                        priority=entry.priority))
                    self.stats["rekeys"] += 1
                except Exception as e2:
                    res = e2
            if isinstance(res, BaseException):
                entry.error = res
            else:
                entry.result = res
            entry.event.set()

    def _fallback(self, batch: List[_PendingCall]) -> list:
        """Replay a failed cohort inline, item by item, with the ORIGINAL
        tokens. The whole pass shares ONE transport-deadline budget: each
        item gets the remaining budget split over the items left, so a
        wedged service costs its items their (shrinking) share instead of
        head-of-line blocking every coalesced caller in the process for
        items x retries x timeout. An item that carries its own propagated
        deadline is bounded by the TIGHTER of the two — and one already
        expired is failed typed immediately, before any cohort-mate's
        replay can sit on it."""
        self.stats["fallback_items"] += len(batch)
        deadline = time.monotonic() + self.gw.transport.timeout
        healed: set = set()                 # services reopened this session
        out = []
        for k, entry in enumerate(batch):
            per_item = max(0.05,
                           (deadline - time.monotonic()) / (len(batch) - k))
            if entry.deadline is not None:
                remaining = entry.deadline - time.monotonic()
                if remaining <= 0:
                    out.append(DeadlineExpired(
                        f"coalesced call to {entry.service!r}: deadline "
                        "expired during the cohort's liveness fallback"))
                    continue
                per_item = min(per_item, remaining)
            try:
                s = self._carrier._session_obj
                if s is None or s._crashed or s._closed or s._poisoned:
                    self._carrier.heal()    # fresh session; channels stale
                    healed.clear()
                if entry.service not in healed:
                    self._carrier.reopen(entry.service)     # seqs reset
                    healed.add(entry.service)
                # budget per_item PER ATTEMPT: a replay that is itself
                # dropped must still afford the carrier's bounded retries
                # (wire waits stay clamped per attempt in _call_once)
                out.append(self._own(self._carrier.call(
                    entry.service, entry.payload, token=entry.token,
                    timeout=per_item * (self._carrier.retries + 1),
                    priority=entry.priority)))
            except Exception as e:          # noqa: PERF203 — per-item fate
                out.append(e)
        return out

    def close(self):
        """Stop the drainer, fail anything still parked (typed), release
        the carrier. Idempotent."""
        if self._stop.is_set():
            return
        with self._cond:                    # atomic with call()'s re-check
            self._stop.set()
            self._cond.notify_all()
        self._thread.join(timeout=10)
        with self._cond:
            doomed, self._pending = self._pending, []
        for entry in doomed:
            entry.error = TransportError(
                "coalescer closed while the call was in flight")
            entry.event.set()
        try:
            self._carrier.close()
        # mpklint: disable=MPK105 reason=best-effort carrier close at shutdown
        except Exception:
            pass


# ---------------------------------------------------------------------------
# replica fleets (the replicated serving layer)
# ---------------------------------------------------------------------------

EWMA_ALPHA = 0.2                    # replica service-time EWMA smoothing


class _ReplicaGone(Exception):
    """Internal routing signal: the picked replica died between admission
    and wire submission. The request was NEVER sent, so it is safe to
    re-route to a survivor — unlike a true in-flight loss, which must
    surface as the typed ServiceCrashed. Never escapes the fleet."""


class ReplicaRouter:
    """Seeded power-of-two-choices least-loaded router.

    Per decision the router draws exactly ``choices`` distinct candidate
    indices from its private seeded stream and picks the least-loaded by
    ``(inflight, ewma_ms, rid)``. Everything is deterministic in (seed,
    observation sequence): two routers built from the same seed and fed
    the same load observations produce the identical assignment sequence
    — the FaultPlan property that makes fleet bugs reproduce from a
    one-line seed. With ``record=True`` every decision is appended to
    ``trace`` as ``(loads, candidates, picked)`` and :meth:`replay`
    re-derives the picks from a fresh router, failing loudly on the first
    divergence."""

    def __init__(self, seed: int = 0x524F5554, *,
                 choices: int = FLEET_CHOICES, record: bool = False):
        if choices < 1:
            raise ValueError("choices must be >= 1")
        self.seed = seed
        self.choices = choices
        self.record = record
        self._rng = random.Random(seed)
        self.picks = 0
        self.assigned: Dict[int, int] = {}      # rid -> decisions won
        self.trace: List[Tuple] = []            # (loads, cands, picked)

    def pick(self, loads) -> int:
        """One routing decision. ``loads`` is the ordered ACTIVE set as
        ``(rid, inflight, ewma_ms)`` triples; → the picked rid."""
        n = len(loads)
        if n == 0:
            raise ServiceUnavailable("router invoked with no active replicas")
        cands = [loads[i] for i in self._draw(n)]
        picked = min(cands, key=lambda t: (t[1], t[2], t[0]))[0]
        self.picks += 1
        self.assigned[picked] = self.assigned.get(picked, 0) + 1
        if self.record:
            self.trace.append((tuple(loads),
                               tuple(c[0] for c in cands), picked))
        return picked

    def _draw(self, n: int) -> List[int]:
        """``min(choices, n)`` distinct indices. The draw count depends
        only on ``n`` (part of every observation), keeping the stream
        position — and therefore every later decision — deterministic."""
        k = min(self.choices, n)
        out: List[int] = []
        for d in range(k):
            j = self._rng.randrange(n - d)
            for prev in sorted(out):
                if j >= prev:
                    j += 1
            out.append(j)
        return out

    def replay(self, trace) -> List[int]:
        """Re-derive a recorded decision sequence from a FRESH router with
        this router's seed/choices; raises AssertionError on the first
        divergent pick. → the replayed assignment sequence."""
        fresh = ReplicaRouter(self.seed, choices=self.choices)
        out = []
        for k, (loads, _cands, picked) in enumerate(trace):
            got = fresh.pick(list(loads))
            if got != picked:
                raise AssertionError(
                    f"router replay diverged at decision {k}: "
                    f"recorded rid {picked}, replayed rid {got} "
                    f"(seed {self.seed:#x})")
            out.append(got)
        return out


def simulate_assignments(seed: int, arrivals_ms, n_replicas: int,
                         service_ms=1.0, *,
                         choices: int = FLEET_CHOICES) -> List[int]:
    """Deterministic discrete-event model of fleet routing: each replica
    serves serially at ``service_ms`` per item (scalar or per-arrival
    sequence); inflight at each arrival instant is derived from completion
    times, never from wall clock. Pure function of its arguments —
    identical ``(seed, arrival trace)`` yields the identical replica
    assignment sequence, which is both the determinism property the tests
    pin and the offline tool for reproducing a fleet imbalance from a
    one-line seed."""
    router = ReplicaRouter(seed, choices=choices)
    svc = list(service_ms) if np.ndim(service_ms) else \
        [float(service_ms)] * len(list(arrivals_ms))
    arrivals = list(arrivals_ms)
    if len(svc) != len(arrivals):
        raise ValueError(f"{len(svc)} service times for "
                         f"{len(arrivals)} arrivals")
    outstanding: List[List[float]] = [[] for _ in range(n_replicas)]
    finish = [0.0] * n_replicas
    ewma = [0.0] * n_replicas
    out: List[int] = []
    for t, s in zip(arrivals, svc):
        loads = []
        for rid in range(n_replicas):
            outstanding[rid] = [c for c in outstanding[rid] if c > t]
            loads.append((rid, len(outstanding[rid]), ewma[rid]))
        picked = router.pick(loads)
        done = max(t, finish[picked]) + s
        finish[picked] = done
        outstanding[picked].append(done)
        ewma[picked] = s if ewma[picked] == 0.0 else \
            (1.0 - EWMA_ALPHA) * ewma[picked] + EWMA_ALPHA * s
        out.append(picked)
    return out


class Replica:
    """One fleet member: its own transport instance (its own key registry,
    protection domain and epoch — proc-backed by default, so the handler
    runs in a forked child over a private POSIX shm segment) plus the one
    session the fleet drives it through. The session is serial per the
    session model; ``rlock`` is the fleet-side serializer. ``inflight``
    counts admission→completion (queued + on the wire), which is what the
    power-of-two router balances on."""

    def __init__(self, rid: int, service: str, transport, session):
        self.rid = rid
        self.service = service
        self.transport = transport
        self.session = session
        self.state = REPLICA_ACTIVE
        self.inflight = 0
        self.ewma_ms: Optional[float] = None
        self.served = 0
        self.crashes = 0
        self.released = False
        self.rlock = threading.Lock()       # serializes wire use
        self.quiesced = threading.Event()


class ServiceFleet:
    """N replicas behind one service name, with routing, cohort-whole
    admission, drain/join and crash containment (docs/protocol.md §8,
    docs/architecture.md "The replica fleet").

    * ``dispatch`` is the service handler: seeded power-of-two-choices
      least-loaded admission, then one ``session.request`` on the picked
      replica. A replica that dies between admission and submission is
      re-routed (the request never reached a wire); a true in-flight death
      surfaces as the typed :class:`ServiceCrashed` and marks the replica
      DEAD — the router never picks it again.
    * ``dispatch_batch`` is the service ``batch_handler``: a batch
      envelope or auto-coalesced cohort lands WHOLE on one replica and
      rides its ring as one pipelined ``call_batch`` (cohort-aware
      admission — a cohort is never split across replicas).
    * ``drain``/``add`` implement the live-traffic membership machinery;
      both epoch-bump the service domain through the gateway so clients
      re-key exactly once per membership change.
    """

    def __init__(self, gw: "ServiceGateway", name: str, *,
                 router_seed: int = 0x524F5554):
        self.gw = gw
        self.name = name
        self.router = ReplicaRouter(router_seed)
        self._lock = threading.Lock()
        self._replicas: "OrderedDict[int, Replica]" = OrderedDict()
        self._rid_counter = itertools.count(0)
        # last add()'s (handler, transport, kwargs): what a supervisor
        # respawns a dead replica FROM (docs/protocol.md §9)
        self._spawn: Optional[tuple] = None
        # hedging (enable_hedging): OFF by default
        self._hedge = False
        self._hedge_delay: Optional[float] = None
        self._hedge_quantile = 0.95
        self.hedge_budget: Optional[RetryBudget] = None
        self._lat_ms: "deque" = deque(maxlen=HEDGE_RESERVOIR)
        # per-tenant WFQ over replica in-flight slots (enable_fair_queue):
        # OFF by default
        self._fair_gate: Optional[_FairGate] = None
        self.stats = {"routed": 0, "cohorts": 0, "rerouted": 0,
                      "crashes": 0, "drains": 0, "joins": 0,
                      "expired": 0, "hedges_fired": 0, "hedges_won": 0,
                      "fair_queued": 0}

    # -- membership ---------------------------------------------------------
    def add(self, handler: Handler, *,
            transport: Union[str, type] = "mpklink_opt_proc",
            transport_kwargs: Optional[dict] = None) -> int:
        """Start one replica of ``handler`` behind its own transport
        instance and place it in the routing set. → replica id."""
        if isinstance(transport, str):
            from repro.core import ALL_TRANSPORTS
            transport = ALL_TRANSPORTS[transport]
        self._spawn = (handler, transport, dict(transport_kwargs or {}))
        tr = transport(handler, **dict(transport_kwargs or {}))
        try:
            with self._lock:
                rid = next(self._rid_counter)
                session = tr.connect(f"replica:{self.name}#{rid}")
                self._replicas[rid] = Replica(rid, self.name, tr, session)
                self.stats["joins"] += 1
        except BaseException:
            tr.close()
            raise
        return rid

    def drain(self, rid: int, timeout: Optional[float] = 30.0) -> bool:
        """ACTIVE → DRAINING immediately (the router stops picking it; new
        admissions are impossible), then wait up to ``timeout`` for the
        admitted in-flight work to complete. Quiescence releases the
        replica's session/transport (segment slots recycle ONLY now — the
        crash invariant); a timeout releases nothing and the replica stays
        DRAINING. A DEAD replica drains trivially: nothing is in flight
        that can still complete, and procwire's own close path keeps its
        in-flight slots unrecycled forever. → True once quiesced."""
        with self._lock:
            rep = self._replicas[rid]
            if rep.state == REPLICA_ACTIVE:
                rep.state = REPLICA_DRAINING
                self.stats["drains"] += 1
            if rep.state == REPLICA_QUIESCED:
                return True
            if rep.inflight == 0 or rep.state == REPLICA_DEAD:
                rep.quiesced.set()
        if not rep.quiesced.wait(timeout):
            return False
        with self._lock:
            if rep.state in (REPLICA_DRAINING, REPLICA_DEAD):
                # a released corpse leaves the planners' view too: QUIESCED
                # replicas are neither active nor reclaimable, so a
                # supervisor sweep releases (and re-keys for) each death
                # exactly once
                rep.state = REPLICA_QUIESCED
        self._release(rep)
        return True

    def _release(self, rep: Replica) -> None:
        with self._lock:
            if rep.released:
                return
            rep.released = True
        try:
            rep.session.close()
        # mpklint: disable=MPK105 reason=best-effort release of a quiesced/dead replica session
        except Exception:
            pass
        try:
            rep.transport.close()
        # mpklint: disable=MPK105 reason=best-effort release of a quiesced/dead replica transport
        except Exception:
            pass

    def close(self) -> None:
        """Gateway teardown: release every replica. Unquiesced replicas
        are torn down too — the process is exiting; procwire's own close
        path preserves the crash invariant for anything still in flight."""
        with self._lock:
            reps = list(self._replicas.values())
        for rep in reps:
            self._release(rep)

    # -- hedging ------------------------------------------------------------
    def enable_hedging(self, *, delay: Optional[float] = None,
                       quantile: float = 0.95,
                       budget: Optional[RetryBudget] = None
                       ) -> "RetryBudget":
        """Turn on late-binding request hedging (docs/protocol.md §9):
        a request still PARKED on a busy replica's wire lock after the
        hedge delay is re-routed to a *different* replica instead of
        continuing to wait. The request has not been sent when the hedge
        fires, so exactly ONE wire send ever happens — executed-request
        count is provably unchanged (no dedup races, no double-execution
        window). ``delay`` pins a fixed hedge delay in seconds;
        ``delay=None`` adapts it to the observed ``quantile`` of recent
        dispatch latencies (a :data:`HEDGE_RESERVOIR`-sized window).
        Hedges spend from ``budget`` (a shared :class:`RetryBudget`;
        default a private one) so a fleet-wide stall cannot amplify into
        a re-route storm. → the budget in use."""
        with self._lock:
            self._hedge = True
            self._hedge_delay = None if delay is None else float(delay)
            self._hedge_quantile = float(quantile)
            self.hedge_budget = budget if budget is not None \
                else RetryBudget()
            return self.hedge_budget

    def enable_fair_queue(self, capacity: float, *,
                          quantum: float = WFQ_QUANTUM) -> _FairGate:
        """Turn on weighted fair queuing over the fleet's in-flight slots
        (docs/protocol.md §10): at most ``capacity`` request units in
        flight fleet-wide, with slots granted across backlogged tenants
        by deficit round-robin under the gateway's per-tenant weights
        (:meth:`ServiceGateway.set_tenant_weight`). One tenant's cohort
        backlog can then delay another tenant by at most one cohort per
        round instead of monopolizing every replica. → the gate (for
        observability)."""
        with self._lock:
            if self._fair_gate is not None:
                raise RuntimeError(
                    f"fair queue already enabled for fleet {self.name!r}")
            gate = _FairGate(capacity, weight_of=self.gw._tenant_weight,
                             quantum=quantum)
            self._fair_gate = gate
            return gate

    def _fair_acquire(self, cost: int,
                      deadline: Optional[float]) -> Optional[_FairGate]:
        """Acquire the fair gate (when enabled) for ``cost`` units under
        the calling tenant's flow. → the gate to release, or None when
        fair queuing is off. Sheds typed when the deadline expires while
        parked (nothing charged)."""
        gate = self._fair_gate
        if gate is None:
            return None
        key = current_identity() or "<anon>"
        with self._lock:
            self.stats["fair_queued"] += cost
        if not gate.acquire(key, cost, deadline):
            with self._lock:
                self.stats["expired"] += cost
            raise DeadlineExpired(
                f"service {self.name!r}: deadline expired while queued "
                f"at the fair gate — shed before routing")
        return gate

    def _hedge_after(self) -> Optional[float]:
        """Current hedge delay in seconds, or None when hedging is off /
        has no signal yet (adaptive mode needs a seeded reservoir)."""
        if not self._hedge:
            return None
        if self._hedge_delay is not None:
            return self._hedge_delay
        with self._lock:
            lats = sorted(self._lat_ms)
        if len(lats) < 8:           # not enough signal — don't hedge blind
            return None
        q = lats[min(len(lats) - 1, int(self._hedge_quantile * len(lats)))]
        return q / 1e3

    def _observe_latency(self, ms: float) -> None:
        with self._lock:
            self._lat_ms.append(ms)

    # -- routing ------------------------------------------------------------
    def _route(self, weight: int = 1,
               exclude: Optional[int] = None) -> Replica:
        with self._lock:
            loads = [(r.rid, r.inflight,
                      r.ewma_ms if r.ewma_ms is not None else 0.0)
                     for r in self._replicas.values()
                     if r.state == REPLICA_ACTIVE]
            if exclude is not None and len(loads) > 1:
                # hedge re-route: a DIFFERENT replica when one exists (a
                # single-replica fleet just re-queues on the only wire)
                loads = [t for t in loads if t[0] != exclude]
            if not loads:
                raise ServiceUnavailable(
                    f"service {self.name!r}: no active replicas")
            rep = self._replicas[self.router.pick(loads)]
            rep.inflight += weight
            self.stats["routed"] += weight
            return rep

    def _acquire(self, rep: Replica, deadline: Optional[float],
                 may_hedge: bool) -> str:
        """Admission→submission wait on the replica's wire lock, bounded
        by the propagated deadline and (optionally) the hedge delay.
        → ``"acquired"`` (lock held), ``"expired"`` (deadline passed while
        queued — the request was NEVER sent), or ``"hedge"`` (hedge delay
        passed AND a budget token was granted — re-route, nothing sent)."""
        hedge_after = self._hedge_after() if may_hedge else None
        waited = 0.0
        while True:
            bounds = []
            if deadline is not None:
                rem = deadline - time.monotonic()
                if rem <= 0:
                    return "expired"
                bounds.append(rem)
            if hedge_after is not None:
                bounds.append(max(0.0, hedge_after - waited))
            if not bounds:
                rep.rlock.acquire()
                return "acquired"
            t0 = time.monotonic()
            if rep.rlock.acquire(timeout=min(bounds)):
                return "acquired"
            waited += time.monotonic() - t0
            if deadline is not None and time.monotonic() >= deadline:
                return "expired"
            if hedge_after is not None and waited >= hedge_after:
                if self.hedge_budget.take():
                    return "hedge"
                hedge_after = None      # budget dry: wait like an unhedged
                #                         request (no retry-storm boost)

    def _complete(self, rep: Replica, weight: int, elapsed_ms: float,
                  ok: bool) -> None:
        with self._lock:
            rep.inflight -= weight
            if ok:
                rep.served += weight
                per = elapsed_ms / max(1, weight)
                rep.ewma_ms = per if rep.ewma_ms is None else \
                    (1.0 - EWMA_ALPHA) * rep.ewma_ms + EWMA_ALPHA * per
            if rep.state in (REPLICA_DRAINING, REPLICA_DEAD) \
                    and rep.inflight == 0:
                rep.quiesced.set()

    def _mark_dead(self, rep: Replica) -> None:
        with self._lock:
            if rep.state in (REPLICA_DEAD, REPLICA_QUIESCED):
                return
            rep.state = REPLICA_DEAD
            rep.crashes += 1
            self.stats["crashes"] += 1

    def _link_died(self, rep: Replica) -> bool:
        """True when the replica LINK is gone (child death / poisoned
        session) — as opposed to a remote handler raising a typed error
        that merely reconstructs as the same class on this side."""
        s = rep.session
        return bool(getattr(s, "_crashed", False)
                    or getattr(s, "_poisoned", False)
                    or getattr(s, "_closed", False))

    # -- data plane (the service handler / batch_handler) -------------------
    def dispatch(self, payload: np.ndarray) -> np.ndarray:
        """Route one request to one replica. Runs on the gateway's session
        service threads / shards — concurrency across replicas is real;
        within a replica, ``rlock`` keeps the session serial.

        The admission→submission wait honors the caller's propagated
        deadline (work that expires while QUEUED is shed typed, never
        sent) and, with :meth:`enable_hedging` on, re-routes a parked
        request to a different replica after the hedge delay — late
        binding: the request has a single wire send either way, so
        hedging can never double-execute. Deliberately does NOT tighten
        the replica wire timeout itself: a mid-exchange ``ResponseTimeout``
        poisons the session and would retire a healthy replica.

        With :meth:`enable_fair_queue` on, routing is preceded by a
        per-tenant DRR grant of one in-flight slot (docs/protocol.md §10)
        keyed on the calling identity (``current_identity``), so a noisy
        tenant's backlog parks at the gate instead of saturating every
        replica."""
        deadline = current_deadline()
        gate = self._fair_acquire(1, deadline)
        try:
            return self._dispatch_routed(payload, deadline)
        finally:
            if gate is not None:
                gate.release(1)

    def _dispatch_routed(self, payload: np.ndarray,
                         deadline: Optional[float]) -> np.ndarray:
        attempts = 0
        hedged = False
        exclude: Optional[int] = None
        while True:
            rep = self._route(exclude=exclude)
            exclude = None
            t0 = time.perf_counter()
            ok = False
            try:
                acq = self._acquire(rep, deadline, not hedged)
                if acq == "expired":
                    with self._lock:
                        self.stats["expired"] += 1
                    raise DeadlineExpired(
                        f"service {self.name!r}: deadline expired while "
                        f"queued for replica {rep.rid} — shed before send")
                if acq == "hedge":
                    hedged = True
                    exclude = rep.rid
                    with self._lock:
                        self.stats["hedges_fired"] += 1
                    continue        # finally undoes this rep's admission
                try:
                    if rep.state != REPLICA_ACTIVE \
                            and rep.state != REPLICA_DRAINING:
                        raise _ReplicaGone()
                    if deadline is not None \
                            and time.monotonic() >= deadline:
                        with self._lock:
                            self.stats["expired"] += 1
                        raise DeadlineExpired(
                            f"service {self.name!r}: deadline expired at "
                            f"replica {rep.rid}'s wire — shed before send")
                    # mpklint: disable=MPK002 reason=rlock IS the replica wire lock; the proc session is serial by contract and callers park here by design
                    out = rep.session.request(payload)
                finally:
                    rep.rlock.release()
                ok = True
                # every completed primary refills the hedge budget — even
                # when the bucket ran dry mid-storm (RetryBudget earning is
                # unconditional), so hedging recovers once load normalizes
                # instead of staying disabled forever
                if self.hedge_budget is not None:
                    self.hedge_budget.note_primary()
                self._observe_latency((time.perf_counter() - t0) * 1e3)
                if hedged:
                    with self._lock:
                        self.stats["hedges_won"] += 1
                return out
            except _ReplicaGone:
                attempts += 1
                with self._lock:
                    self.stats["rerouted"] += 1
                if attempts > 32:
                    raise ServiceUnavailable(
                        f"service {self.name!r}: re-route budget exhausted")
            except DeadlineExpired:
                raise           # a shed, not a replica failure: never
                #                 retires the replica (subclasses
                #                 ResponseTimeout — must precede it)
            except ServiceCrashed:
                if self._link_died(rep):
                    self._mark_dead(rep)
                raise
            except ResponseTimeout:
                # a ring/lockstep deadline expiry poisons the session —
                # the replica can no longer be driven; retire it
                self._mark_dead(rep)
                raise
            finally:
                self._complete(rep, 1, (time.perf_counter() - t0) * 1e3, ok)

    def dispatch_batch(self, payloads) -> list:
        """Cohort-aware admission: the WHOLE batch lands on ONE replica
        and rides its ring as one pipelined ``call_batch`` (ring-windowed
        for cohorts larger than the slot ring). Per-item remote failures
        come back as typed exception instances in their slots (the
        gateway's batch paths map them to per-item typed errors); a child
        death mid-cohort marks the replica DEAD and every not-yet-served
        item of the cohort carries the typed ServiceCrashed.

        Honors the tightest propagated deadline of the cohort (the
        thread-local set by the gateway's batch execution core): a cohort
        that expires while QUEUED for its replica is shed typed before
        the wire. Cohorts never hedge — a cohort binds WHOLE to one
        replica by design (docs/protocol.md §9). With
        :meth:`enable_fair_queue` on, the cohort first takes ``n`` units
        (clamped to the gate's capacity) under its tenant's DRR flow."""
        n = len(payloads)
        deadline = current_deadline()
        with self._lock:
            self.stats["cohorts"] += 1
        gate = self._fair_acquire(n, deadline)
        try:
            return self._dispatch_batch_routed(payloads, n, deadline)
        finally:
            if gate is not None:
                gate.release(n)

    def _dispatch_batch_routed(self, payloads, n: int,
                               deadline: Optional[float]) -> list:
        attempts = 0
        while True:
            rep = self._route(weight=n)
            t0 = time.perf_counter()
            ok = False
            try:
                if self._acquire(rep, deadline, False) == "expired":
                    with self._lock:
                        self.stats["expired"] += n
                    raise DeadlineExpired(
                        f"service {self.name!r}: cohort deadline expired "
                        f"while queued for replica {rep.rid} — shed "
                        "before send")
                try:
                    if rep.state != REPLICA_ACTIVE \
                            and rep.state != REPLICA_DRAINING:
                        raise _ReplicaGone()
                    outs = rep.session.call_batch(payloads,
                                                  return_exceptions=True)
                finally:
                    rep.rlock.release()
                ok = True
                # cohort primaries refill the hedge budget too (earning is
                # unconditional — see RetryBudget.note_primary)
                if self.hedge_budget is not None:
                    self.hedge_budget.note_primary()
            except _ReplicaGone:
                attempts += 1
                with self._lock:
                    self.stats["rerouted"] += n
                if attempts > 32:
                    raise ServiceUnavailable(
                        f"service {self.name!r}: re-route budget exhausted")
                continue
            except DeadlineExpired:
                raise           # shed, not a replica failure (subclasses
                #                 ResponseTimeout — must precede it)
            except (ServiceCrashed, ResponseTimeout):
                if self._link_died(rep):
                    self._mark_dead(rep)
                raise
            finally:
                self._complete(rep, n, (time.perf_counter() - t0) * 1e3, ok)
            if self._link_died(rep):
                self._mark_dead(rep)
            return outs

    # -- observability -------------------------------------------------------
    def snapshot(self) -> List[Dict[str, object]]:
        """Deterministically ordered per-replica view (rid ascending) for
        supervisors and :func:`repro.runtime.elastic.plan_fleet_scaling`."""
        with self._lock:
            return [{"rid": r.rid,
                     "state": _REPLICA_STATE_NAMES[r.state],
                     "inflight": r.inflight,
                     "ewma_ms": None if r.ewma_ms is None
                     else round(r.ewma_ms, 3),
                     "served": r.served,
                     "crashes": r.crashes}
                    for r in self._replicas.values()]


# ---------------------------------------------------------------------------
# the fleet supervisor (self-healing control plane)
# ---------------------------------------------------------------------------

class FleetSupervisor:
    """Health-probing supervision loop over one service's
    :class:`ServiceFleet`: detects DEAD and wedged replicas, ejects
    EWMA-latency outliers, and actuates the pure planners'
    (:func:`repro.runtime.elastic.plan_outlier_ejection`,
    :func:`repro.runtime.elastic.plan_fleet_scaling`) step lists so
    steady-state capacity converges back to ``target`` ACTIVE replicas
    under continuous kill -9 (docs/protocol.md §9).

    One sweep =

    1. **probe** every ACTIVE replica, in seeded-shuffled order: grab its
       wire lock (bounded — a busy wire is NOT a failure, the replica is
       making progress) and exchange one tiny request. ANY response,
       including a remote typed error, proves the link + dispatch loop
       alive; a dead link or a probe timeout retires the replica (a
       replica that cannot answer a bounded probe cannot be driven — the
       timeout has already poisoned its session);
    2. **eject** latency outliers per ``plan_outlier_ejection`` (peer-
       median EWMA × ``eject_factor``, with warmup/population guards) by
       draining them under live traffic;
    3. **converge** per ``plan_fleet_scaling``: release dead replicas
       (trivially quiesced), respawn the deficit from the fleet's stored
       spawn spec as fresh proc-backed sessions — each with its own
       segment/domain/epoch, each membership change exactly one re-key —
       and drain any surplus.

    Decisions come from pure planners over an immutable snapshot, so a
    recorded trace (``record=True``) replays exactly: :meth:`replay`
    re-derives every sweep's plan from its recorded snapshot and fails
    loudly on the first divergence, mirroring :class:`ReplicaRouter`."""

    def __init__(self, gw: ServiceGateway, name: str, target: int, *,
                 interval: float = 0.25, probe_timeout: float = 1.0,
                 seed: int = 0x53555056, eject_factor: float = 4.0,
                 record: bool = False):
        if target < 1:
            raise ValueError("target must be >= 1")
        self.gw = gw
        self.name = name
        self.target = int(target)
        self.interval = float(interval)
        self.probe_timeout = float(probe_timeout)
        self.seed = seed
        self.eject_factor = float(eject_factor)
        self.record = record
        self._rng = random.Random(seed)
        self._probe_payload = np.zeros(1, np.int32)
        self._draining: set = set()     # ejected/surplus rids to re-drain
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.trace: List[Tuple] = []    # (sweep#, probes, snapshot, plan)
        self.stats = {"sweeps": 0, "probes": 0, "deaths_detected": 0,
                      "ejections": 0, "respawns": 0, "releases": 0,
                      "drains": 0}

    # -- probing ------------------------------------------------------------
    def _probe(self, rep: Replica) -> str:
        """One liveness probe. → ``"alive"`` | ``"dead"`` | ``"busy"``
        (wire lock held past the bound — not probed, not failed)."""
        if not rep.rlock.acquire(timeout=self.probe_timeout):
            return "busy"
        try:
            if rep.state != REPLICA_ACTIVE:
                return "busy"           # decided by another path meanwhile
            try:
                rep.session.request(self._probe_payload,
                                    timeout=self.probe_timeout)
            except (ServiceCrashed, ResponseTimeout):
                # link death, or a probe the replica could not answer
                # within the bound (the timeout has poisoned the session
                # either way — the replica can no longer be driven)
                return "dead"
            except Exception:
                # a remote TYPED error (the probe payload is not a valid
                # request for every handler) — the link answered: alive
                return "alive"
            return "alive"
        finally:
            rep.rlock.release()

    # -- one sweep ----------------------------------------------------------
    def sweep(self) -> list:
        """Run one supervision sweep; → the actuated plan_fleet_scaling
        step list (after probing and outlier ejection)."""
        from repro.runtime.elastic import (plan_fleet_scaling,
                                           plan_outlier_ejection)
        fleet = self.gw.fleet(self.name)
        sweep_no = self.stats["sweeps"]
        self.stats["sweeps"] += 1

        with fleet._lock:
            actives = [r for r in fleet._replicas.values()
                       if r.state == REPLICA_ACTIVE]
        self._rng.shuffle(actives)
        probes = []
        for rep in actives:
            verdict = self._probe(rep)
            self.stats["probes"] += 1
            probes.append((rep.rid, verdict))
            if verdict == "dead":
                self.stats["deaths_detected"] += 1
                fleet._mark_dead(rep)

        snap = fleet.snapshot()
        for op, rid in plan_outlier_ejection(snap,
                                             factor=self.eject_factor):
            assert op == "eject"
            self.stats["ejections"] += 1
            self._draining.add(rid)

        # re-drain anything decided earlier that has not quiesced yet
        for rid in sorted(self._draining):
            if self.gw.drain_replica(self.name, rid,
                                     timeout=self.probe_timeout):
                self._draining.discard(rid)
                self.stats["drains"] += 1

        snap = fleet.snapshot()
        plan = plan_fleet_scaling(snap, self.target)
        for step in plan:
            op, arg = step
            if op == "release":
                # a DEAD replica drains trivially; one re-key on release
                if self.gw.drain_replica(self.name, arg,
                                         timeout=self.probe_timeout):
                    self.stats["releases"] += 1
            elif op == "join":
                handler, transport, kwargs = fleet._spawn
                for _ in range(arg):
                    # a fresh proc-backed replica: own segment/domain/
                    # epoch; the join epoch-bumps the service exactly once
                    self.gw.register_replica(self.name, handler,
                                             transport=transport,
                                             transport_kwargs=kwargs)
                    self.stats["respawns"] += 1
            elif op == "drain":
                self._draining.add(arg)
        if self.record:
            self.trace.append((sweep_no, tuple(probes), tuple(
                tuple(sorted(r.items())) for r in snap), tuple(plan)))
        return plan

    def replay(self) -> None:
        """Re-derive every recorded sweep's plan from its recorded
        snapshot with the PURE planner; raise AssertionError on the first
        divergence (the supervision analogue of ReplicaRouter.replay)."""
        from repro.runtime.elastic import plan_fleet_scaling
        for sweep_no, _probes, snap_t, plan in self.trace:
            snap = [dict(items) for items in snap_t]
            fresh = tuple(plan_fleet_scaling(snap, self.target))
            if fresh != plan:
                raise AssertionError(
                    f"supervisor replay diverged at sweep {sweep_no}: "
                    f"recorded {plan}, replayed {fresh} "
                    f"(seed {self.seed:#x})")

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> "FleetSupervisor":
        if self._thread is not None:
            raise RuntimeError("supervisor already started")
        # pre-warm the planner import HERE: a cold import inside the first
        # sweep would stall the whole probe loop for its duration
        from repro.runtime import elastic as _elastic  # noqa: F401
        self._thread = threading.Thread(
            target=self._run, daemon=True,
            name=f"fleet-supervisor-{self.name}")
        self._thread.start()
        return self

    def _run(self) -> None:
        while not self._stop.wait(self.interval):
            try:
                self.sweep()
            # mpklint: disable=MPK105 reason=supervision loop must survive any single sweep failure; failures surface via stats/snapshot
            except Exception:
                pass

    def stop(self) -> None:
        self._stop.set()
        t = self._thread
        if t is not None:
            t.join(timeout=30)
            self._thread = None
