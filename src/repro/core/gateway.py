"""MPKLink service gateway: named services multiplexed over one transport.

The transports in :mod:`repro.core.transports` move bytes between ONE client
and ONE handler. The gateway is the routing/multiplexing layer the paper's
microservice story needs on top: a single co-located process exposes N
**named services**, each behind its own **protection domain**, and M
concurrent clients call them through one transport.

Wire format (one gateway envelope per transport message; the normative
spec lives in docs/protocol.md):

  request   [GW_MAGIC, service_id, client_id, token]  (4×u32 route words)
            + MPKLink frame (framing.build_frame) MAC-seeded with the
              (client, service) channel seed and per-channel sequence
  response  [GW_MAGIC, status, service_id, err_len]
            + status 0: response frame under the same channel seed/seq
            + status 1: msgpack {"type", "msg"} error blob (typed re-raise
              client-side — AccessViolation / FrameError / CapacityError)

Batch envelope (the pipelined data plane — N messages, ONE round trip,
ONE vectorized MAC pass per side):

  request   [GW_BATCH_MAGIC, service_id, client_id, n_items]
            + n_items frames concatenated row-wise, sequence numbers
              chan.seq .. chan.seq+n-1 (each frame is self-describing, so
              the server carves the concatenation with framing.split_frames
              and verifies all MACs in one framing.verify_batch pass)
  response  [GW_MAGIC, 2 (batch-ok), service_id, n_items]
            + per item: [GW_MAGIC, status, byte_len, 0] + body (status 0:
              response frame, sealed batch-wide in one framing.seal_batch
              pass; status 1: msgpack error blob, padded to 4B) — so one
              failed message stays a typed per-item error while the rest of
              the batch completes.
            Whole-batch failures (unknown service, no channel, desynced
            frame walk) use the plain single-message error envelope.

Isolation model (the paper's §V, finally with >2 endpoints):

* every service gets its own :class:`ProtectionDomain` in the gateway's
  shared :class:`KeyRegistry`; the service holds an RW key on it;
* a client must enroll with the gateway CA (key pair + proof of
  possession) and *open* a channel per service: the CA re-verifies the
  client certificate (and the service's allow-list) before issuing the
  client a capability key on that service's domain;
* the channel MAC seed = service-domain tag ⊕ epoch-mix ⊕ DH session key
  of (client, service) — so a frame built with service A's channel seed is
  rejected by service B's guard (FrameError), and a client holding no key
  for B is rejected at the capability check (AccessViolation). A foreign
  client can never read another service's region, only its own;
* revocation bumps the service-domain epoch: stale keys fail the PKRU
  check and stale frames fail the MAC — the analogue of flushing stale
  PKRU state from every thread that ever cached the key.

Dispatch runs on the per-session service threads of the underlying
transport, so N clients drive N concurrent request streams; per-channel
sequence numbers keep each stream's framing order independent. For the
mpklink transports the gateway shares its registry/CA with the transport,
putting link-level channel domains and service domains in ONE key table
(one software PKRU file per process, like the hardware).
"""
from __future__ import annotations

import itertools
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Set, Tuple, Union

import numpy as np

from repro.core import framing
from repro.core.ca import CertificateAuthority, enroll
from repro.core.domains import (AccessViolation, DomainKey, KeyRegistry,
                                ProtectionDomain, RW, READ, WRITE, mac_seed)
from repro.core.transports import (HandlerCrash, MPKLinkTransport,
                                   ResponseTimeout, ServiceCrashed,
                                   ServiceUnavailable, Transport,
                                   TransportError, _pack_error, _raise_remote,
                                   fast_mac)

Handler = Callable[[np.ndarray], np.ndarray]

GW_MAGIC = 0x4D504B47               # "MPKG"
GW_BATCH_MAGIC = 0x4D504B42         # "MPKB" — batch request envelope
_ROUTE_BYTES = 16                   # 4 × u32 route words
_OK, _ERR, _BOK = 0, 1, 2           # _BOK: batch response follows


def _route(a: int, b: int, c: int) -> np.ndarray:
    return np.array([GW_MAGIC, a, b, c], "<u4").view(np.uint8)


def _batch_route(sid: int, cid: int, n: int) -> np.ndarray:
    return np.array([GW_BATCH_MAGIC, sid, cid, n], "<u4").view(np.uint8)


def _as_frameable(arr: np.ndarray) -> np.ndarray:
    """Handlers may return any dtype/rank; frame unsupported ones as raw
    bytes. This must never fail: response sealing happens AFTER the
    channel sequence has advanced, so a sealing error would desync the
    channel permanently instead of surfacing as a typed per-item error."""
    arr = np.ascontiguousarray(arr)
    if np.dtype(arr.dtype) not in framing._DTYPE_CODES or arr.ndim > 4:
        arr = arr.view(np.uint8).reshape(-1)
    return arr


class ServiceHealth:
    """Per-service failure tracking + circuit breaker.

    States: ``closed`` (healthy) → ``open`` after ``threshold`` consecutive
    handler failures (requests are shed with a typed
    :class:`ServiceUnavailable` instead of hanging) → ``half_open`` after
    ``probe_after`` sheds (ONE probe request is let through; success closes
    the circuit, failure re-opens it). Counting sheds instead of wall-clock
    keeps chaos runs exactly replayable from a seed."""

    def __init__(self, threshold: int = 3, probe_after: int = 8):
        self.threshold = threshold
        self.probe_after = probe_after
        self.state = "closed"
        self.consecutive_failures = 0
        self.failures = 0               # lifetime handler failures
        self.crashes = 0                # lifetime handler-thread crashes
        self.sheds = 0                  # lifetime circuit rejections
        self.restarts = 0               # lifetime handler restarts
        self._shed_run = 0              # sheds since the circuit last opened
        self._lock = threading.Lock()

    def admit(self, service: str):
        """Gate a request. Raises ServiceUnavailable while the circuit is
        open (except for the half-open probe)."""
        with self._lock:
            if self.state == "closed":
                return
            if self.state == "open":
                if self._shed_run >= self.probe_after:
                    self.state = "half_open"    # this request is the probe
                    return
                self._shed_run += 1
                self.sheds += 1
                raise ServiceUnavailable(
                    f"service {service!r} circuit open "
                    f"({self.consecutive_failures} consecutive failures); "
                    f"shedding load ({self._shed_run}/{self.probe_after} "
                    f"before probe)")
            # half_open: another caller's probe is in flight; let it race —
            # both outcomes resolve the state below

    def success(self):
        with self._lock:
            self.consecutive_failures = 0
            self.state = "closed"
            self._shed_run = 0

    def failure(self, crashed: bool = False) -> bool:
        """Record a handler failure. → True when the breaker trips (the
        gateway then restarts the service if it can, else opens the
        circuit)."""
        with self._lock:
            self.failures += 1
            self.crashes += int(crashed)
            self.consecutive_failures += 1
            if self.state == "half_open":
                self.state = "open"
                self._shed_run = 0
                return True
            if self.state == "closed" \
                    and self.consecutive_failures >= self.threshold:
                return True
            return False

    def trip(self):
        with self._lock:
            self.state = "open"
            self._shed_run = 0

    def reset(self):
        with self._lock:
            self.state = "closed"
            self.consecutive_failures = 0
            self._shed_run = 0
            self.restarts += 1

    def snapshot(self) -> Dict[str, object]:
        with self._lock:
            return {"state": self.state,
                    "consecutive_failures": self.consecutive_failures,
                    "failures": self.failures, "crashes": self.crashes,
                    "sheds": self.sheds, "restarts": self.restarts}


@dataclass
class _Service:
    sid: int
    name: str
    handler: Handler
    domain: ProtectionDomain
    server_key: DomainKey
    allow: Optional[Set[str]]       # client-name allow-list; None = any cert
    factory: Optional[Callable[[], Handler]] = None   # restart hook
    # optional native batch entry point: takes a list of payloads, returns a
    # same-length list of responses (EngineService.handler_batch feeds the
    # continuous-batching decode loop through this)
    batch_handler: Optional[Callable] = None
    health: ServiceHealth = field(default_factory=ServiceHealth)
    # cid → (idempotency token → response payload): a retried request whose
    # original DID execute is answered from here, never re-executed. The
    # window is per-client so one client's traffic can never evict another
    # client's pending-retry token (a client is serial: its own window only
    # needs to cover its own last few calls)
    done: "OrderedDict[int, OrderedDict[int, np.ndarray]]" = \
        field(default_factory=OrderedDict)
    done_lock: threading.Lock = field(default_factory=threading.Lock)


_DONE_TOKENS = 16                   # dedup window depth per client
_DONE_CLIENTS = 256                 # client buckets kept per service (LRU)


@dataclass
class Channel:
    """One (client, service) grant: capability key + MAC seed + sequences.

    The two sequence counters advance in lock-step because the transport
    session is strictly request/response. If the transport fails between the
    server's increment and the client's (e.g. a response timeout), the
    channel is desynced — but the transport session poisons itself on
    timeout, so every later call fails loudly instead of mis-parsing;
    recovery is a fresh client."""
    cid: int
    sid: int
    service: str
    seed: int
    client_key: DomainKey
    seq: int = 0                    # client-side next sequence number
    server_seq: int = 0             # server-side expected sequence number
    slock: threading.Lock = field(default_factory=threading.Lock)


class ServiceGateway:
    """Dispatch table of named services over a single transport."""

    def __init__(self, transport: Union[str, type] = "mpklink_opt", *,
                 max_keys: int = 256, mac_impl: Callable = fast_mac,
                 transport_kwargs: Optional[dict] = None):
        self.registry = KeyRegistry(max_keys=max_keys, seed=0x6A7E)
        self.ca = CertificateAuthority(self.registry)
        self._mac = mac_impl
        # batch-path MAC: None selects framing's fused vectorized pass
        # (bit-identical to fast_mac); a custom impl is honored per frame
        # so batched and single exchanges can never disagree
        self._batch_mac = None if mac_impl is fast_mac else mac_impl
        self._services: Dict[str, _Service] = {}
        self._by_sid: Dict[int, _Service] = {}
        self._channels: Dict[Tuple[int, int], Channel] = {}
        self._glock = threading.Lock()
        self._sid_counter = itertools.count(1)
        self._cid_counter = itertools.count(1)
        self.stats = {"requests": 0, "responses": 0, "macs_verified": 0,
                      "rejected": 0, "deduped": 0, "sheds": 0,
                      "restarts": 0, "crashes": 0}

        if isinstance(transport, str):
            from repro.core import TRANSPORTS
            transport = TRANSPORTS[transport]
        kwargs = dict(transport_kwargs or {})
        if isinstance(transport, type) and issubclass(transport, MPKLinkTransport):
            # one key table for link channels AND service domains
            kwargs.setdefault("registry", self.registry)
            kwargs.setdefault("ca", self.ca)
        self.transport: Transport = transport(self._dispatch, **kwargs)

    # -- service lifecycle --------------------------------------------------
    def register_service(self, name: str, handler: Handler,
                         allow: Optional[Set[str]] = None, *,
                         factory: Optional[Callable[[], Handler]] = None,
                         batch_handler: Optional[Callable] = None,
                         failure_threshold: int = 3,
                         probe_after: int = 8) -> int:
        """Enroll a service with the CA and give it its own protection
        domain. ``allow`` restricts which client names may open channels.
        ``factory`` makes the service self-healing: after
        ``failure_threshold`` consecutive handler failures the gateway
        replaces the handler with ``factory()``, bumps the domain epoch and
        lets still-certified clients re-key transparently. Without a
        factory the circuit opens instead and requests are shed with
        :class:`ServiceUnavailable` until a probe succeeds.
        ``batch_handler`` (list of payloads → same-length list of
        responses) lets a batch envelope execute as ONE native call —
        EngineService passes its handler_batch here so a batched prompt
        submission joins the decode slot grid as a single cohort."""
        with self._glock:
            if name in self._services:
                raise ValueError(f"service {name!r} already registered")
            enroll(self.ca, name)
            dom = self.registry.allocate_domain(f"svc:{name}")
            svc = _Service(next(self._sid_counter), name, handler, dom,
                           self.registry.issue_key(dom, RW),
                           set(allow) if allow is not None else None,
                           factory=factory, batch_handler=batch_handler,
                           health=ServiceHealth(failure_threshold,
                                                probe_after))
            self._services[name] = svc
            self._by_sid[svc.sid] = svc
            return svc.sid

    def restart_service(self, name: str) -> None:
        """Self-healing restart: swap in a fresh handler (via the service's
        factory, when present), bump the service-domain epoch so every
        outstanding key/frame on the domain goes stale (the PKRU-flush
        analogue), and re-key the service. Still-certified clients re-key
        transparently on their next call."""
        svc = self._services[name]
        with self._glock:
            if svc.factory is not None:
                svc.handler = svc.factory()
            self.registry.revoke(svc.server_key)          # epoch bump
            svc.server_key = self.registry.issue_key(svc.domain, RW)
            self.stats["restarts"] += 1
        svc.health.reset()

    def health(self) -> Dict[str, Dict[str, object]]:
        """Per-service health snapshot (for supervisors/monitoring)."""
        with self._glock:
            services = list(self._services.values())
        return {s.name: s.health.snapshot() for s in services}

    def start(self) -> "ServiceGateway":
        self.transport.start()
        return self

    def close(self):
        self.transport.close()

    # -- client lifecycle ---------------------------------------------------
    def connect(self, client_name: str, *, retries: int = 0,
                backoff: float = 0.005) -> "GatewayClient":
        return GatewayClient(self, client_name, retries=retries,
                             backoff=backoff)

    def _open_channel(self, client: "GatewayClient", service: str) -> Channel:
        """Control plane: CA-checked issue of a client key on the service's
        domain + derivation of the per-(client, service) MAC seed."""
        svc = self._services.get(service)
        if svc is None:
            raise AccessViolation(f"unknown service {service!r}")
        if svc.allow is not None and client.name not in svc.allow:
            raise AccessViolation(
                f"client {client.name!r} not authorized for service {service!r}")
        rec = self.ca._services.get(client.name)
        if rec is None or not rec.verified or not self.ca.verify_cert(rec):
            raise AccessViolation(
                f"client {client.name!r} failed certificate check")
        key = self.registry.issue_key(svc.domain, RW)
        seed = mac_seed(svc.domain, self.registry.epoch(svc.domain)) \
            ^ self.ca.session_seed(client._kp.private, service)
        chan = Channel(client.cid, svc.sid, service, seed, key)
        with self._glock:
            old = self._channels.get((client.cid, svc.sid))
            self._channels[(client.cid, svc.sid)] = chan
        if old is not None:             # re-key: retire the replaced grant
            self.registry.retire(old.client_key)
        return chan

    def revoke(self, client: "GatewayClient", service: Optional[str] = None):
        """Revoke a client's channel key(s). Bumps the service-domain epoch,
        so every stale key/frame on that domain fails the guard afterwards
        (other clients must re-open — the PKRU-flush analogue)."""
        with self._glock:
            doomed = [(k, ch) for k, ch in self._channels.items()
                      if k[0] == client.cid
                      and (service is None or ch.service == service)]
        for k, ch in doomed:
            self.registry.revoke(ch.client_key)
            with self._glock:
                self._channels.pop(k, None)
            client._channels.pop(ch.service, None)
            # the epoch bump stales every key on the domain, including the
            # service's own — the co-located service re-syncs immediately
            # (clients must re-open through the CA; GatewayClient.call does
            # this transparently for still-certified clients)
            svc = self._by_sid[ch.sid]
            svc.server_key = self.registry.issue_key(svc.domain, RW)

    def _release_client(self, client: "GatewayClient"):
        """Graceful disconnect: retire the client's keys (no epoch bump —
        closing is not a security event) and drop its routing entries, so a
        closed client's cid can never dispatch again."""
        with self._glock:
            doomed = [(k, ch) for k, ch in self._channels.items()
                      if k[0] == client.cid]
            for k, ch in doomed:
                self._channels.pop(k, None)
        for _, ch in doomed:
            self.registry.retire(ch.client_key)

    # -- data plane (runs on the transport's per-session service threads) ----
    def _bump(self, *stats: str):
        with self._glock:
            for s in stats:
                self.stats[s] += 1

    def _bump_n(self, stat: str, n: int):
        with self._glock:
            self.stats[stat] += n

    def _service_failure(self, svc: _Service, crashed: bool = False):
        """Record a handler failure; when the breaker trips, self-heal by
        restarting (factory available) or open the circuit and shed."""
        if crashed:
            self._bump("crashes")
        if svc.health.failure(crashed=crashed):
            if svc.factory is not None:
                self.restart_service(svc.name)
            else:
                svc.health.trip()

    def note_wire_crash(self, sid: int):
        """A transport-level crash was observed for a request routed to
        ``sid`` before it reached dispatch (fault fabrics call this so the
        gateway's health view includes wire-level kills)."""
        svc = self._by_sid.get(sid)
        if svc is not None:
            self._service_failure(svc, crashed=True)

    def _invoke(self, svc: _Service, chan: Channel, cid: int, token: int,
                fseq: int, payload: np.ndarray) -> np.ndarray:
        """Run the service handler behind the circuit breaker + dedup cache.
        Returns the response payload; updates ``chan.server_seq``."""
        if token:
            with svc.done_lock:
                bucket = svc.done.get(cid)
                cached = bucket.get(token) if bucket is not None else None
            if cached is not None:
                # the original executed but its response was lost in flight:
                # answer from the dedup window, never re-execute. The window
                # only ever moves FORWARD — a replayed old envelope gets its
                # (already-delivered) answer but cannot rewind the channel
                # and desync legitimate in-order traffic
                self._bump("deduped")
                chan.server_seq = max(chan.server_seq,
                                      (fseq + 1) & 0xFFFFFFFF)
                return cached
        if fseq != chan.server_seq:
            raise framing.FrameError(
                f"sequence mismatch (got {fseq}, want {chan.server_seq})")
        svc.health.admit(svc.name)      # circuit breaker: shed, don't hang
        try:
            resp = _as_frameable(np.asarray(svc.handler(payload)))
        except HandlerCrash:
            # kills the transport service thread (by design) — record it,
            # then let it propagate past the per-request except nets
            self._service_failure(svc, crashed=True)
            raise
        except Exception:
            self._service_failure(svc)
            raise
        svc.health.success()
        if token:
            with svc.done_lock:
                bucket = svc.done.setdefault(cid, OrderedDict())
                bucket[token] = resp
                while len(bucket) > _DONE_TOKENS:
                    bucket.popitem(last=False)
                svc.done.move_to_end(cid)
                while len(svc.done) > _DONE_CLIENTS:
                    svc.done.popitem(last=False)
        chan.server_seq = (fseq + 1) & 0xFFFFFFFF
        return resp

    def _invoke_batch(self, svc: _Service, chan: Channel, parsed) -> list:
        """Execute a verified batch. ``parsed`` holds payload arrays with
        FrameError objects in failed positions (verify_batch strict=False);
        those pass through untouched. Every consumed item advances
        ``chan.server_seq`` positionally — success or failure — matching
        the client's batch-wide sequence advance (unlike the single path,
        where a failed exchange advances neither side). Health/circuit
        accounting: per item on the loop path, once per batch on the
        native ``batch_handler`` path."""
        results = list(parsed)
        good = [(i, p) for i, p in enumerate(parsed)
                if not isinstance(p, framing.FrameError)]
        if svc.batch_handler is not None and good:
            try:
                svc.health.admit(svc.name)
                outs = svc.batch_handler([p for _, p in good])
                if len(outs) != len(good):
                    raise TransportError(
                        f"batch handler returned {len(outs)} responses "
                        f"for {len(good)} requests")
                svc.health.success()
                for (i, _), o in zip(good, outs):
                    results[i] = _as_frameable(np.asarray(o))
            except HandlerCrash:
                self._service_failure(svc, crashed=True)
                raise
            except ServiceUnavailable as e:     # circuit shed, not a
                self._bump("sheds")             # handler failure
                for i, _ in good:
                    results[i] = e
            except Exception as e:
                self._service_failure(svc)
                for i, _ in good:
                    results[i] = e
        else:
            for i, p in good:
                try:
                    svc.health.admit(svc.name)
                    resp = _as_frameable(np.asarray(svc.handler(p)))
                    svc.health.success()
                    results[i] = resp
                except HandlerCrash:
                    self._service_failure(svc, crashed=True)
                    raise
                except ServiceUnavailable as e:
                    self._bump("sheds")
                    results[i] = e
                except Exception as e:
                    self._service_failure(svc)
                    results[i] = e
        chan.server_seq = (chan.server_seq + len(parsed)) & 0xFFFFFFFF
        return results

    def _dispatch_batch(self, raw: np.ndarray) -> np.ndarray:
        """Serve one batch envelope: route/capability checks once, frame
        walk (split_frames), ONE vectorized MAC verify, per-item execution,
        ONE vectorized response seal. Per-item failures come back as typed
        error blobs in that item's slot; whole-batch failures use the
        single-message error envelope."""
        sid = 0
        try:
            route = raw[:_ROUTE_BYTES].view("<u4")
            sid, cid, n_items = int(route[1]), int(route[2]), int(route[3])
            svc = self._by_sid.get(sid)
            if svc is None:
                raise AccessViolation(f"unknown service id {sid}")
            chan = self._channels.get((cid, sid))
            if chan is None:
                raise AccessViolation(
                    f"client {cid} holds no key for service {svc.name!r}")
            with chan.slock:
                self.registry.check(chan.client_key, WRITE)
                self.registry.check(svc.server_key, READ)
                body = raw[_ROUTE_BYTES:]
                if body.nbytes == 0 or body.nbytes % (framing.LANES * 4):
                    raise framing.FrameError(
                        "malformed batch — truncated or not lane-aligned")
                frames = framing.split_frames(
                    body.view("<u4").reshape(-1, framing.LANES))
                if len(frames) != n_items:
                    raise framing.FrameError(
                        f"batch declares {n_items} frames, found {len(frames)}")
                start = chan.server_seq
                seqs = [(start + i) & 0xFFFFFFFF for i in range(len(frames))]
                parsed = framing.verify_batch(frames, seed=chan.seed,
                                              seqs=seqs, strict=False,
                                              mac_impl=self._batch_mac)
                n_ok = sum(1 for p in parsed
                           if not isinstance(p, framing.FrameError))
                self._bump_n("requests", len(frames))
                self._bump_n("macs_verified", n_ok)
                self._bump_n("rejected", len(frames) - n_ok)
                results = self._invoke_batch(svc, chan, parsed)
                try:
                    self.registry.check(svc.server_key, WRITE)
                    self.registry.check(chan.client_key, READ)
                except AccessViolation as e:
                    # the epoch moved UNDER this batch (e.g. its own
                    # failures tripped a self-healing restart). Handlers
                    # already ran, so the client must NOT transparently
                    # re-key and resend — tag the rejection so call_batch's
                    # stale-epoch retry stands down (batches carry no
                    # idempotency token; a resend would double-execute)
                    raise AccessViolation(f"post-execution: {e}") from None
                ok_idx = [i for i, r in enumerate(results)
                          if not isinstance(r, BaseException)]
                rframes = framing.seal_batch(
                    [results[i] for i in ok_idx], seed=chan.seed,
                    seqs=[seqs[i] for i in ok_idx],
                    mac_impl=self._batch_mac) if ok_idx else []
            parts = [_route(_BOK, sid, len(results))]
            rit = iter(rframes)
            for r in results:
                if isinstance(r, BaseException):
                    blob = _pack_error(r)
                    pad = (-len(blob)) % 4
                    parts.append(_route(_ERR, len(blob), 0))
                    parts.append(np.frombuffer(blob + b"\0" * pad, np.uint8))
                else:
                    rf = next(rit).reshape(-1).view(np.uint8)
                    parts.append(_route(_OK, rf.nbytes, 0))
                    parts.append(rf)
            self._bump_n("responses", len(ok_idx))
            self._bump_n("rejected",
                         len(results) - len(ok_idx)
                         - sum(1 for p in parsed
                               if isinstance(p, framing.FrameError)))
            return np.concatenate(parts)
        except Exception as e:
            self._bump(*(("rejected", "sheds")
                         if isinstance(e, ServiceUnavailable)
                         else ("rejected",)))
            blob = _pack_error(e)
            return np.concatenate(
                [_route(_ERR, sid, len(blob)), np.frombuffer(blob, np.uint8)])

    def _dispatch(self, req: np.ndarray) -> np.ndarray:
        sid = 0
        try:
            raw = np.ascontiguousarray(np.asarray(req)) \
                .view(np.uint8).reshape(-1)
            if raw.nbytes < _ROUTE_BYTES:
                raise framing.FrameError("short gateway envelope")
            route = raw[:_ROUTE_BYTES].view("<u4")
            if int(route[0]) == GW_BATCH_MAGIC:
                return self._dispatch_batch(raw)
            if int(route[0]) != GW_MAGIC:
                raise framing.FrameError("not a gateway envelope (bad magic)")
            sid, cid, token = int(route[1]), int(route[2]), int(route[3])
            svc = self._by_sid.get(sid)
            if svc is None:
                raise AccessViolation(f"unknown service id {sid}")
            chan = self._channels.get((cid, sid))
            if chan is None:
                raise AccessViolation(
                    f"client {cid} holds no key for service {svc.name!r}")
            with chan.slock:
                # PKRU staging checks: the client may write the request
                # region, the service may read it (revocation/epoch enforced)
                self.registry.check(chan.client_key, WRITE)
                self.registry.check(svc.server_key, READ)
                body = raw[_ROUTE_BYTES:]
                if body.nbytes == 0 or body.nbytes % (framing.LANES * 4):
                    raise framing.FrameError(
                        "malformed frame — truncated or not lane-aligned")
                frame = body.view("<u4").reshape(-1, framing.LANES)
                # MAC/seed/header verification first (expect_seq=None: the
                # sequence check is downstream so an idempotent retry of an
                # already-executed request can be answered from the dedup
                # window); the unverified sequence word is read afterwards
                payload = framing.parse_frame(
                    frame, seed=chan.seed, expect_seq=None,
                    mac_impl=self._mac)
                fseq = int(frame[0][2])
                self._bump("requests", "macs_verified")
                resp = self._invoke(svc, chan, cid, token, fseq, payload)
                self.registry.check(svc.server_key, WRITE)
                self.registry.check(chan.client_key, READ)
                rframe = framing.build_frame(
                    resp, seed=chan.seed, seq=fseq, mac_impl=self._mac)
            self._bump("responses")
            return np.concatenate(
                [_route(_OK, sid, 0), rframe.reshape(-1).view(np.uint8)])
        except Exception as e:
            self._bump(*(("rejected", "sheds")
                         if isinstance(e, ServiceUnavailable)
                         else ("rejected",)))
            blob = _pack_error(e)
            return np.concatenate(
                [_route(_ERR, sid, len(blob)), np.frombuffer(blob, np.uint8)])


class GatewayClient:
    """One CA-enrolled client: its own transport session plus per-service
    channels. ``call()`` is thread-safe but serial per client — open one
    client per concurrent caller (that's the session model).

    Resilience: every call carries an idempotency token; with ``retries``
    > 0 a call that fails with a *liveness* error (session crash/response
    timeout — never a security rejection) heals the transport session,
    re-keys the channel and resends the SAME token, so a retried request
    whose original did execute is answered from the gateway's dedup window
    instead of running twice."""

    def __init__(self, gw: ServiceGateway, name: str, *, retries: int = 0,
                 backoff: float = 0.005):
        self.gw = gw
        self.name = name
        self.retries = retries
        self.backoff = backoff
        self._kp, _ = enroll(gw.ca, name)
        self.cid = next(gw._cid_counter)
        self._session = gw.transport.connect(f"gw:{name}")
        self._channels: Dict[str, Channel] = {}
        self._lock = threading.Lock()
        self._tokens = itertools.count(1)   # 0 = "no token" on the wire
        self.macs_verified = 0          # response MACs this client checked
        self.retried = 0                # liveness retries this client made

    def open(self, service: str) -> Channel:
        with self._lock:
            chan = self._channels.get(service)
            if chan is None:
                chan = self.gw._open_channel(self, service)
                self._channels[service] = chan
            return chan

    def reopen(self, service: str) -> Channel:
        """Drop the cached channel and open a fresh one (new key at the
        current epoch) — the recovery path after a domain-epoch bump."""
        with self._lock:
            self._channels.pop(service, None)
        return self.open(service)

    def heal(self, service: Optional[str] = None):
        """Recover from a dead/poisoned transport session: reconnect the
        session and (optionally) re-open the service channel so both sides
        restart from a fresh key + sequence 0."""
        s = self._session
        if s._crashed or s._closed or s._poisoned:
            self._reconnect()
        if service is not None:
            self.reopen(service)

    def _reconnect(self):
        try:
            self._session.close()
        except Exception:
            pass
        self._session = self.gw.transport.connect(f"gw:{self.name}")

    def call(self, service: str, payload: np.ndarray) -> np.ndarray:
        payload = np.asarray(payload)
        token = next(self._tokens) & 0xFFFFFFFF or next(self._tokens)
        attempts = 0
        rekeyed = False
        while True:
            chan = self.open(service)
            try:
                return self._call_once(chan, payload, token)
            except AccessViolation as e:
                # someone's revocation (or a self-healing restart) bumped
                # the service-domain epoch; a still-certified client just
                # re-keys through the CA and retries once per attempt (a
                # banned client fails the certificate check in reopen())
                if "stale key epoch" not in str(e) or rekeyed:
                    raise
                rekeyed = True
                self.reopen(service)
            except ServiceUnavailable:
                attempts += 1
                if attempts > self.retries:
                    raise
                self.retried += 1
                time.sleep(self.backoff * attempts)
            except (ServiceCrashed, ResponseTimeout):
                attempts += 1
                if attempts > self.retries:
                    raise
                self.retried += 1
                rekeyed = False
                self.heal(service)      # fresh session + channel, same token
                time.sleep(self.backoff * attempts)

    def call_batch(self, service: str, payloads,
                   return_exceptions: bool = False) -> list:
        """Pipelined batch call: N messages in ONE gateway envelope / ONE
        transport round trip, sealed client-side and verified server-side
        in one vectorized MAC pass each. Returns responses in payload
        order; a failed message surfaces as its typed exception (in-place
        with ``return_exceptions``, else the first one is raised after the
        batch has drained). Batch calls carry no idempotency token and are
        not auto-retried — a liveness failure (crash/timeout) poisons the
        session as usual and ``heal()`` recovers; whole-batch security
        rejections advance neither side's sequence. Like ``call()``, a
        stale-key-epoch rejection (revocation / self-healing restart)
        re-keys through the CA transparently and retries once."""
        payloads = [np.asarray(p) for p in payloads]
        if not payloads:
            return []
        rekeyed = False
        while True:
            chan = self.open(service)
            try:
                return self._call_batch_once(chan, payloads,
                                             return_exceptions)
            except AccessViolation as e:
                # transparently re-key ONLY for pre-execution rejections:
                # a "post-execution" tag means the batch already ran under
                # the old epoch — resending it would double-execute
                if "stale key epoch" not in str(e) or rekeyed \
                        or "post-execution" in str(e):
                    raise
                rekeyed = True
                self.reopen(service)

    def _call_batch_once(self, chan: Channel, payloads,
                         return_exceptions: bool) -> list:
        with self._lock:
            frames = framing.seal_batch(payloads, seed=chan.seed,
                                        start_seq=chan.seq,
                                        mac_impl=self.gw._batch_mac)
            env = np.concatenate(
                [_batch_route(chan.sid, self.cid, len(frames))]
                + [f.reshape(-1).view(np.uint8) for f in frames])
            resp = np.ascontiguousarray(np.asarray(self._session.request(env))) \
                .view(np.uint8).reshape(-1)
            if resp.nbytes < _ROUTE_BYTES:
                raise TransportError("malformed gateway response (truncated)")
            route = resp[:_ROUTE_BYTES].view("<u4")
            if int(route[0]) != GW_MAGIC:
                raise TransportError("malformed gateway response (bad magic)")
            if int(route[1]) == _ERR:       # whole-batch failure: no item
                _raise_remote(resp[_ROUTE_BYTES:         # consumed a seq
                                   _ROUTE_BYTES + int(route[3])].tobytes())
            if int(route[1]) != _BOK or int(route[3]) != len(frames):
                raise TransportError("malformed gateway batch response")
            start, ofs = chan.seq, _ROUTE_BYTES
            results: list = [None] * len(frames)
            ok_frames, ok_pos = [], []
            for i in range(len(frames)):
                if resp.nbytes < ofs + _ROUTE_BYTES:
                    raise TransportError("truncated gateway batch response")
                ih = resp[ofs: ofs + _ROUTE_BYTES].view("<u4")
                if int(ih[0]) != GW_MAGIC:
                    raise TransportError("desynced gateway batch response")
                status, nb = int(ih[1]), int(ih[2])
                body = resp[ofs + _ROUTE_BYTES: ofs + _ROUTE_BYTES + nb]
                ofs += _ROUTE_BYTES + nb + ((-nb) % 4)
                if status == _OK:
                    ok_frames.append(body.view("<u4")
                                     .reshape(-1, framing.LANES))
                    ok_pos.append(i)
                else:
                    try:
                        _raise_remote(body.tobytes())
                    except Exception as e:
                        results[i] = e
            if ok_frames:                   # ONE vectorized verify pass
                verified = framing.verify_batch(
                    ok_frames, seed=chan.seed,
                    seqs=[start + i for i in ok_pos], strict=False,
                    mac_impl=self.gw._batch_mac)
                for p, v in zip(ok_pos, verified):
                    results[p] = v
                    if not isinstance(v, framing.FrameError):
                        self.macs_verified += 1
            chan.seq += len(frames)         # every item consumed a sequence
        if not return_exceptions:
            for r in results:
                if isinstance(r, BaseException):
                    raise r
        return results

    def _call_once(self, chan: Channel, payload: np.ndarray,
                   token: int = 0) -> np.ndarray:
        with self._lock:
            frame = framing.build_frame(payload, seed=chan.seed,
                                        seq=chan.seq, mac_impl=self.gw._mac)
            env = np.concatenate([_route(chan.sid, self.cid, token),
                                  frame.reshape(-1).view(np.uint8)])
            resp = np.ascontiguousarray(np.asarray(self._session.request(env))) \
                .view(np.uint8).reshape(-1)
            if resp.nbytes < _ROUTE_BYTES:
                raise TransportError("malformed gateway response (truncated)")
            route = resp[:_ROUTE_BYTES].view("<u4")
            if int(route[0]) != GW_MAGIC:
                raise TransportError("malformed gateway response (bad magic)")
            if int(route[1]) != _OK:
                _raise_remote(resp[_ROUTE_BYTES:
                                   _ROUTE_BYTES + int(route[3])].tobytes())
            rframe = resp[_ROUTE_BYTES:].view("<u4") \
                .reshape(-1, framing.LANES)
            out = framing.parse_frame(rframe, seed=chan.seed,
                                      expect_seq=chan.seq,
                                      mac_impl=self.gw._mac)
            chan.seq += 1
            self.macs_verified += 1
            return out

    def close(self):
        self.gw._release_client(self)
        with self._lock:
            self._channels.clear()
        self._session.close()
