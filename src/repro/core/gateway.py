"""MPKLink service gateway: named services multiplexed over one transport.

The transports in :mod:`repro.core.transports` move bytes between ONE client
and ONE handler. The gateway is the routing/multiplexing layer the paper's
microservice story needs on top: a single co-located process exposes N
**named services**, each behind its own **protection domain**, and M
concurrent clients call them through one transport.

Wire format (one gateway envelope per transport message):

  request   [GW_MAGIC, service_id, client_id, 0]  (4×u32 route words)
            + MPKLink frame (framing.build_frame) MAC-seeded with the
              (client, service) channel seed and per-channel sequence
  response  [GW_MAGIC, status, service_id, err_len]
            + status 0: response frame under the same channel seed/seq
            + status 1: msgpack {"type", "msg"} error blob (typed re-raise
              client-side — AccessViolation / FrameError / CapacityError)

Isolation model (the paper's §V, finally with >2 endpoints):

* every service gets its own :class:`ProtectionDomain` in the gateway's
  shared :class:`KeyRegistry`; the service holds an RW key on it;
* a client must enroll with the gateway CA (key pair + proof of
  possession) and *open* a channel per service: the CA re-verifies the
  client certificate (and the service's allow-list) before issuing the
  client a capability key on that service's domain;
* the channel MAC seed = service-domain tag ⊕ epoch-mix ⊕ DH session key
  of (client, service) — so a frame built with service A's channel seed is
  rejected by service B's guard (FrameError), and a client holding no key
  for B is rejected at the capability check (AccessViolation). A foreign
  client can never read another service's region, only its own;
* revocation bumps the service-domain epoch: stale keys fail the PKRU
  check and stale frames fail the MAC — the analogue of flushing stale
  PKRU state from every thread that ever cached the key.

Dispatch runs on the per-session service threads of the underlying
transport, so N clients drive N concurrent request streams; per-channel
sequence numbers keep each stream's framing order independent. For the
mpklink transports the gateway shares its registry/CA with the transport,
putting link-level channel domains and service domains in ONE key table
(one software PKRU file per process, like the hardware).
"""
from __future__ import annotations

import itertools
import threading
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Set, Tuple, Union

import numpy as np

from repro.core import framing
from repro.core.ca import CertificateAuthority, enroll
from repro.core.domains import (AccessViolation, DomainKey, KeyRegistry,
                                ProtectionDomain, RW, READ, WRITE, mac_seed)
from repro.core.transports import (MPKLinkTransport, Transport, TransportError,
                                   _pack_error, _raise_remote, fast_mac)

Handler = Callable[[np.ndarray], np.ndarray]

GW_MAGIC = 0x4D504B47               # "MPKG"
_ROUTE_BYTES = 16                   # 4 × u32 route words
_OK, _ERR = 0, 1


def _route(a: int, b: int, c: int) -> np.ndarray:
    return np.array([GW_MAGIC, a, b, c], "<u4").view(np.uint8)


def _as_frameable(arr: np.ndarray) -> np.ndarray:
    """Handlers may return any dtype; frame unsupported ones as raw bytes."""
    arr = np.ascontiguousarray(arr)
    if np.dtype(arr.dtype) not in framing._DTYPE_CODES:
        arr = arr.view(np.uint8).reshape(-1)
    return arr


@dataclass
class _Service:
    sid: int
    name: str
    handler: Handler
    domain: ProtectionDomain
    server_key: DomainKey
    allow: Optional[Set[str]]       # client-name allow-list; None = any cert


@dataclass
class Channel:
    """One (client, service) grant: capability key + MAC seed + sequences.

    The two sequence counters advance in lock-step because the transport
    session is strictly request/response. If the transport fails between the
    server's increment and the client's (e.g. a response timeout), the
    channel is desynced — but the transport session poisons itself on
    timeout, so every later call fails loudly instead of mis-parsing;
    recovery is a fresh client."""
    cid: int
    sid: int
    service: str
    seed: int
    client_key: DomainKey
    seq: int = 0                    # client-side next sequence number
    server_seq: int = 0             # server-side expected sequence number
    slock: threading.Lock = field(default_factory=threading.Lock)


class ServiceGateway:
    """Dispatch table of named services over a single transport."""

    def __init__(self, transport: Union[str, type] = "mpklink_opt", *,
                 max_keys: int = 256, mac_impl: Callable = fast_mac,
                 transport_kwargs: Optional[dict] = None):
        self.registry = KeyRegistry(max_keys=max_keys, seed=0x6A7E)
        self.ca = CertificateAuthority(self.registry)
        self._mac = mac_impl
        self._services: Dict[str, _Service] = {}
        self._by_sid: Dict[int, _Service] = {}
        self._channels: Dict[Tuple[int, int], Channel] = {}
        self._glock = threading.Lock()
        self._sid_counter = itertools.count(1)
        self._cid_counter = itertools.count(1)
        self.stats = {"requests": 0, "responses": 0, "macs_verified": 0,
                      "rejected": 0}

        if isinstance(transport, str):
            from repro.core import TRANSPORTS
            transport = TRANSPORTS[transport]
        kwargs = dict(transport_kwargs or {})
        if isinstance(transport, type) and issubclass(transport, MPKLinkTransport):
            # one key table for link channels AND service domains
            kwargs.setdefault("registry", self.registry)
            kwargs.setdefault("ca", self.ca)
        self.transport: Transport = transport(self._dispatch, **kwargs)

    # -- service lifecycle --------------------------------------------------
    def register_service(self, name: str, handler: Handler,
                         allow: Optional[Set[str]] = None) -> int:
        """Enroll a service with the CA and give it its own protection
        domain. ``allow`` restricts which client names may open channels."""
        with self._glock:
            if name in self._services:
                raise ValueError(f"service {name!r} already registered")
            enroll(self.ca, name)
            dom = self.registry.allocate_domain(f"svc:{name}")
            svc = _Service(next(self._sid_counter), name, handler, dom,
                           self.registry.issue_key(dom, RW),
                           set(allow) if allow is not None else None)
            self._services[name] = svc
            self._by_sid[svc.sid] = svc
            return svc.sid

    def start(self) -> "ServiceGateway":
        self.transport.start()
        return self

    def close(self):
        self.transport.close()

    # -- client lifecycle ---------------------------------------------------
    def connect(self, client_name: str) -> "GatewayClient":
        return GatewayClient(self, client_name)

    def _open_channel(self, client: "GatewayClient", service: str) -> Channel:
        """Control plane: CA-checked issue of a client key on the service's
        domain + derivation of the per-(client, service) MAC seed."""
        svc = self._services.get(service)
        if svc is None:
            raise AccessViolation(f"unknown service {service!r}")
        if svc.allow is not None and client.name not in svc.allow:
            raise AccessViolation(
                f"client {client.name!r} not authorized for service {service!r}")
        rec = self.ca._services.get(client.name)
        if rec is None or not rec.verified or not self.ca.verify_cert(rec):
            raise AccessViolation(
                f"client {client.name!r} failed certificate check")
        key = self.registry.issue_key(svc.domain, RW)
        seed = mac_seed(svc.domain, self.registry.epoch(svc.domain)) \
            ^ self.ca.session_seed(client._kp.private, service)
        chan = Channel(client.cid, svc.sid, service, seed, key)
        with self._glock:
            self._channels[(client.cid, svc.sid)] = chan
        return chan

    def revoke(self, client: "GatewayClient", service: Optional[str] = None):
        """Revoke a client's channel key(s). Bumps the service-domain epoch,
        so every stale key/frame on that domain fails the guard afterwards
        (other clients must re-open — the PKRU-flush analogue)."""
        with self._glock:
            doomed = [(k, ch) for k, ch in self._channels.items()
                      if k[0] == client.cid
                      and (service is None or ch.service == service)]
        for k, ch in doomed:
            self.registry.revoke(ch.client_key)
            with self._glock:
                self._channels.pop(k, None)
            client._channels.pop(ch.service, None)
            # the epoch bump stales every key on the domain, including the
            # service's own — the co-located service re-syncs immediately
            # (clients must re-open through the CA; GatewayClient.call does
            # this transparently for still-certified clients)
            svc = self._by_sid[ch.sid]
            svc.server_key = self.registry.issue_key(svc.domain, RW)

    def _release_client(self, client: "GatewayClient"):
        """Graceful disconnect: retire the client's keys (no epoch bump —
        closing is not a security event) and drop its routing entries, so a
        closed client's cid can never dispatch again."""
        with self._glock:
            doomed = [(k, ch) for k, ch in self._channels.items()
                      if k[0] == client.cid]
            for k, ch in doomed:
                self._channels.pop(k, None)
        for _, ch in doomed:
            self.registry.retire(ch.client_key)

    # -- data plane (runs on the transport's per-session service threads) ----
    def _bump(self, *stats: str):
        with self._glock:
            for s in stats:
                self.stats[s] += 1

    def _dispatch(self, req: np.ndarray) -> np.ndarray:
        sid = 0
        try:
            raw = np.ascontiguousarray(np.asarray(req)) \
                .view(np.uint8).reshape(-1)
            if raw.nbytes < _ROUTE_BYTES:
                raise framing.FrameError("short gateway envelope")
            route = raw[:_ROUTE_BYTES].view("<u4")
            if int(route[0]) != GW_MAGIC:
                raise framing.FrameError("not a gateway envelope (bad magic)")
            sid, cid = int(route[1]), int(route[2])
            svc = self._by_sid.get(sid)
            if svc is None:
                raise AccessViolation(f"unknown service id {sid}")
            chan = self._channels.get((cid, sid))
            if chan is None:
                raise AccessViolation(
                    f"client {cid} holds no key for service {svc.name!r}")
            with chan.slock:
                # PKRU staging checks: the client may write the request
                # region, the service may read it (revocation/epoch enforced)
                self.registry.check(chan.client_key, WRITE)
                self.registry.check(svc.server_key, READ)
                frame = raw[_ROUTE_BYTES:].view("<u4") \
                    .reshape(-1, framing.LANES)
                payload = framing.parse_frame(
                    frame, seed=chan.seed, expect_seq=chan.server_seq,
                    mac_impl=self._mac)
                self._bump("requests", "macs_verified")
                resp = _as_frameable(np.asarray(svc.handler(payload)))
                self.registry.check(svc.server_key, WRITE)
                self.registry.check(chan.client_key, READ)
                rframe = framing.build_frame(
                    resp, seed=chan.seed, seq=chan.server_seq,
                    mac_impl=self._mac)
                chan.server_seq += 1
            self._bump("responses")
            return np.concatenate(
                [_route(_OK, sid, 0), rframe.reshape(-1).view(np.uint8)])
        except Exception as e:
            self._bump("rejected")
            blob = _pack_error(e)
            return np.concatenate(
                [_route(_ERR, sid, len(blob)), np.frombuffer(blob, np.uint8)])


class GatewayClient:
    """One CA-enrolled client: its own transport session plus per-service
    channels. ``call()`` is thread-safe but serial per client — open one
    client per concurrent caller (that's the session model)."""

    def __init__(self, gw: ServiceGateway, name: str):
        self.gw = gw
        self.name = name
        self._kp, _ = enroll(gw.ca, name)
        self.cid = next(gw._cid_counter)
        self._session = gw.transport.connect(f"gw:{name}")
        self._channels: Dict[str, Channel] = {}
        self._lock = threading.Lock()
        self.macs_verified = 0          # response MACs this client checked

    def open(self, service: str) -> Channel:
        with self._lock:
            chan = self._channels.get(service)
            if chan is None:
                chan = self.gw._open_channel(self, service)
                self._channels[service] = chan
            return chan

    def reopen(self, service: str) -> Channel:
        """Drop the cached channel and open a fresh one (new key at the
        current epoch) — the recovery path after a domain-epoch bump."""
        with self._lock:
            self._channels.pop(service, None)
        return self.open(service)

    def call(self, service: str, payload: np.ndarray) -> np.ndarray:
        payload = np.asarray(payload)
        try:
            return self._call_once(self.open(service), payload)
        except AccessViolation as e:
            # someone's revocation bumped the service-domain epoch; a still-
            # certified client just re-keys through the CA and retries once
            # (a banned client fails the certificate check in reopen())
            if "stale key epoch" not in str(e):
                raise
            return self._call_once(self.reopen(service), payload)

    def _call_once(self, chan: Channel, payload: np.ndarray) -> np.ndarray:
        with self._lock:
            frame = framing.build_frame(payload, seed=chan.seed,
                                        seq=chan.seq, mac_impl=self.gw._mac)
            env = np.concatenate([_route(chan.sid, self.cid, 0),
                                  frame.reshape(-1).view(np.uint8)])
            resp = np.ascontiguousarray(np.asarray(self._session.request(env))) \
                .view(np.uint8).reshape(-1)
            if resp.nbytes < _ROUTE_BYTES:
                raise TransportError("malformed gateway response (truncated)")
            route = resp[:_ROUTE_BYTES].view("<u4")
            if int(route[0]) != GW_MAGIC:
                raise TransportError("malformed gateway response (bad magic)")
            if int(route[1]) != _OK:
                _raise_remote(resp[_ROUTE_BYTES:
                                   _ROUTE_BYTES + int(route[3])].tobytes())
            rframe = resp[_ROUTE_BYTES:].view("<u4") \
                .reshape(-1, framing.LANES)
            out = framing.parse_frame(rframe, seed=chan.seed,
                                      expect_seq=chan.seq,
                                      mac_impl=self.gw._mac)
            chan.seq += 1
            self.macs_verified += 1
            return out

    def close(self):
        self.gw._release_client(self)
        with self._lock:
            self._channels.clear()
        self._session.close()
