"""Pure-jnp oracles for every kernel. Slow, obvious, and correct.

These define the semantics that the Pallas kernels (and the chunked jnp
twins used for training/dry-run) must match bit-for-bit in f32 / within
tolerance in bf16. All tests assert against these.

Shared conventions
------------------
attention: q (B, Sq, H, Dh); k, v (B, Skv, Hkv, Dh) with H = Hkv * g (GQA).
positions: q_pos (B, Sq), kv_pos (B, Skv) int32; kv_pos == -1 marks an
invalid slot (unfilled cache / padding), q_pos < 0 marks a padded query row
(output forced to 0). ``causal`` masks kv_pos > q_pos; ``window`` (if set)
masks q_pos - kv_pos >= window (SWA).

ssd (Mamba2 state-space duality): x (B, S, H, P); dt (B, S, H);
A_log (H,); B, C (B, S, G, N) with G | H; D (H,); state (B, H, P, N).
Recurrence per head:  a_t = exp(dt_t * -exp(A_log))
    state_t = a_t * state_{t-1} + dt_t * (x_t ⊗ B_t)
    y_t     = state_t · C_t + D * x_t

guard_copy (MPKLink data plane): payload (n, 128) uint32, tag word, 128-lane
Horner MAC folded to one uint32; returns (copy, mac, ok).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30
MAC_PRIME = 0x01000193   # FNV-ish multiplier (python int: safe to use inside Pallas)
MAC_INIT = 0x811C9DC5


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def attention_ref(q, k, v, q_pos, kv_pos, *, causal: bool = True,
                  window=None, softmax_scale=None):
    B, Sq, H, Dh = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    scale = softmax_scale if softmax_scale is not None else Dh ** -0.5

    qf = q.astype(jnp.float32).reshape(B, Sq, Hkv, g, Dh)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf) * scale

    qp = q_pos[:, None, None, :, None].astype(jnp.int32)
    kp = kv_pos[:, None, None, None, :].astype(jnp.int32)
    valid = kp >= 0
    if causal:
        valid &= kp <= qp
    if window is not None:
        valid &= (qp - kp) < window
    scores = jnp.where(valid, scores, NEG_INF)

    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - jax.lax.stop_gradient(jnp.maximum(m, NEG_INF / 2)))
    e = jnp.where(valid, e, 0.0)
    denom = jnp.sum(e, axis=-1, keepdims=True)
    p = e / jnp.maximum(denom, 1e-30)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf).reshape(B, Sq, H, Dh)
    out = jnp.where(q_pos[:, :, None, None] < 0, 0.0, out)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# ssd (Mamba2)
# ---------------------------------------------------------------------------

def ssd_ref(x, dt, A_log, B, C, D, init_state=None):
    Bb, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = jnp.repeat(B.astype(jnp.float32), rep, axis=2)   # (B, S, H, N)
    Cf = jnp.repeat(C.astype(jnp.float32), rep, axis=2)
    A = -jnp.exp(A_log.astype(jnp.float32))               # (H,) negative
    a = jnp.exp(dtf * A[None, None, :])                   # (B, S, H) decay in (0, 1]

    state0 = (jnp.zeros((Bb, H, P, N), jnp.float32)
              if init_state is None else init_state.astype(jnp.float32))

    def step(state, inp):
        x_t, a_t, dt_t, B_t, C_t = inp                    # (B,H,P), (B,H), (B,H), (B,H,N), (B,H,N)
        state = a_t[:, :, None, None] * state + jnp.einsum("bhp,bhn->bhpn", dt_t[..., None] * x_t, B_t)
        y_t = jnp.einsum("bhpn,bhn->bhp", state, C_t)
        return state, y_t

    xs = (xf.transpose(1, 0, 2, 3), a.transpose(1, 0, 2), dtf.transpose(1, 0, 2),
          Bf.transpose(1, 0, 2, 3), Cf.transpose(1, 0, 2, 3))
    final_state, ys = jax.lax.scan(step, state0, xs)
    y = ys.transpose(1, 0, 2, 3) + D.astype(jnp.float32)[None, None, :, None] * xf
    return y.astype(x.dtype), final_state


# ---------------------------------------------------------------------------
# guard_copy (MPKLink protected copy: tag check + Horner MAC + copy)
# ---------------------------------------------------------------------------

def _fold_powers_u32():
    """PRIME^(127-i) mod 2^32 — Horner across lanes as one vector dot."""
    import numpy as np
    p = np.uint64(MAC_PRIME)
    out = np.zeros(128, np.uint64)
    acc = np.uint64(1)
    for i in range(127, -1, -1):
        out[i] = acc
        acc = (acc * p) & np.uint64(0xFFFFFFFF)
    return out.astype(np.uint32)


_FOLD_POWERS = _fold_powers_u32()


def mac_ref(payload_u32, tag: jnp.ndarray):
    """128-lane Horner hash over rows, folded across lanes, tag mixed in.

    Fold identity: Horner(h_0..h_127) = Σ h_i · PRIME^(127-i)  (mod 2^32),
    so the lane fold is a single vector multiply-add — the same form the
    Pallas kernel uses on the VPU.
    """
    assert payload_u32.dtype == jnp.uint32 and payload_u32.shape[-1] == 128

    def row_step(h, row):
        return h * MAC_PRIME + row, None

    from repro.utils import match_vma
    h0 = jnp.full((128,), MAC_INIT, jnp.uint32) + tag.astype(jnp.uint32)
    h0 = match_vma(h0, payload_u32)
    h, _ = jax.lax.scan(row_step, h0, payload_u32)
    return jnp.sum(h * jnp.asarray(_FOLD_POWERS), dtype=jnp.uint32)


def guard_copy_ref(payload_u32, tag, expected_mac):
    mac = mac_ref(payload_u32, tag)
    ok = (mac == expected_mac.astype(jnp.uint32)).astype(jnp.int32)
    return payload_u32, mac, ok
