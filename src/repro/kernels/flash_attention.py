"""Flash attention as a Pallas TPU kernel.

Target: TPU v5e MXU/VMEM. Grid (B, H, nq, nk) with nk innermost — TPU grids
iterate sequentially, so the (m, l, acc) online-softmax state lives in VMEM
scratch and persists across the nk sweep for a fixed (b, h, iq); the output
block is written once on the last nk step.

Tiling: q block (qc=128, Dh) and kv blocks (kc=128, Dh) are (8,128)-aligned
for Dh ∈ {64, 80, 128}; all matmuls are qc×Dh·Dh×kc and qc×kc·kc×Dh — MXU
shapes. f32 accumulation. GQA is handled in the k/v index_map (h → h // g),
so no KV repeat is ever materialized.

SWA/causal masking uses explicit position vectors (works for ring caches);
fully-masked kv blocks skip the dots (`pl.when`) — on TPU this saves the MXU
issue for the lower triangle's empty blocks and everything outside the SWA
band.

Validated in interpret mode against ref.attention_ref (tests/test_kernels_flash.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import NEG_INF


def _flash_kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, out_ref,
                  m_ref, l_ref, acc_ref, *, causal, window, out_dtype):
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qp = qpos_ref[0, :]                                    # (qc,)
    kp = kpos_ref[0, :]                                    # (kc,)
    mask = (kp >= 0)[None, :]
    if causal:
        mask = mask & (kp[None, :] <= qp[:, None])
    if window is not None:
        mask = mask & ((qp[:, None] - kp[None, :]) < window)

    @pl.when(jnp.any(mask))
    def _compute():
        qb = q_ref[0, :, 0, :].astype(jnp.float32)
        kb = k_ref[0, :, 0, :].astype(jnp.float32)
        vb = v_ref[0, :, 0, :].astype(jnp.float32)
        scale = qb.shape[-1] ** -0.5
        s = jax.lax.dot_general(qb, kb, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[0, :]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        l_ref[0, :] = l_ref[0, :] * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
            p, vb, preferred_element_type=jnp.float32)
        m_ref[0, :] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        out = acc_ref[...] / jnp.maximum(l_ref[0, :], 1e-30)[:, None]
        out = jnp.where((qp < 0)[:, None], 0.0, out)
        out_ref[0, :, 0, :] = out.astype(out_dtype)


def flash_attention_pallas(q, k, v, q_pos, kv_pos, *, causal=True, window=None,
                           q_chunk=128, kv_chunk=128, interpret=True):
    """q (B,Sq,H,Dh); k/v (B,Skv,Hkv,Dh); positions (B,S*) int32.

    Requires Sq % q_chunk == 0 and Skv % kv_chunk == 0 (ops.py pads).
    interpret=True on CPU; on a real TPU pass interpret=False.
    """
    B, Sq, H, Dh = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    qc, kc = q_chunk, kv_chunk
    assert Sq % qc == 0 and Skv % kc == 0, (Sq, qc, Skv, kc)
    nq, nk = Sq // qc, Skv // kc

    grid = (B, H, nq, nk)
    kernel = functools.partial(_flash_kernel, causal=causal, window=window,
                               out_dtype=q.dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, qc), lambda b, h, iq, ik: (b, iq)),           # qpos
            pl.BlockSpec((1, kc), lambda b, h, iq, ik: (b, ik)),           # kpos
            pl.BlockSpec((1, qc, 1, Dh), lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, kc, 1, Dh), lambda b, h, iq, ik: (b, ik, h // g, 0)),
            pl.BlockSpec((1, kc, 1, Dh), lambda b, h, iq, ik: (b, ik, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, qc, 1, Dh), lambda b, h, iq, ik: (b, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, H, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, qc), jnp.float32),     # m
            pltpu.VMEM((1, qc), jnp.float32),     # l
            pltpu.VMEM((qc, Dh), jnp.float32),    # acc
        ],
        interpret=interpret,
    )(q_pos.astype(jnp.int32), kv_pos.astype(jnp.int32), q, k, v)
