"""Mamba2 SSD (state-space duality) — chunked jnp implementation.

The sequential recurrence (ref.py) is O(S) steps; SSD reorganizes it into
MXU-friendly chunk-local matmuls plus an O(S/Q) inter-chunk state scan:

  within chunk c (length Q), with cumulative log-decay L_i = Σ_{t≤i} dt_t·A:
    intra:  y_i += Σ_{j≤i} (C_i·B_j) · exp(L_i − L_j) · dt_j · x_j
    carry:  S_c  = exp(L_Q)·S_{c−1} + Σ_j exp(L_Q − L_j)·dt_j·(x_j ⊗ B_j)
    inter:  y_i += exp(L_i) · C_i · S_{c−1}

All decays are ≤ 1 (dt ≥ 0, A < 0) so every exp() here is ≤ 1 — no overflow.
Group-aware (B/C shared across H/G heads) without materializing repeats.
Fully differentiable (plain jnp + scan); the Pallas kernel mirrors this
blocking with the state carried in VMEM scratch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _pad_seq(x, mult, fill=0.0):
    pad = (-x.shape[1]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[1] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


def ssd_chunked(x, dt, A_log, B, C, D, init_state=None, *, chunk=128):
    """Shapes as ref.ssd_ref. Returns (y, final_state (B,H,P,N) f32)."""
    Bb, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    R = H // G
    Q = min(chunk, S)

    xf = _pad_seq(x.astype(jnp.float32), Q)
    dtf = _pad_seq(dt.astype(jnp.float32), Q)          # pad dt=0 → decay 1, no update
    Bf = _pad_seq(B.astype(jnp.float32), Q)
    Cf = _pad_seq(C.astype(jnp.float32), Q)
    Sp = xf.shape[1]
    nc = Sp // Q

    # (B, nc, Q, G, R, ...) group-aware blocks
    xb = xf.reshape(Bb, nc, Q, G, R, P)
    dtb = dtf.reshape(Bb, nc, Q, G, R)
    Bb_ = Bf.reshape(Bb, nc, Q, G, N)
    Cb = Cf.reshape(Bb, nc, Q, G, N)
    A = -jnp.exp(A_log.astype(jnp.float32)).reshape(G, R)

    la = dtb * A[None, None, None]                     # (B,nc,Q,G,R) ≤ 0
    cum = jnp.cumsum(la, axis=2)                       # inclusive cumulative log-decay
    seg = cum[:, :, -1:]                               # (B,nc,1,G,R) chunk total

    # intra-chunk: M_ij = exp(L_i − L_j) for i ≥ j
    dec = jnp.exp(cum[:, :, :, None] - cum[:, :, None, :, :])      # (B,nc,Q,Q,G,R)
    tri = jnp.tril(jnp.ones((Q, Q), jnp.float32))
    dec = dec * tri[None, None, :, :, None, None]
    cb = jnp.einsum("bcqgn,bcjgn->bcqjg", Cb, Bb_)                  # (B,nc,Q,Q,G)
    att = cb[..., None] * dec * dtb[:, :, None, :, :]               # weight at source j
    y_intra = jnp.einsum("bcqjgr,bcjgrp->bcqgrp", att, xb)

    # chunk state contribution: Σ_j exp(L_Q − L_j)·dt_j·(x_j ⊗ B_j)
    w = jnp.exp(seg - cum) * dtb                                    # (B,nc,Q,G,R)
    s_c = jnp.einsum("bcjgr,bcjgrp,bcjgn->bcgrpn", w, xb, Bb_)      # (B,nc,G,R,P,N)

    state0 = (jnp.zeros((Bb, G, R, P, N), jnp.float32) if init_state is None
              else init_state.astype(jnp.float32).reshape(Bb, G, R, P, N))
    from repro.utils import match_vma
    state0 = match_vma(state0, xf)

    def carry_step(state, inp):
        decay_c, s_chunk = inp                                      # (B,G,R), (B,G,R,P,N)
        state_out = decay_c[..., None, None] * state + s_chunk
        return state_out, state                                     # emit state *entering* chunk

    decay = jnp.exp(seg[:, :, 0])                                   # (B,nc,G,R)
    final_state, states_in = jax.lax.scan(
        carry_step, state0, (decay.transpose(1, 0, 2, 3), s_c.transpose(1, 0, 2, 3, 4, 5)))
    states_in = states_in.transpose(1, 0, 2, 3, 4, 5)               # (B,nc,G,R,P,N)

    # inter-chunk: exp(L_i) · C_i · S_{c−1}
    y_inter = jnp.einsum("bcqgn,bcgrpn,bcqgr->bcqgrp",
                         Cb, states_in, jnp.exp(cum))

    y = (y_intra + y_inter).reshape(Bb, Sp, H, P)[:, :S]
    y = y + D.astype(jnp.float32)[None, None, :, None] * x.astype(jnp.float32)
    return y.astype(x.dtype), final_state.reshape(Bb, H, P, N)


def ssd_decode_step(x_t, dt_t, A_log, B_t, C_t, D, state):
    """Single-token recurrent step. x_t (B,H,P); dt_t (B,H); B_t/C_t (B,G,N);
    state (B,H,P,N) f32 → (y_t (B,H,P), new_state)."""
    Bb, H, P = x_t.shape
    G, N = B_t.shape[1], B_t.shape[2]
    R = H // G
    xf = x_t.astype(jnp.float32)
    dtf = dt_t.astype(jnp.float32)
    A = -jnp.exp(A_log.astype(jnp.float32))
    a = jnp.exp(dtf * A[None])                                      # (B,H)
    Bh = jnp.repeat(B_t.astype(jnp.float32), R, axis=1)             # (B,H,N) — tiny at decode
    Ch = jnp.repeat(C_t.astype(jnp.float32), R, axis=1)
    state = a[:, :, None, None] * state + jnp.einsum("bhp,bhn->bhpn", dtf[..., None] * xf, Bh)
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch) + D.astype(jnp.float32)[None, :, None] * xf
    return y.astype(x_t.dtype), state
