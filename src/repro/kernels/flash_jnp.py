"""Blockwise online-softmax attention in pure jnp — the differentiable twin
of the Pallas flash kernel.

Same algorithm, same O(Sq·Dh) live memory profile (no Sq×Skv score tensor in
the checkpointed state): forward is a scan over q-blocks with an inner scan
over kv-blocks carrying (m, l, acc); backward (custom_vjp) recomputes scores
blockwise and accumulates dq/dk/dv, so training at 32k context never
materializes the full attention matrix. This is what `train`/`prefill`
dry-runs lower, so cost_analysis reflects the flash memory profile.

Numerics: NEG_INF is a large *finite* negative so fully-masked rows give
m - m = 0 (not nan); masked rows produce exactly 0 output, matching ref.py.
"""
from __future__ import annotations

from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels.ref import NEG_INF
from repro.utils import match_vma


def _valid(qp, kp, causal, window):
    """qp (B, q), kp (B, k) → (B, 1, 1, q, k) bool."""
    v = (kp >= 0)[:, None, None, None, :]
    if causal:
        v = v & (kp[:, None, None, None, :] <= qp[:, None, None, :, None])
    if window is not None:
        v = v & ((qp[:, None, None, :, None] - kp[:, None, None, None, :]) < window)
    return v


def _expand_kv(k, g):
    """repeat_kv: expand (B,S,Hkv,Dh) → (B,S,H,Dh). Under GSPMD this keeps
    the head dim shardable over the TP axis; the (Hkv, g) reshape view used
    previously splits the sharded H dim into two dims XLA cannot co-shard,
    which silently REPLICATED every attention block over the model axis
    (measured 16× attention flops/bytes on mixtral before this). The Pallas
    kernel needs no expansion — its k index_map (h → h//g) is the
    zero-copy equivalent."""
    if g == 1:
        return k
    return jnp.repeat(k, g, axis=2)


def _fwd_core(q, k, v, qpos, kpos, causal, window, qc, kc):
    """Divisible shapes only. Returns (out f32, lse f32)."""
    B, Sq, H, Dh = q.shape
    k = _expand_kv(k, H // k.shape[2])
    v = _expand_kv(v, H // v.shape[2])
    Hkv = k.shape[2]
    g = H // Hkv
    Skv = k.shape[1]
    nq, nk = Sq // qc, Skv // kc
    scale = Dh ** -0.5

    qf = q.astype(jnp.float32).reshape(B, nq, qc, Hkv, g, Dh)
    kf = k.astype(jnp.float32).reshape(B, nk, kc, Hkv, Dh)
    vf = v.astype(jnp.float32).reshape(B, nk, kc, Hkv, Dh)
    qpb = qpos.reshape(B, nq, qc)
    kpb = kpos.reshape(B, nk, kc)

    def q_block(_, qi):
        qb, qp = qi                                        # (B,qc,Hkv,g,Dh), (B,qc)
        m0 = jnp.full((B, Hkv, g, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, g, qc), jnp.float32)
        a0 = jnp.zeros((B, Hkv, g, qc, Dh), jnp.float32)
        m0, l0, a0 = match_vma((m0, l0, a0), qb)

        def kv_block(carry, ki):
            m, l, acc = carry
            kb, vb, kp = ki
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb) * scale
            ok = _valid(qp, kp, causal, window)            # (B,1,1,qc,kc)
            s = jnp.where(ok, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(ok, p, 0.0)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum("bhgqk,bkhd->bhgqd", p, vb)
            return (m_new, l, acc), None

        (m, l, acc), _ = jax.lax.scan(
            kv_block, (m0, l0, a0),
            (kf.transpose(1, 0, 2, 3, 4), vf.transpose(1, 0, 2, 3, 4), kpb.transpose(1, 0, 2)))
        ob = acc / jnp.maximum(l, 1e-30)[..., None]        # (B,Hkv,g,qc,Dh)
        lse = jnp.where(l > 0, m + jnp.log(l), NEG_INF)    # (B,Hkv,g,qc)
        ob = jnp.where((qp < 0)[:, None, None, :, None], 0.0, ob)
        return None, (ob, lse)

    _, (ob, lse) = jax.lax.scan(
        q_block, None, (qf.transpose(1, 0, 2, 3, 4, 5), qpb.transpose(1, 0, 2)))
    out = ob.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, H, Dh)       # nq,B,Hkv,g,qc,Dh
    lse_full = lse.transpose(1, 0, 4, 2, 3).reshape(B, Sq, H)
    return out, lse_full


@partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def _flash(q, k, v, qpos, kpos, causal, window, qc, kc):
    out, _ = _fwd_core(q, k, v, qpos, kpos, causal, window, qc, kc)
    return out.astype(q.dtype)


def _flash_fwd(q, k, v, qpos, kpos, causal, window, qc, kc):
    out, lse = _fwd_core(q, k, v, qpos, kpos, causal, window, qc, kc)
    return out.astype(q.dtype), (q, k, v, qpos, kpos, out, lse)


def _flash_bwd(causal, window, qc, kc, res, do):
    q, k, v, qpos, kpos, out, lse = res
    B, Sq, H, Dh = q.shape
    g_kv = H // k.shape[2]                         # true GQA group size
    k = _expand_kv(k, g_kv)
    v = _expand_kv(v, g_kv)
    Hkv = k.shape[2]
    g = H // Hkv
    Skv = k.shape[1]
    nq, nk = Sq // qc, Skv // kc
    scale = Dh ** -0.5

    qf = q.astype(jnp.float32).reshape(B, nq, qc, Hkv, g, Dh)
    kf = k.astype(jnp.float32).reshape(B, nk, kc, Hkv, Dh)
    vf = v.astype(jnp.float32).reshape(B, nk, kc, Hkv, Dh)
    dof = do.astype(jnp.float32).reshape(B, nq, qc, Hkv, g, Dh)
    qpb = qpos.reshape(B, nq, qc)
    kpb = kpos.reshape(B, nk, kc)
    lseb = lse.reshape(B, nq, qc, Hkv, g)
    # Delta_i = rowsum(dO ∘ O)
    delta = jnp.sum(out * do.astype(jnp.float32), axis=-1).reshape(B, nq, qc, Hkv, g)
    live = (lseb > NEG_INF / 2)

    def kv_step(dq_acc, kv_in):
        kb, vb, kp = kv_in                                 # kb/vb (B,kc,Hkv,Dh)

        def q_step(dq_acc, q_in):
            qb, dob, qp, lse_i, dl_i, lv_i, iq = q_in
            # lse_i/dl_i/lv_i arrive as (B, qc, Hkv, g) → (B, Hkv, g, qc)
            lse_t = lse_i.transpose(0, 2, 3, 1)[..., None]
            dl_t = dl_i.transpose(0, 2, 3, 1)[..., None]
            lv_t = lv_i.transpose(0, 2, 3, 1)[..., None]
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb) * scale
            ok = _valid(qp, kp, causal, window) & lv_t
            p = jnp.where(ok, jnp.exp(s - lse_t), 0.0)
            dp = jnp.einsum("bqhgd,bkhd->bhgqk", dob, vb)
            ds = p * (dp - dl_t) * scale
            dv_j = jnp.einsum("bhgqk,bqhgd->bkhd", p, dob)
            dk_j = jnp.einsum("bhgqk,bqhgd->bkhd", ds, qb)
            dq_i = jnp.einsum("bhgqk,bkhd->bqhgd", ds, kb)
            dq_acc = dq_acc.at[:, iq].add(dq_i)            # accumulate across kv blocks
            return dq_acc, (dk_j, dv_j)

        dq_acc, (dks, dvs) = jax.lax.scan(
            q_step, dq_acc,
            (qf.transpose(1, 0, 2, 3, 4, 5), dof.transpose(1, 0, 2, 3, 4, 5),
             qpb.transpose(1, 0, 2), lseb.transpose(1, 0, 2, 3, 4),
             delta.transpose(1, 0, 2, 3, 4), live.transpose(1, 0, 2, 3, 4),
             jnp.arange(nq)))
        dk_j = jnp.sum(dks, axis=0)
        dv_j = jnp.sum(dvs, axis=0)
        return dq_acc, (dk_j, dv_j)

    dq0 = match_vma(jnp.zeros((B, nq, qc, Hkv, g, Dh), jnp.float32), qf)
    dq, (dk, dv) = jax.lax.scan(
        kv_step, dq0,
        (kf.transpose(1, 0, 2, 3, 4), vf.transpose(1, 0, 2, 3, 4),
         kpb.transpose(1, 0, 2)))
    dq = dq.reshape(B, Sq, H, Dh).astype(q.dtype)
    dk = dk.transpose(1, 0, 2, 3, 4).reshape(B, Skv, Hkv, Dh)
    dv = dv.transpose(1, 0, 2, 3, 4).reshape(B, Skv, Hkv, Dh)
    if g_kv > 1:                                   # un-expand: sum over groups
        dk = dk.reshape(B, Skv, Hkv // g_kv, g_kv, Dh).sum(3)
        dv = dv.reshape(B, Skv, Hkv // g_kv, g_kv, Dh).sum(3)
    dk = dk.astype(res[1].dtype)
    dv = dv.astype(res[2].dtype)
    f0 = lambda x: np.zeros(x.shape, jax.dtypes.float0)
    return dq, dk, dv, f0(qpos), f0(kpos)


_flash.defvjp(_flash_fwd, _flash_bwd)


def _pad_to(x, axis, mult, fill):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


def flash_attention_jnp(q, k, v, q_pos, kv_pos, *, causal=True, window=None,
                        q_chunk=128, kv_chunk=128):
    """Public chunked attention; pads to block multiples then unpads."""
    B, Sq = q.shape[:2]
    qc = min(q_chunk, max(1, Sq))
    kc = min(kv_chunk, max(1, k.shape[1]))
    qp = _pad_to(q_pos.astype(jnp.int32), 1, qc, -2)
    kp = _pad_to(kv_pos.astype(jnp.int32), 1, kc, -1)
    qpad = _pad_to(q, 1, qc, 0)
    kpad = _pad_to(k, 1, kc, 0)
    vpad = _pad_to(v, 1, kc, 0)
    out = _flash(qpad, kpad, vpad, qp, kp, causal, window, qc, kc)
    return out[:, :Sq]
