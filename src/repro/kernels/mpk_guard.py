"""mpk_guard — the MPKLink data plane as a Pallas TPU kernel.

The paper's hot spot is the *protected copy*: moving a message through a
shared region while enforcing access control and authenticity. On x86 that
is pkey-tagged pages + PKRU checks + a signature pass. On TPU we fuse all
three into the copy itself:

  * the channel's domain **tag** seeds the MAC state, so a receiver holding
    the wrong key computes a wrong MAC — access control and authentication
    collapse into one check;
  * a 128-lane **Horner MAC** is updated per tile while it is resident in
    VMEM, then folded with a precomputed power vector (Σ h_i·P^(127-i),
    algebraically identical to scalar Horner but one vector multiply-add —
    no 128-step scalar loop on the VPU);
  * the payload is **copied** HBM→VMEM→HBM tile by tile.

The MAC arithmetic rides under the tile loads: the kernel stays memory-bound,
so authenticated transport costs ≈ a plain copy (benchmarks/kernel_bench.py
measures exactly this delta — the paper's Table-X "security for free" claim).

Grid is 1-D over row tiles, sequential; the MAC state is VMEM scratch.
Validated in interpret mode against ref.mac_ref / ref.guard_copy_ref.

Batch variant (the pipelined data plane): :func:`mac_batch_pallas` MACs a
whole (N, rows, 128) stack of frames in one launch — grid (N, row-tiles),
one VMEM Horner state per frame, N MAC words out. :func:`mac_batch_jnp` is
the shape-polymorphic jnp twin. Both are bit-identical to
``core.framing.mac_batch`` (the host path the transports use) and to the
scalar ``ref.mac_ref`` — tests/test_batching.py asserts all four agree.

Streaming variant (the zero-copy seal path): :func:`mac_init_state` /
:func:`mac_update_pallas` / :func:`mac_update_jnp` / :func:`mac_finalize`
expose the Horner recurrence as an explicit running state, so a large
payload is MAC'd block-wise as each chunk lands in the region — no staging
copy of the whole message. Feeding the blocks of a payload through
``mac_update`` and folding with ``mac_finalize`` is bit-identical to one
``mac_ref`` pass over the concatenation (tests/test_zero_copy.py asserts
it for pallas, jnp and the host twins in ``core.framing``).
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import MAC_PRIME, MAC_INIT

LANES = 128


def _fold_powers() -> np.ndarray:
    """PRIME^(127-i) mod 2^32 for the vectorized Horner fold."""
    p = np.uint64(MAC_PRIME)
    out = np.zeros(LANES, np.uint64)
    acc = np.uint64(1)
    for i in range(LANES - 1, -1, -1):
        out[i] = acc
        acc = (acc * p) & np.uint64(0xFFFFFFFF)
    return out.astype(np.uint32)


FOLD_POWERS = _fold_powers()


def _guard_kernel(tag_ref, expect_ref, powers_ref, in_ref, out_ref, mac_ref,
                  ok_ref, h, *, rows_per_tile):
    i = pl.program_id(0)
    n = pl.num_programs(0)

    @pl.when(i == 0)
    def _init():
        h[...] = (jnp.full((1, LANES), MAC_INIT, jnp.uint32)
                  + tag_ref[0].astype(jnp.uint32))

    tile = in_ref[...]                                  # (rows, 128) uint32
    acc = h[0, :]
    for r in range(rows_per_tile):                      # static unroll
        acc = acc * MAC_PRIME + tile[r, :]
    h[0, :] = acc
    out_ref[...] = tile                                 # the copy

    @pl.when(i == n - 1)
    def _final():
        mac = jnp.sum(h[0, :] * powers_ref[...], dtype=jnp.uint32)
        mac_ref[0] = mac
        ok_ref[0] = (mac == expect_ref[0].astype(jnp.uint32)).astype(jnp.int32)


def guard_copy_pallas(payload_u32, tag, expected_mac, *, rows_per_tile=256,
                      interpret=True):
    """payload (n, 128) uint32 with n % rows_per_tile == 0 (ops.py pads).
    Returns (copy, mac (1,) uint32, ok (1,) int32)."""
    n, lanes = payload_u32.shape
    assert lanes == LANES and payload_u32.dtype == jnp.uint32
    rt = min(rows_per_tile, n)
    assert n % rt == 0, (n, rt)
    grid = (n // rt,)
    kernel = functools.partial(_guard_kernel, rows_per_tile=rt)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),         # tag
            pl.BlockSpec((1,), lambda i: (0,)),         # expected mac
            pl.BlockSpec((LANES,), lambda i: (0,)),     # fold powers
            pl.BlockSpec((rt, LANES), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((rt, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, LANES), jnp.uint32),
            jax.ShapeDtypeStruct((1,), jnp.uint32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((1, LANES), jnp.uint32)],
        interpret=interpret,
    )(tag.reshape(1).astype(jnp.uint32), expected_mac.reshape(1).astype(jnp.uint32),
      jnp.asarray(FOLD_POWERS), payload_u32)


# ---------------------------------------------------------------------------
# batched MAC: N frames in one launch (the vectorized data-plane pass)
# ---------------------------------------------------------------------------

def _batch_mac_kernel(tag_ref, powers_ref, in_ref, mac_ref, h,
                      *, rows_per_tile):
    j = pl.program_id(1)
    nt = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        h[...] = (jnp.full((1, LANES), MAC_INIT, jnp.uint32)
                  + tag_ref[0].astype(jnp.uint32))

    tile = in_ref[0]                                    # (rows, 128) uint32
    acc = h[0, :]
    for r in range(rows_per_tile):                      # static unroll
        acc = acc * MAC_PRIME + tile[r, :]
    h[0, :] = acc

    @pl.when(j == nt - 1)
    def _final():
        mac_ref[0] = jnp.sum(h[0, :] * powers_ref[...], dtype=jnp.uint32)


def mac_batch_pallas(stack_u32, tag, *, rows_per_tile=256, interpret=True):
    """(N, rows, 128) uint32 stack → (N,) uint32 MACs, one kernel launch.

    Grid is (frame, row-tile); the row-tile axis is innermost so each
    frame's Horner state lives in VMEM scratch across its tiles exactly like
    the scalar kernel — the batch axis just replays that schedule N times
    without N dispatches. ``rows`` must divide by ``rows_per_tile`` (snapped
    down here, never padded: padding rows would change the Horner MAC)."""
    n, rows, lanes = stack_u32.shape
    assert lanes == LANES and stack_u32.dtype == jnp.uint32
    rt = min(rows_per_tile, max(1, rows))
    while rows % rt:
        rt -= 1
    grid = (n, rows // rt)
    kernel = functools.partial(_batch_mac_kernel, rows_per_tile=rt)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (0,)),          # tag
            pl.BlockSpec((LANES,), lambda i, j: (0,)),      # fold powers
            pl.BlockSpec((1, rt, LANES), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.uint32),
        scratch_shapes=[pltpu.VMEM((1, LANES), jnp.uint32)],
        interpret=interpret,
    )(tag.reshape(1).astype(jnp.uint32), jnp.asarray(FOLD_POWERS),
      stack_u32)


def mac_batch_jnp(stack_u32, tag):
    """jnp twin of :func:`mac_batch_pallas`: (N, rows, 128) → (N,) uint32.
    One lax.scan over the row axis, vectorized across frames."""
    assert stack_u32.dtype == jnp.uint32 and stack_u32.shape[-1] == LANES

    def row_step(h, row):                               # h, row: (N, 128)
        return h * jnp.uint32(MAC_PRIME) + row, None

    n = stack_u32.shape[0]
    h0 = jnp.full((n, LANES), MAC_INIT, jnp.uint32) + tag.astype(jnp.uint32)
    h, _ = jax.lax.scan(row_step, h0, stack_u32.transpose(1, 0, 2))
    return jnp.sum(h * jnp.asarray(FOLD_POWERS)[None, :], axis=1,
                   dtype=jnp.uint32)


# ---------------------------------------------------------------------------
# streaming MAC: explicit running state, blocks MAC'd as they land
# ---------------------------------------------------------------------------

def mac_init_state(tag) -> jnp.ndarray:
    """Fresh (LANES,) uint32 Horner state for a channel ``tag`` — the
    device twin of ``core.framing.mac_init_np``."""
    return (jnp.full((LANES,), MAC_INIT, jnp.uint32)
            + jnp.asarray(tag).astype(jnp.uint32))


def mac_update_jnp(h, block_u32) -> jnp.ndarray:
    """Advance a (LANES,) uint32 Horner state over an (m, 128) uint32
    block: the shape-polymorphic twin of :func:`mac_update_pallas`."""
    assert block_u32.dtype == jnp.uint32 and block_u32.shape[-1] == LANES

    def row_step(acc, row):
        return acc * jnp.uint32(MAC_PRIME) + row, None

    h, _ = jax.lax.scan(row_step, h.astype(jnp.uint32), block_u32)
    return h


def _mac_update_kernel(h_ref, in_ref, out_ref, acc, *, rows_per_tile):
    i = pl.program_id(0)
    n = pl.num_programs(0)

    @pl.when(i == 0)
    def _init():
        acc[...] = h_ref[...].reshape(1, LANES)

    tile = in_ref[...]                                  # (rows, 128) uint32
    a = acc[0, :]
    for r in range(rows_per_tile):                      # static unroll
        a = a * MAC_PRIME + tile[r, :]
    acc[0, :] = a

    @pl.when(i == n - 1)
    def _final():
        out_ref[...] = acc[0, :]


def mac_update_pallas(h, block_u32, *, rows_per_tile=256, interpret=True):
    """Advance a (LANES,) uint32 Horner state over an (m, 128) uint32
    block in one launch. The state rides in VMEM scratch across row tiles
    exactly like the one-shot kernels — this is the same schedule with the
    init/fold peeled off, so ``mac_finalize(update(update(init, b0), b1))``
    is bit-identical to ``mac_ref(concat(b0, b1))`` for any block split.
    ``m`` is snapped down to a divisor tile (padding would change the
    Horner MAC); an empty block returns the state unchanged."""
    m, lanes = block_u32.shape
    assert lanes == LANES and block_u32.dtype == jnp.uint32
    if m == 0:
        return h.astype(jnp.uint32)
    rt = min(rows_per_tile, m)
    while m % rt:
        rt -= 1
    kernel = functools.partial(_mac_update_kernel, rows_per_tile=rt)
    return pl.pallas_call(
        kernel,
        grid=(m // rt,),
        in_specs=[
            pl.BlockSpec((LANES,), lambda i: (0,)),     # running state
            pl.BlockSpec((rt, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((LANES,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((LANES,), jnp.uint32),
        scratch_shapes=[pltpu.VMEM((1, LANES), jnp.uint32)],
        interpret=interpret,
    )(h.astype(jnp.uint32), block_u32)


def mac_finalize(h) -> jnp.ndarray:
    """Fold a (LANES,) Horner state to the single uint32 MAC word
    (Σ h_i·P^(127-i) — one vector multiply-add, shared by every impl)."""
    return jnp.sum(h.astype(jnp.uint32) * jnp.asarray(FOLD_POWERS),
                   dtype=jnp.uint32)
