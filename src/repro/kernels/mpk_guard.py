"""mpk_guard — the MPKLink data plane as a Pallas TPU kernel.

The paper's hot spot is the *protected copy*: moving a message through a
shared region while enforcing access control and authenticity. On x86 that
is pkey-tagged pages + PKRU checks + a signature pass. On TPU we fuse all
three into the copy itself:

  * the channel's domain **tag** seeds the MAC state, so a receiver holding
    the wrong key computes a wrong MAC — access control and authentication
    collapse into one check;
  * a 128-lane **Horner MAC** is updated per tile while it is resident in
    VMEM, then folded with a precomputed power vector (Σ h_i·P^(127-i),
    algebraically identical to scalar Horner but one vector multiply-add —
    no 128-step scalar loop on the VPU);
  * the payload is **copied** HBM→VMEM→HBM tile by tile.

The MAC arithmetic rides under the tile loads: the kernel stays memory-bound,
so authenticated transport costs ≈ a plain copy (benchmarks/kernel_bench.py
measures exactly this delta — the paper's Table-X "security for free" claim).

Grid is 1-D over row tiles, sequential; the MAC state is VMEM scratch.
Validated in interpret mode against ref.mac_ref / ref.guard_copy_ref.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import MAC_PRIME, MAC_INIT

LANES = 128


def _fold_powers() -> np.ndarray:
    """PRIME^(127-i) mod 2^32 for the vectorized Horner fold."""
    p = np.uint64(MAC_PRIME)
    out = np.zeros(LANES, np.uint64)
    acc = np.uint64(1)
    for i in range(LANES - 1, -1, -1):
        out[i] = acc
        acc = (acc * p) & np.uint64(0xFFFFFFFF)
    return out.astype(np.uint32)


FOLD_POWERS = _fold_powers()


def _guard_kernel(tag_ref, expect_ref, powers_ref, in_ref, out_ref, mac_ref,
                  ok_ref, h, *, rows_per_tile):
    i = pl.program_id(0)
    n = pl.num_programs(0)

    @pl.when(i == 0)
    def _init():
        h[...] = (jnp.full((1, LANES), MAC_INIT, jnp.uint32)
                  + tag_ref[0].astype(jnp.uint32))

    tile = in_ref[...]                                  # (rows, 128) uint32
    acc = h[0, :]
    for r in range(rows_per_tile):                      # static unroll
        acc = acc * MAC_PRIME + tile[r, :]
    h[0, :] = acc
    out_ref[...] = tile                                 # the copy

    @pl.when(i == n - 1)
    def _final():
        mac = jnp.sum(h[0, :] * powers_ref[...], dtype=jnp.uint32)
        mac_ref[0] = mac
        ok_ref[0] = (mac == expect_ref[0].astype(jnp.uint32)).astype(jnp.int32)


def guard_copy_pallas(payload_u32, tag, expected_mac, *, rows_per_tile=256,
                      interpret=True):
    """payload (n, 128) uint32 with n % rows_per_tile == 0 (ops.py pads).
    Returns (copy, mac (1,) uint32, ok (1,) int32)."""
    n, lanes = payload_u32.shape
    assert lanes == LANES and payload_u32.dtype == jnp.uint32
    rt = min(rows_per_tile, n)
    assert n % rt == 0, (n, rt)
    grid = (n // rt,)
    kernel = functools.partial(_guard_kernel, rows_per_tile=rt)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),         # tag
            pl.BlockSpec((1,), lambda i: (0,)),         # expected mac
            pl.BlockSpec((LANES,), lambda i: (0,)),     # fold powers
            pl.BlockSpec((rt, LANES), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((rt, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, LANES), jnp.uint32),
            jax.ShapeDtypeStruct((1,), jnp.uint32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((1, LANES), jnp.uint32)],
        interpret=interpret,
    )(tag.reshape(1).astype(jnp.uint32), expected_mac.reshape(1).astype(jnp.uint32),
      jnp.asarray(FOLD_POWERS), payload_u32)
