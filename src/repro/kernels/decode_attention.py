"""Single-token decode attention as a Pallas TPU kernel.

The serving hot spot: one new query per sequence attending over a long KV
cache. Memory-bound by design — the cache is read exactly once per step —
so the kernel's job is to stream (S_cache, Dh) tiles through VMEM with the
online-softmax state in scratch and never materialize the (B, H, S) score
tensor in HBM (the jnp decode path writes it, visible in the decode cells'
memory terms).

Grid (B, H, nk), kv innermost; q (one row per (b,h)) stays resident.
Handles GQA via the k/v index_map (h → h//g) and masked cache slots /
SWA windows via the position vector (works for ring buffers, where
slot_pos carries absolute positions).

Validated in interpret mode against ref.attention_ref
(tests/test_kernels_decode.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import NEG_INF


def _decode_kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, out_ref,
                   m_ref, l_ref, acc_ref, *, causal, window, out_dtype):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qp = qpos_ref[0, 0]                                    # scalar position
    kp = kpos_ref[0, :]                                    # (kc,)
    mask = kp >= 0
    if causal:
        mask = mask & (kp <= qp)
    if window is not None:
        mask = mask & ((qp - kp) < window)

    @pl.when(jnp.any(mask))
    def _compute():
        q = q_ref[0, 0, 0, :].astype(jnp.float32)          # (Dh,)
        kb = k_ref[0, :, 0, :].astype(jnp.float32)         # (kc, Dh)
        vb = v_ref[0, :, 0, :].astype(jnp.float32)
        scale = q.shape[-1] ** -0.5
        s = kb @ q * scale                                 # (kc,)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[0, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        l_ref[0, 0] = l_ref[0, 0] * corr + jnp.sum(p)
        acc_ref[0, :] = acc_ref[0, :] * corr + p @ vb
        m_ref[0, 0] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        out = acc_ref[0, :] / jnp.maximum(l_ref[0, 0], 1e-30)
        out_ref[0, 0, 0, :] = out.astype(out_dtype)


def decode_attention_pallas(q, k, v, q_pos, kv_pos, *, causal=True,
                            window=None, kv_chunk=512, interpret=True):
    """q (B, 1, H, Dh); k/v (B, S, Hkv, Dh); q_pos (B, 1); kv_pos (B, S).
    Requires S % kv_chunk == 0 (ops.py pads). → (B, 1, H, Dh)."""
    B, one, H, Dh = q.shape
    assert one == 1
    S, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    kc = kv_chunk
    assert S % kc == 0, (S, kc)
    grid = (B, H, S // kc)
    kernel = functools.partial(_decode_kernel, causal=causal, window=window,
                               out_dtype=q.dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, ik: (b, 0)),            # q_pos
            pl.BlockSpec((1, kc), lambda b, h, ik: (b, ik)),          # kv_pos
            pl.BlockSpec((1, 1, 1, Dh), lambda b, h, ik: (b, 0, h, 0)),
            pl.BlockSpec((1, kc, 1, Dh), lambda b, h, ik: (b, ik, h // g, 0)),
            pl.BlockSpec((1, kc, 1, Dh), lambda b, h, ik: (b, ik, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, Dh), lambda b, h, ik: (b, 0, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 1, H, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),      # m
            pltpu.VMEM((1, 1), jnp.float32),      # l
            pltpu.VMEM((1, Dh), jnp.float32),     # acc
        ],
        interpret=interpret,
    )(q_pos.astype(jnp.int32), kv_pos.astype(jnp.int32), q, k, v)
