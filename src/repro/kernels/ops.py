"""Public kernel entry points with implementation selection.

impl choices:
  attention: "naive" (oracle, O(S²) memory — smoke/small only)
             "chunked" (flash_jnp custom_vjp twin — differentiable, what the
                        dry-run lowers; the default for train/prefill)
             "pallas"  (TPU kernel; interpret=True on CPU; fwd-only)
  ssd:       "ref" | "chunked" | "pallas"
  guard:     "ref" | "pallas"

The jnp paths are shape-polymorphic; pallas paths pad to block multiples here
so kernels only ever see divisible shapes.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels import flash_jnp as _fj
from repro.kernels import ssd_jnp as _sj
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas
from repro.kernels.mpk_guard import guard_copy_pallas, LANES

mac = _ref.mac_ref


def attention(q, k, v, q_pos, kv_pos, *, causal=True, window=None,
              impl="chunked", q_chunk=128, kv_chunk=128, interpret=True):
    if impl == "naive":
        return _ref.attention_ref(q, k, v, q_pos, kv_pos, causal=causal, window=window)
    if impl == "chunked":
        return _fj.flash_attention_jnp(q, k, v, q_pos, kv_pos, causal=causal,
                                       window=window, q_chunk=q_chunk, kv_chunk=kv_chunk)
    if impl == "pallas":
        B, Sq = q.shape[:2]
        qc = min(q_chunk, max(1, Sq))
        kc = min(kv_chunk, max(1, k.shape[1]))
        qp = _fj._pad_to(q_pos.astype(jnp.int32), 1, qc, -2)
        kp = _fj._pad_to(kv_pos.astype(jnp.int32), 1, kc, -1)
        out = flash_attention_pallas(
            _fj._pad_to(q, 1, qc, 0), _fj._pad_to(k, 1, kc, 0),
            _fj._pad_to(v, 1, kc, 0), qp, kp, causal=causal, window=window,
            q_chunk=qc, kv_chunk=kc, interpret=interpret)
        return out[:, :Sq]
    if impl == "pallas_decode":
        assert q.shape[1] == 1, "pallas_decode is the single-token path"
        kc = min(kv_chunk, max(1, k.shape[1]))
        kp = _fj._pad_to(kv_pos.astype(jnp.int32), 1, kc, -1)
        return decode_attention_pallas(
            q, _fj._pad_to(k, 1, kc, 0), _fj._pad_to(v, 1, kc, 0),
            q_pos, kp, causal=causal, window=window, kv_chunk=kc,
            interpret=interpret)
    raise ValueError(f"unknown attention impl {impl!r}")


def ssd(x, dt, A_log, B, C, D, init_state=None, *, chunk=128, impl="chunked",
        interpret=True):
    if impl == "ref":
        return _ref.ssd_ref(x, dt, A_log, B, C, D, init_state)
    if impl == "chunked":
        return _sj.ssd_chunked(x, dt, A_log, B, C, D, init_state, chunk=chunk)
    if impl == "pallas":
        S = x.shape[1]
        Q = min(chunk, S)
        xp = _sj._pad_seq(x, Q)
        dtp = _sj._pad_seq(dt, Q)       # dt=0 padding → identity steps
        Bp = _sj._pad_seq(B, Q)
        Cp = _sj._pad_seq(C, Q)
        y, sf = ssd_scan_pallas(xp, dtp, A_log, Bp, Cp, D, init_state,
                                chunk=Q, interpret=interpret)
        return y[:, :S], sf
    raise ValueError(f"unknown ssd impl {impl!r}")


def ssd_decode_step(x_t, dt_t, A_log, B_t, C_t, D, state):
    return _sj.ssd_decode_step(x_t, dt_t, A_log, B_t, C_t, D, state)


def guard_copy(payload_u32, tag, expected_mac, *, rows_per_tile=256,
               impl="pallas", interpret=True):
    """(copy, mac, ok). The tile size is snapped down to the largest divisor
    of the row count ≤ rows_per_tile, so the kernel never pads (padding
    would change the Horner MAC). Frames are LANES-padded by core.framing,
    so real row counts are benign; a pathological prime degrades to rt=1,
    never to a wrong MAC."""
    if impl == "ref":
        return _ref.guard_copy_ref(payload_u32, tag, expected_mac)
    n = payload_u32.shape[0]
    rt = min(rows_per_tile, max(1, n))
    while n % rt:
        rt -= 1
    return guard_copy_pallas(payload_u32, tag, expected_mac,
                             rows_per_tile=rt, interpret=interpret)


def guard_mac_batch(stack_u32, tag, *, rows_per_tile=256, impl="pallas",
                    interpret=True):
    """(N, rows, 128) uint32 stack of frame payloads → (N,) uint32 MACs.

    The device side of the batched data plane: N frames MAC'd in one fused
    launch instead of N scalar kernel calls. ``impl="jnp"`` is the
    shape-polymorphic twin (what the dry-run lowers); both are bit-identical
    to the host path ``core.framing.mac_batch``. Zero-row frames (empty
    payloads) fall through to the jnp twin — a zero-size grid would skip the
    kernel epilogue entirely."""
    from repro.kernels.mpk_guard import mac_batch_jnp, mac_batch_pallas
    if impl == "jnp" or stack_u32.shape[1] == 0:
        return mac_batch_jnp(stack_u32, tag)
    if impl == "pallas":
        return mac_batch_pallas(stack_u32, tag, rows_per_tile=rows_per_tile,
                                interpret=interpret)
    raise ValueError(f"unknown guard_mac_batch impl {impl!r}")


def guard_mac_init(tag):
    """Fresh (LANES,) uint32 streaming-MAC state for ``tag``."""
    from repro.kernels.mpk_guard import mac_init_state
    return mac_init_state(tag)


def guard_mac_update(h, block_u32, *, rows_per_tile=256, impl="pallas",
                     interpret=True):
    """Advance a streaming-MAC state over one (m, 128) uint32 block.

    The device side of the zero-copy seal path: a payload too large to
    stage is MAC'd block-wise as each chunk lands, with the Horner state
    carried between launches. ``impl="jnp"`` is the shape-polymorphic twin.
    Both are bit-identical to the one-shot ``mac_ref`` over the
    concatenated blocks (and to ``core.framing.mac_update_np``)."""
    from repro.kernels.mpk_guard import mac_update_jnp, mac_update_pallas
    if impl == "jnp" or block_u32.shape[0] == 0:
        return mac_update_jnp(h, block_u32)
    if impl == "pallas":
        return mac_update_pallas(h, block_u32, rows_per_tile=rows_per_tile,
                                 interpret=interpret)
    raise ValueError(f"unknown guard_mac_update impl {impl!r}")


def guard_mac_finalize(h):
    """Fold a streaming-MAC state to the single uint32 MAC word."""
    from repro.kernels.mpk_guard import mac_finalize
    return mac_finalize(h)
