# Pallas TPU kernels for the compute hot-spots this system optimizes:
#   flash_attention.py   — blockwise online-softmax attention (causal/SWA/GQA)
#   decode_attention.py  — single-token cache attention (serving decode path)
#   ssd_scan.py          — Mamba2 SSD chunked scan (state carried in VMEM)
#   mpk_guard.py         — MPKLink protected copy (tag check + MAC + copy fused)
# ops.py = jit'd public wrappers with impl selection; ref.py = pure-jnp oracles.
from repro.kernels import ops, ref
from repro.kernels.ops import attention, ssd, ssd_decode_step, guard_copy, mac

__all__ = ["ops", "ref", "attention", "ssd", "ssd_decode_step", "guard_copy", "mac"]
