"""Mamba2 SSD chunked scan as a Pallas TPU kernel.

Grid (B, H, nc) with the chunk dim innermost/sequential: the (P, N) SSM state
for a fixed (b, h) lives in VMEM scratch and is carried across chunk steps —
the inter-chunk recurrence never touches HBM. Each chunk step does three
MXU matmuls (C·Bᵀ → Q×Q, att·x → Q×P, state in/out → Q×N·N×P-shaped work)
on (Q=128)-aligned tiles, which is exactly the SSD restructuring insight:
turn an O(S) elementwise recurrence into O(S/Q) matmul steps.

B/C group sharing (n_groups G ≤ H) is handled in the index_map (h → h // R),
same trick as GQA in the flash kernel — no repeat materialized.

Validated in interpret mode against ref.ssd_ref (tests/test_kernels_ssd.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, alog_ref, b_ref, c_ref, d_ref, s0_ref,
                y_ref, sf_ref, state, *, out_dtype):
    c = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(c == 0)
    def _init():
        state[...] = s0_ref[0, 0].astype(jnp.float32)

    xb = x_ref[0, :, 0, :].astype(jnp.float32)          # (Q, P)
    dtb = dt_ref[0, :, 0].astype(jnp.float32)           # (Q,)
    Bb = b_ref[0, :, 0, :].astype(jnp.float32)          # (Q, N)
    Cb = c_ref[0, :, 0, :].astype(jnp.float32)          # (Q, N)
    A = -jnp.exp(alog_ref[0].astype(jnp.float32))       # scalar
    Dc = d_ref[0].astype(jnp.float32)

    la = dtb * A                                        # (Q,) ≤ 0
    cum = jnp.cumsum(la)
    Q = xb.shape[0]

    s_in = state[...]
    # intra-chunk quadratic form
    dec = jnp.exp(cum[:, None] - cum[None, :])
    tri = jnp.tril(jnp.ones((Q, Q), jnp.float32))
    cb = jax.lax.dot_general(Cb, Bb, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    att = cb * dec * tri * dtb[None, :]
    y = jnp.dot(att, xb, preferred_element_type=jnp.float32)
    # inter-chunk contribution: exp(L_i) · C_i · S_inᵀ
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cb, s_in, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    y += Dc * xb
    y_ref[0, :, 0, :] = y.astype(out_dtype)

    # state carry: S_out = exp(L_Q)·S_in + Σ_j exp(L_Q − L_j)·dt_j·(x_j ⊗ B_j)
    w = jnp.exp(cum[-1] - cum) * dtb                    # (Q,)
    s_c = jax.lax.dot_general(w[:, None] * xb, Bb, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)   # (P, N)
    state[...] = jnp.exp(cum[-1]) * s_in + s_c

    @pl.when(c == nc - 1)
    def _final():
        sf_ref[0, 0] = state[...]


def ssd_scan_pallas(x, dt, A_log, B, C, D, init_state=None, *, chunk=128,
                    interpret=True):
    """Shapes as ref.ssd_ref; requires S % chunk == 0 (ops.py pads).
    Returns (y, final_state (B,H,P,N) f32)."""
    Bb, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    R = H // G
    Q = chunk
    assert S % Q == 0, (S, Q)
    nc = S // Q
    if init_state is None:
        init_state = jnp.zeros((Bb, H, P, N), jnp.float32)

    grid = (Bb, H, nc)
    kernel = functools.partial(_ssd_kernel, out_dtype=x.dtype)
    y, sf = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0)),       # x
            pl.BlockSpec((1, Q, 1), lambda b, h, c: (b, c, h)),             # dt
            pl.BlockSpec((1,), lambda b, h, c: (h,)),                        # A_log
            pl.BlockSpec((1, Q, 1, N), lambda b, h, c: (b, c, h // R, 0)),  # B
            pl.BlockSpec((1, Q, 1, N), lambda b, h, c: (b, c, h // R, 0)),  # C
            pl.BlockSpec((1,), lambda b, h, c: (h,)),                        # D
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),       # init_state
        ],
        out_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0)),       # y
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),       # final_state
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((Bb, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A_log, B, C, D, init_state)
    return y, sf
