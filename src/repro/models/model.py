"""Top-level model API: init / forward / loss / prefill / decode for all
ten architecture families.

Batch contract (see launch/dryrun.py input_specs):
  train/prefill: {"tokens" (B,S) i32, "labels" (B,S) i32}
                 + vlm: {"vision_embeds" (B, Vtok, Vdim)} — replaces the
                   first Vtok sequence positions (labels there are masked)
                 + audio: {"frames" (B, enc_ctx, d_model)} — encoder input
  decode:        serve_step(params, state, token (B,1)) with ``state`` built
                 by init_decode_state (caches sized for the cell's seq_len).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import kvcache
from repro.models import ssm as ssm_mod
from repro.models import transformer as tf
from repro.models.layers import (apply_norm, dense_init, embed_tokens,
                                 init_embed, init_mlp, init_norm, lm_logits)
from repro.models.transformer import Impl


def sinusoid(seq_len: int, d_model: int, offset=0):
    pos = jnp.arange(seq_len, dtype=jnp.float32) + offset
    half = d_model // 2
    freq = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos[:, None] * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key):
    ks = jax.random.split(key, 8)
    params = {"embed": init_embed(cfg, ks[0]), "final_norm": init_norm(cfg, ks[1])}

    if cfg.family == "hybrid":
        def init_mamba_block(k):
            k1, k2 = jax.random.split(k)
            return {"ln1": init_norm(cfg, k1), "mamba": ssm_mod.init_mamba(cfg, k2)}
        params["blocks"] = tf.init_stack(cfg, ks[2], cfg.num_layers, init_mamba_block)
        sk = jax.random.split(ks[3], 4)
        params["shared_attn"] = {
            "ln1": init_norm(cfg, sk[0]), "attn": attn_mod.init_attn(cfg, sk[1]),
            "ln2": init_norm(cfg, sk[2]), "ffn": init_mlp(cfg, sk[3]),
        }
    elif cfg.enc_dec:
        params["enc_blocks"] = tf.init_stack(cfg, ks[2], cfg.enc_layers)
        params["blocks"] = tf.init_stack(
            cfg, ks[3], cfg.num_layers, lambda k: tf.init_dec_block(cfg, k))
        params["enc_final_norm"] = init_norm(cfg, ks[4])
    else:
        params["blocks"] = tf.init_stack(cfg, ks[2], cfg.num_layers)

    if cfg.vision_tokens:
        params["vision_proj"] = {
            "w": dense_init(ks[5], (cfg.vision_dim, cfg.d_model)),
            "b": jnp.zeros((cfg.d_model,), jnp.float32),
        }
    return params


# ---------------------------------------------------------------------------
# forward (train / prefill logits)
# ---------------------------------------------------------------------------

def _embed_input(cfg: ModelConfig, params, batch, dtype):
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed_tokens(params["embed"], tokens, dtype)
    if cfg.vision_tokens and "vision_embeds" in batch:
        ve = batch["vision_embeds"].astype(dtype)
        vp = params["vision_proj"]
        v = ve @ vp["w"].astype(dtype) + vp["b"].astype(dtype)
        x = jnp.concatenate([v, x[:, cfg.vision_tokens:]], axis=1)
    return x


def encode(cfg: ModelConfig, params, frames, *, impl: Impl):
    """Audio encoder: precomputed frame embeddings (stub frontend) + sinusoid."""
    B, Se, D = frames.shape
    x = frames + sinusoid(Se, D).astype(frames.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32)[None], (B, Se))
    x, _ = tf.apply_stack(cfg, params["enc_blocks"], x, positions=positions,
                          impl=impl, causal=False, use_rope=False)
    return apply_norm(cfg, params["enc_final_norm"], x)


def forward(cfg: ModelConfig, params, batch, *, impl: Impl = Impl(),
            dtype=jnp.bfloat16, last_only: bool = False):
    """→ (logits (B,S,V) f32, aux dict). ``last_only`` computes logits for the
    final position only (serving prefill: the next-token head is all a
    prefill needs, and it keeps the (B,S,V) tensor out of memory)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    x = _embed_input(cfg, params, batch, dtype)

    if cfg.enc_dec:
        enc_out = encode(cfg, params, batch["frames"].astype(dtype), impl=impl)
        x = x + sinusoid(S, cfg.d_model).astype(dtype)[None]
        x, aux = tf.apply_dec_stack(cfg, params["blocks"], x, enc_out,
                                    positions=positions, impl=impl)
    elif cfg.family == "hybrid":
        x, aux = tf.apply_hybrid_stack(cfg, params["blocks"], params["shared_attn"],
                                       x, positions=positions, impl=impl)
    else:
        x, aux = tf.apply_stack(cfg, params["blocks"], x, positions=positions,
                                impl=impl)

    if last_only:
        x = x[:, -1:]
    x = apply_norm(cfg, params["final_norm"], x)
    logits = lm_logits(cfg, params["embed"], x)
    return logits, aux


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def loss_fn(cfg: ModelConfig, params, batch, *, impl: Impl = Impl(),
            dtype=jnp.bfloat16):
    """Next-token CE (labels == -1 masked) + MoE aux losses. → (loss, metrics)."""
    logits, aux = forward(cfg, params, batch, impl=impl, dtype=dtype)
    labels = batch["labels"]
    logits = logits[:, :-1].astype(jnp.float32)
    targets = labels[:, 1:]
    mask = (targets >= 0).astype(jnp.float32)
    tgt = jnp.maximum(targets, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    ce = jnp.sum((lse - picked) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    loss = ce
    metrics = {"ce": ce, **aux}
    for k in ("moe_lb_loss", "moe_z_loss"):
        if k in aux:
            loss = loss + aux[k]
    metrics["loss"] = loss
    return loss, metrics


# ---------------------------------------------------------------------------
# decode state + step (serving)
# ---------------------------------------------------------------------------

def _attn_cache_spec(cfg: ModelConfig, batch: int, max_seq: int, dtype):
    """Ring cache when SWA is enabled and the context exceeds the window."""
    if cfg.swa_window is not None and max_seq > cfg.swa_window:
        return kvcache.init_ring_cache(batch, cfg.swa_window, cfg.kv_heads_eff,
                                       cfg.head_dim, dtype)
    return kvcache.init_dense_cache(batch, max_seq, cfg.kv_heads_eff,
                                    cfg.head_dim, dtype)


def init_decode_state(cfg: ModelConfig, params, batch: int, max_seq: int, *,
                      dtype=jnp.bfloat16, impl: Impl = Impl(),
                      enc_out: Optional[jnp.ndarray] = None):
    s = cfg.ssm
    if cfg.family == "ssm":
        one = kvcache.init_ssm_state(batch, cfg.ssm_heads, s.head_dim, s.d_state,
                                     s.conv_width,
                                     cfg.d_inner + 2 * s.n_groups * s.d_state, dtype)
        caches = kvcache.stack_caches([one] * cfg.num_layers)
    elif cfg.family == "hybrid":
        one = kvcache.init_ssm_state(batch, cfg.ssm_heads, s.head_dim, s.d_state,
                                     s.conv_width,
                                     cfg.d_inner + 2 * s.n_groups * s.d_state, dtype)
        n_seg = cfg.num_layers // cfg.attn_every
        attn_one = _attn_cache_spec(cfg, batch, max_seq, dtype)
        caches = {
            "mamba": kvcache.stack_caches([one] * cfg.num_layers),
            "attn": kvcache.stack_caches([attn_one] * n_seg),
        }
    elif cfg.enc_dec:
        assert enc_out is not None, "enc-dec decode state needs encoder output"
        self_one = kvcache.init_dense_cache(batch, max_seq, cfg.kv_heads_eff,
                                            cfg.head_dim, dtype)

        def cross_kv(layer_p):
            k = jnp.einsum("bsd,dhe->bshe", enc_out,
                           layer_p["cross"]["wk"].astype(enc_out.dtype))
            v = jnp.einsum("bsd,dhe->bshe", enc_out,
                           layer_p["cross"]["wv"].astype(enc_out.dtype))
            return {"k": k, "v": v}

        cross = jax.vmap(cross_kv)(params["blocks"])      # map over L axis
        caches = {
            "self": kvcache.stack_caches([self_one] * cfg.num_layers),
            "cross": cross,
        }
    else:
        one = _attn_cache_spec(cfg, batch, max_seq, dtype)
        caches = kvcache.stack_caches([one] * cfg.num_layers)
    return {"caches": caches, "pos": jnp.int32(0)}


def decode_step(cfg: ModelConfig, params, state, token, *, impl: Impl = Impl(),
                dtype=jnp.bfloat16):
    """token (B,1) i32 at position state["pos"] → (logits (B,1,V) f32, state)."""
    pos = state["pos"]
    x = embed_tokens(params["embed"], token, dtype)

    if cfg.enc_dec:
        half = cfg.d_model // 2
        freq = jnp.exp(-math.log(10000.0)
                       * jnp.arange(half, dtype=jnp.float32) / half)
        ang = pos.astype(jnp.float32)[..., None] * freq      # scalar or (B,)
        pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
        pe = pe[None, None] if pe.ndim == 1 else pe[:, None]
        x = x + pe.astype(dtype)
        caches = state["caches"]
        x, new_caches = tf.decode_dec_stack(
            cfg, params["blocks"],
            {"self": caches["self"], "cross": caches["cross"]}, x, pos, impl=impl)
        new_caches = {"self": new_caches["self"], "cross": caches["cross"]}
    elif cfg.family == "hybrid":
        x, new_caches = tf.decode_hybrid_stack(cfg, params["blocks"],
                                               params["shared_attn"],
                                               state["caches"], x, pos, impl=impl)
    else:
        x, new_caches = tf.decode_stack(cfg, params["blocks"], state["caches"],
                                        x, pos, impl=impl,
                                        use_rope=not cfg.enc_dec)

    x = apply_norm(cfg, params["final_norm"], x)
    logits = lm_logits(cfg, params["embed"], x)
    return logits, {"caches": new_caches, "pos": pos + 1}
