"""Top-k routed mixture-of-experts FFN (GShard/Switch-style dense dispatch).

Dispatch/combine are expressed as einsums against a (T, E, C) one-hot
dispatch tensor — the formulation XLA SPMD partitions well (dispatch
contraction lowers to an all-to-all-free sharded matmul under TP; the true
EP all_to_all variant is the MPKLink-fabric hillclimb, core/fabric.py).

Capacity: C = ceil(capacity_factor · T · k / E); overflow tokens drop to the
residual path (standard). Aux losses: Switch load-balance + router z-loss.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, activation


def init_moe(cfg: ModelConfig, key):
    m = cfg.moe
    D, F, E = cfg.d_model, cfg.d_ff, m.num_experts
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (D, E)),
        "gate": dense_init(ks[1], (E, D, F), in_axis_size=D),
        "up": dense_init(ks[2], (E, D, F), in_axis_size=D),
        "down": dense_init(ks[3], (E, F, D), in_axis_size=F),
    }


def _route(cfg: ModelConfig, p, x_flat, min_capacity: int = 1):
    """x_flat (T, D) → (dispatch (T,E,C), combine (T,E,C), aux dict)."""
    m = cfg.moe
    T = x_flat.shape[0]
    E, k = m.num_experts, m.top_k
    C = max(min_capacity, int(m.capacity_factor * T * k / E))

    logits = (x_flat @ p["router"].astype(x_flat.dtype)).astype(jnp.float32)  # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)

    # top-k choices per token
    top_p, top_e = jax.lax.top_k(probs, k)                    # (T,k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    # position of each (token, choice) within its expert queue, choice-major so
    # first choices fill capacity before second choices steal slots
    disp = jnp.zeros((T, E, C), jnp.float32)
    comb = jnp.zeros((T, E, C), jnp.float32)
    fill = jnp.zeros((E,), jnp.int32)
    for j in range(k):                                        # static, k=2
        e_j = top_e[:, j]                                     # (T,)
        onehot = jax.nn.one_hot(e_j, E, dtype=jnp.int32)      # (T,E)
        pos_in_e = jnp.cumsum(onehot, axis=0) - onehot + fill[None, :]  # (T,E)
        pos = jnp.sum(pos_in_e * onehot, axis=1)              # (T,)
        keep = pos < C
        slot = jax.nn.one_hot(e_j, E, dtype=jnp.float32)[:, :, None] * \
            jax.nn.one_hot(jnp.where(keep, pos, 0), C, dtype=jnp.float32)[:, None, :]
        slot = slot * keep[:, None, None]
        disp = disp + slot
        comb = comb + slot * top_p[:, j][:, None, None]
        fill = fill + jnp.sum(onehot, axis=0)

    # aux losses
    frac_tokens = jnp.mean(jax.nn.one_hot(top_e[:, 0], E, dtype=jnp.float32), axis=0)
    mean_probs = jnp.mean(probs, axis=0)
    lb = E * jnp.sum(frac_tokens * mean_probs) * m.load_balance_loss
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * m.router_z_loss
    dropped = 1.0 - jnp.sum(disp) / (T * k)
    return disp, comb, {"moe_lb_loss": lb, "moe_z_loss": z, "moe_drop_frac": dropped}


def _moe_ffn_flat(cfg: ModelConfig, p, xf, min_capacity: int = 1
                  ) -> Tuple[jnp.ndarray, dict]:
    """One routing group: xf (T, D) → (out (T, D), aux)."""
    act = activation(cfg.act)
    disp, comb, aux = _route(cfg, p, xf, min_capacity)
    d = disp.astype(xf.dtype)
    expert_in = jnp.einsum("tec,td->ecd", d, xf)              # (E,C,D)
    h = act(jnp.einsum("ecd,edf->ecf", expert_in, p["gate"].astype(xf.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", expert_in, p["up"].astype(xf.dtype))
    out_e = jnp.einsum("ecf,efd->ecd", h, p["down"].astype(xf.dtype))
    y = jnp.einsum("tec,ecd->td", comb.astype(xf.dtype), out_e)
    return y, aux


def apply_moe(cfg: ModelConfig, p, x) -> Tuple[jnp.ndarray, dict]:
    """x (B, S, D) → (out (B,S,D), aux).

    With ``moe.group_size`` set, tokens route in independent groups (GShard):
    the (T,E,C) dispatch einsums are T·E·C_g·D per group — LINEAR in total
    tokens — and stay local to each group's data shard (no cross-shard
    reduction in dispatch/combine). The ungrouped baseline is quadratic and
    all-reduces every dispatch (measured 47 TB/step on mixtral train_4k)."""
    B, S, D = x.shape
    T = B * S
    # decode (S == 1) never drops tokens: capacity covers the worst case so
    # serving matches the full-sequence forward exactly (test_models.py)
    min_cap = T if S == 1 else 1
    g = cfg.moe.group_size
    if not g or T <= g:
        y, aux = _moe_ffn_flat(cfg, p, x.reshape(T, D), min_cap)
        return y.reshape(B, S, D), aux
    assert T % g == 0, (T, g)
    xg = x.reshape(T // g, g, D)

    def per_group(xf):
        return _moe_ffn_flat(cfg, p, xf)

    y, aux = jax.vmap(per_group)(xg)
    aux = {k: jnp.mean(v) for k, v in aux.items()}
    return y.reshape(B, S, D), aux
