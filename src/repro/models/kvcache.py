"""KV-cache / recurrent-state structures for serving.

Three cache kinds, all pure pytrees:

* ``dense``  — (B, S_max, H_kv, Dh) K/V per layer; supports full and windowed
               attention; sequence dim is the context-parallel shard axis.
* ``ring``   — (B, W, H_kv, Dh) sliding-window ring buffer (SWA archs at 500k:
               O(W) memory instead of O(S)). Slot positions are tracked so
               masking stays exact.
* ``ssm``    — Mamba2 conv tail + SSD state, O(1) in sequence length.

Caches for a layer stack are stacked on a leading L axis and scanned.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


# -- dense ------------------------------------------------------------------

def init_dense_cache(batch: int, max_seq: int, n_kv: int, head_dim: int, dtype):
    return {
        "k": jnp.zeros((batch, max_seq, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, max_seq, n_kv, head_dim), dtype),
    }


def dense_cache_insert(cache, k_new, v_new, pos: jnp.ndarray):
    """Insert (B, S_new, H, D) at sequence offset ``pos`` (scalar int32)."""
    idx = (0, pos, 0, 0)
    return {
        "k": jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype), idx),
        "v": jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype), idx),
    }


def dense_cache_positions(cache, length: jnp.ndarray):
    """kv positions (S_max,) with slots >= length masked as -1."""
    s = cache["k"].shape[1]
    pos = jnp.arange(s, dtype=jnp.int32)
    return jnp.where(pos < length, pos, -1)


def dense_cache_insert_rows(cache, k_new, v_new, pos_b: jnp.ndarray):
    """Per-slot insert for continuous batching: row b gets its token at its
    own position pos_b[b]. k_new/v_new (B, 1, H, D); pos_b (B,) int32."""
    def one(c, x, p):
        return jax.lax.dynamic_update_slice(c, x.astype(c.dtype), (p, 0, 0))
    k = jax.vmap(one)(cache["k"], k_new, pos_b.astype(jnp.int32))
    v = jax.vmap(one)(cache["v"], v_new, pos_b.astype(jnp.int32))
    return {"k": k, "v": v}


def dense_cache_positions_rows(cache, lengths: jnp.ndarray):
    """(B, S_max) kv positions with per-row valid lengths."""
    s = cache["k"].shape[1]
    pos = jnp.arange(s, dtype=jnp.int32)[None]
    return jnp.where(pos < lengths.astype(jnp.int32)[:, None], pos, -1)


# -- ring (SWA) ---------------------------------------------------------------

def init_ring_cache(batch: int, window: int, n_kv: int, head_dim: int, dtype):
    return {
        "k": jnp.zeros((batch, window, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, window, n_kv, head_dim), dtype),
        "slot_pos": jnp.full((window,), -1, jnp.int32),   # absolute position per slot
    }


def ring_cache_insert(cache, k_new, v_new, pos: jnp.ndarray):
    """Insert a single token (B, 1, H, D) at absolute position ``pos``."""
    w = cache["k"].shape[1]
    slot = jnp.mod(pos, w)
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype), (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype), (0, slot, 0, 0))
    sp = jax.lax.dynamic_update_slice(cache["slot_pos"], pos[None].astype(jnp.int32), (slot,))
    return {"k": k, "v": v, "slot_pos": sp}


# -- ssm ----------------------------------------------------------------------

def init_ssm_state(batch: int, n_heads: int, head_dim: int, d_state: int,
                   conv_width: int, conv_channels: int, dtype):
    return {
        "ssd": jnp.zeros((batch, n_heads, head_dim, d_state), jnp.float32),
        "conv": jnp.zeros((batch, conv_width - 1, conv_channels), dtype),
    }


# -- assembly -----------------------------------------------------------------

def stack_caches(caches):
    """[cache_pytree] * L → one pytree with leading L axis (scan-ready)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *caches)
