"""Block assembly and scan-over-layers stacks for every family.

Layer parameters are stacked on a leading L axis (init via vmap over keys)
and consumed with lax.scan — the HLO contains ONE block body regardless of
depth, which keeps XLA compile time flat across the 4..64-layer archs and is
what makes the 512-device dry-run tractable. Activation rematerialization
wraps the scan body (``remat="block"`` saves only block boundaries).

Families:
  dense/vlm : [attn → ffn] × L
  moe       : [attn → moe-ffn] × L (+ aux losses accumulated through the scan)
  ssm       : [mamba2] × L
  hybrid    : segments of ``attn_every`` mamba blocks with a SHARED attention
              block applied between segments (zamba2)
  audio     : encoder [attn → ffn] × Le, decoder [self → cross → ffn] × Ld
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import kvcache
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import apply_mlp, apply_norm, init_mlp, init_norm


@dataclass(frozen=True)
class Impl:
    """Kernel implementation selection (see kernels/ops.py).

    ``act_dp``: mesh axes the activation batch dim is sharded over. When set,
    scan-over-layers bodies re-anchor x with a sharding constraint — without
    it GSPMD may leave the while-loop carry replicated and compute every
    layer redundantly on all devices (measured 256× on grok prefill)."""
    attention: str = "chunked"
    decode_attention: str = "naive"
    ssd: str = "chunked"
    q_chunk: int = 128
    kv_chunk: int = 128
    remat: bool = True
    act_dp: Optional[tuple] = None

    def anchor(self, x):
        if self.act_dp is None:
            return x
        from jax.sharding import PartitionSpec as P
        dpe = self.act_dp if len(self.act_dp) > 1 else self.act_dp[0]
        return jax.lax.with_sharding_constraint(
            x, P(dpe, *([None] * (x.ndim - 1))))


def zero_aux(cfg: ModelConfig):
    if cfg.moe:
        return {"moe_lb_loss": jnp.float32(0), "moe_z_loss": jnp.float32(0),
                "moe_drop_frac": jnp.float32(0)}
    return {}


def _add_aux(a, b):
    return {k: a[k] + b[k] for k in a}


# ---------------------------------------------------------------------------
# single blocks
# ---------------------------------------------------------------------------

def init_block(cfg: ModelConfig, key):
    ks = jax.random.split(key, 4)
    if cfg.family == "ssm":
        return {"ln1": init_norm(cfg, ks[0]), "mamba": ssm_mod.init_mamba(cfg, ks[1])}
    p = {"ln1": init_norm(cfg, ks[0]), "attn": attn_mod.init_attn(cfg, ks[1]),
         "ln2": init_norm(cfg, ks[2])}
    if cfg.moe:
        p["ffn"] = moe_mod.init_moe(cfg, ks[3])
    else:
        p["ffn"] = init_mlp(cfg, ks[3])
    return p


def apply_block(cfg: ModelConfig, p, x, *, positions, impl: Impl,
                causal=True, use_rope=True):
    """Full-sequence block. Returns (x, aux)."""
    aux = zero_aux(cfg)
    if cfg.family == "ssm":
        x = x + ssm_mod.apply_mamba(cfg, p["mamba"], apply_norm(cfg, p["ln1"], x),
                                    impl=impl.ssd)
        return x, aux
    h = attn_mod.apply_attn(cfg, p["attn"], apply_norm(cfg, p["ln1"], x),
                            positions=positions, causal=causal, use_rope=use_rope,
                            impl=impl.attention, q_chunk=impl.q_chunk,
                            kv_chunk=impl.kv_chunk)
    x = x + h
    h = apply_norm(cfg, p["ln2"], x)
    if cfg.moe:
        h, aux = moe_mod.apply_moe(cfg, p["ffn"], h)
    else:
        h = apply_mlp(cfg, p["ffn"], h)
    return x + h, aux


# ---------------------------------------------------------------------------
# stacked init
# ---------------------------------------------------------------------------

def init_stack(cfg: ModelConfig, key, n_layers: int, init_one=None):
    init_one = init_one or (lambda k: init_block(cfg, k))
    keys = jax.random.split(key, n_layers)
    return jax.vmap(init_one)(keys)


# ---------------------------------------------------------------------------
# forward stacks (train / prefill without cache)
# ---------------------------------------------------------------------------

def apply_stack(cfg: ModelConfig, stacked, x, *, positions, impl: Impl,
                causal=True, use_rope=True):
    def body(carry, layer_p):
        h, aux = carry
        h = impl.anchor(h)
        h, aux_l = apply_block(cfg, layer_p, h, positions=positions, impl=impl,
                               causal=causal, use_rope=use_rope)
        return (h, _add_aux(aux, aux_l)), None

    if impl.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (x, aux), _ = jax.lax.scan(body, (impl.anchor(x), zero_aux(cfg)), stacked)
    return x, aux


def apply_hybrid_stack(cfg: ModelConfig, mamba_stack, shared_block, x, *,
                       positions, impl: Impl):
    """zamba2: segments of ``attn_every`` mamba layers, shared attn between."""
    L, every = cfg.num_layers, cfg.attn_every
    n_seg = L // every
    assert n_seg * every == L, (L, every)
    seg_params = jax.tree.map(lambda a: a.reshape((n_seg, every) + a.shape[1:]),
                              mamba_stack)

    def mamba_body(h, layer_p):
        h = h + ssm_mod.apply_mamba(cfg, layer_p["mamba"],
                                    apply_norm(cfg, layer_p["ln1"], h),
                                    impl=impl.ssd)
        return h, None

    def shared_attn(h):
        a = attn_mod.apply_attn(cfg, shared_block["attn"],
                                apply_norm(cfg, shared_block["ln1"], h),
                                positions=positions, causal=True, use_rope=True,
                                impl=impl.attention, q_chunk=impl.q_chunk,
                                kv_chunk=impl.kv_chunk)
        h = h + a
        h = h + apply_mlp(cfg, shared_block["ffn"],
                          apply_norm(cfg, shared_block["ln2"], h))
        return h

    def seg_body(h, seg_p):
        h = impl.anchor(h)
        h, _ = jax.lax.scan(mamba_body, h, seg_p)
        h = shared_attn(h)
        return h, None

    if impl.remat:
        seg_body = jax.checkpoint(seg_body, prevent_cse=False)
    x, _ = jax.lax.scan(seg_body, impl.anchor(x), seg_params)
    return x, zero_aux(cfg)


# ---------------------------------------------------------------------------
# decode blocks (single new token through a cached stack)
# ---------------------------------------------------------------------------

def decode_block(cfg: ModelConfig, p, x, cache, pos, *, impl: Impl,
                 use_rope=True):
    """Returns (x, new_cache)."""
    if cfg.family == "ssm":
        h, new_state = ssm_mod.decode_mamba(cfg, p["mamba"],
                                            apply_norm(cfg, p["ln1"], x), cache)
        return x + h, new_state
    h, new_cache = attn_mod.decode_attn(cfg, p["attn"], apply_norm(cfg, p["ln1"], x),
                                        cache, pos, use_rope=use_rope,
                                        impl=impl.decode_attention,
                                        kv_chunk=impl.kv_chunk)
    x = x + h
    h = apply_norm(cfg, p["ln2"], x)
    if cfg.moe:
        h, _ = moe_mod.apply_moe(cfg, p["ffn"], h)
    else:
        h = apply_mlp(cfg, p["ffn"], h)
    return x + h, new_cache


def decode_stack(cfg: ModelConfig, stacked, caches, x, pos, *, impl: Impl,
                 use_rope=True):
    """Scan the layer stack carrying the token activation, emitting new caches."""
    def body(h, inp):
        layer_p, cache_l = inp
        h, new_cache = decode_block(cfg, layer_p, h, cache_l, pos, impl=impl,
                                    use_rope=use_rope)
        return h, new_cache

    x, new_caches = jax.lax.scan(body, x, (stacked, caches))
    return x, new_caches


def decode_hybrid_stack(cfg: ModelConfig, mamba_stack, shared_block, caches,
                        x, pos, *, impl: Impl):
    """caches = {"mamba": stacked ssm states (L,...), "attn": stacked dense/ring
    caches (n_seg, ...) — one KV cache per shared-block insertion}."""
    L, every = cfg.num_layers, cfg.attn_every
    n_seg = L // every
    seg_params = jax.tree.map(lambda a: a.reshape((n_seg, every) + a.shape[1:]),
                              mamba_stack)
    seg_mamba_caches = jax.tree.map(
        lambda a: a.reshape((n_seg, every) + a.shape[1:]), caches["mamba"])

    def mamba_body(h, inp):
        layer_p, st = inp
        y, new_st = ssm_mod.decode_mamba(cfg, layer_p["mamba"],
                                         apply_norm(cfg, layer_p["ln1"], h), st)
        return h + y, new_st

    def seg_body(h, inp):
        seg_p, seg_c, attn_c = inp
        h, new_seg_c = jax.lax.scan(mamba_body, h, (seg_p, seg_c))
        a, new_attn_c = attn_mod.decode_attn(
            cfg, shared_block["attn"], apply_norm(cfg, shared_block["ln1"], h),
            attn_c, pos, use_rope=True, impl=impl.decode_attention,
            kv_chunk=impl.kv_chunk)
        h = h + a
        h = h + apply_mlp(cfg, shared_block["ffn"],
                          apply_norm(cfg, shared_block["ln2"], h))
        return h, (new_seg_c, new_attn_c)

    x, (new_mamba, new_attn) = jax.lax.scan(
        seg_body, x, (seg_params, seg_mamba_caches, caches["attn"]))
    new_caches = {
        "mamba": jax.tree.map(lambda a: a.reshape((L,) + a.shape[2:]), new_mamba),
        "attn": new_attn,
    }
    return x, new_caches


# ---------------------------------------------------------------------------
# encoder-decoder (whisper)
# ---------------------------------------------------------------------------

def init_dec_block(cfg: ModelConfig, key):
    ks = jax.random.split(key, 6)
    return {
        "ln1": init_norm(cfg, ks[0]), "attn": attn_mod.init_attn(cfg, ks[1]),
        "ln2": init_norm(cfg, ks[2]), "cross": attn_mod.init_attn(cfg, ks[3]),
        "ln3": init_norm(cfg, ks[4]), "ffn": init_mlp(cfg, ks[5]),
    }


def apply_dec_block(cfg: ModelConfig, p, x, enc_out, enc_pos, *, positions,
                    impl: Impl):
    h = attn_mod.apply_attn(cfg, p["attn"], apply_norm(cfg, p["ln1"], x),
                            positions=positions, causal=True, use_rope=False,
                            impl=impl.attention, q_chunk=impl.q_chunk,
                            kv_chunk=impl.kv_chunk)
    x = x + h
    h = attn_mod.apply_cross_attn(cfg, p["cross"], apply_norm(cfg, p["ln2"], x),
                                  enc_out, enc_pos, impl=impl.attention,
                                  q_chunk=impl.q_chunk, kv_chunk=impl.kv_chunk)
    x = x + h
    return x + apply_mlp(cfg, p["ffn"], apply_norm(cfg, p["ln3"], x))


def apply_dec_stack(cfg: ModelConfig, stacked, x, enc_out, *, positions, impl: Impl):
    B, Se = enc_out.shape[:2]
    enc_pos = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32)[None], (B, Se))

    def body(h, layer_p):
        return apply_dec_block(cfg, layer_p, impl.anchor(h), enc_out, enc_pos,
                               positions=positions, impl=impl), None

    if impl.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, impl.anchor(x), stacked)
    return x, {}


def decode_dec_block(cfg: ModelConfig, p, x, cache, pos, *, impl: Impl):
    """cache = {"self": dense cache, "cross": precomputed enc K/V}."""
    h, new_self = attn_mod.decode_attn(cfg, p["attn"], apply_norm(cfg, p["ln1"], x),
                                       cache["self"], pos, use_rope=False,
                                       impl=impl.decode_attention,
                                       kv_chunk=impl.kv_chunk)
    x = x + h
    h, _ = attn_mod.decode_attn(cfg, p["cross"], apply_norm(cfg, p["ln2"], x),
                                cache["cross"], pos, cross=True,
                                impl=impl.decode_attention, kv_chunk=impl.kv_chunk)
    x = x + h
    x = x + apply_mlp(cfg, p["ffn"], apply_norm(cfg, p["ln3"], x))
    return x, {"self": new_self, "cross": cache["cross"]}


def decode_dec_stack(cfg: ModelConfig, stacked, caches, x, pos, *, impl: Impl):
    def body(h, inp):
        layer_p, cache_l = inp
        h, new_cache = decode_dec_block(cfg, layer_p, h, cache_l, pos, impl=impl)
        return h, new_cache

    return jax.lax.scan(body, x, (stacked, caches))
