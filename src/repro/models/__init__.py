from repro.models.model import (decode_step, encode, forward, init_decode_state,
                                init_params, loss_fn)
from repro.models.transformer import Impl

__all__ = ["decode_step", "encode", "forward", "init_decode_state",
           "init_params", "loss_fn", "Impl"]
