"""Mamba2 block: fused in-projection, depthwise causal conv, SSD core,
gated RMS norm, out-projection. The SSD core lives in repro.kernels.ops.

Layout follows the Mamba2 reference: one in_proj produces
  [z (d_inner) | xBC (d_inner + 2·G·N) | dt (H)]
with the short causal conv applied to the xBC slab only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops as kops
from repro.models.layers import dense_init, rms_norm


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    di = cfg.d_inner
    H = cfg.ssm_heads
    conv_ch = di + 2 * s.n_groups * s.d_state
    return s, di, H, conv_ch


def init_mamba(cfg: ModelConfig, key):
    s, di, H, conv_ch = _dims(cfg)
    D = cfg.d_model
    ks = jax.random.split(key, 5)
    proj_out = 2 * di + 2 * s.n_groups * s.d_state + H
    # dt bias init so softplus(dt_bias) spans [dt_min, dt_max] (mamba2 init)
    u = jax.random.uniform(ks[2], (H,), jnp.float32)
    dt0 = jnp.exp(u * (jnp.log(s.dt_max) - jnp.log(s.dt_min)) + jnp.log(s.dt_min))
    dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))    # inverse softplus
    return {
        "in_proj": dense_init(ks[0], (D, proj_out)),
        "conv_w": dense_init(ks[1], (s.conv_width, conv_ch), in_axis_size=s.conv_width),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "dt_bias": dt_bias,
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "gate_norm": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[3], (di, D), in_axis_size=di),
    }


def _causal_conv(w, b, x, state=None):
    """Depthwise causal conv, width cw. x (B,S,C); state (B,cw-1,C) or None.
    Returns (y (B,S,C), new_state)."""
    cw = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i].astype(x.dtype) for i in range(cw))
    y = y + b.astype(x.dtype)
    new_state = xp[:, -(cw - 1):, :] if cw > 1 else state
    return y, new_state


def _split_proj(cfg: ModelConfig, zxbcdt):
    s, di, H, _ = _dims(cfg)
    gn = s.n_groups * s.d_state
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * gn]
    dt = zxbcdt[..., di + di + 2 * gn:]
    return z, xbc, dt


def _split_xbc(cfg: ModelConfig, xbc):
    s, di, H, _ = _dims(cfg)
    gn = s.n_groups * s.d_state
    xs = xbc[..., :di]
    Bm = xbc[..., di:di + gn]
    Cm = xbc[..., di + gn:]
    return xs, Bm, Cm


def apply_mamba(cfg: ModelConfig, p, x, *, impl="chunked"):
    """Full-sequence Mamba2 block (train / prefill, state discarded)."""
    y, _ = apply_mamba_with_state(cfg, p, x, conv_state=None, ssd_state=None, impl=impl)
    return y


def apply_mamba_with_state(cfg: ModelConfig, p, x, *, conv_state, ssd_state,
                           impl="chunked"):
    s, di, H, conv_ch = _dims(cfg)
    B, S, D = x.shape
    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    xbc, new_conv = _causal_conv(p["conv_w"], p["conv_b"], xbc, conv_state)
    xbc = jax.nn.silu(xbc)
    xs, Bm, Cm = _split_xbc(cfg, xbc)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])      # (B,S,H)
    xh = xs.reshape(B, S, H, s.head_dim)
    Bh = Bm.reshape(B, S, s.n_groups, s.d_state)
    Ch = Cm.reshape(B, S, s.n_groups, s.d_state)
    y, final_state = kops.ssd(xh, dt, p["A_log"], Bh, Ch, p["D"],
                              init_state=ssd_state, chunk=s.chunk_size, impl=impl)
    y = y.reshape(B, S, di)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = y @ p["out_proj"].astype(x.dtype)
    return out, {"conv": new_conv, "ssd": final_state}


def decode_mamba(cfg: ModelConfig, p, x_new, state):
    """Single-token recurrent step. x_new (B,1,D); state {"conv","ssd"}."""
    s, di, H, conv_ch = _dims(cfg)
    B = x_new.shape[0]
    zxbcdt = x_new @ p["in_proj"].astype(x_new.dtype)
    z, xbc, dt = _split_proj(cfg, zxbcdt)

    # conv state: (B, cw-1, C) rolling window
    cw = s.conv_width
    xp = jnp.concatenate([state["conv"].astype(xbc.dtype), xbc], axis=1)  # (B,cw,C)
    y = sum(xp[:, i:i + 1, :] * p["conv_w"][i].astype(xbc.dtype) for i in range(cw))
    xbc = jax.nn.silu(y + p["conv_b"].astype(xbc.dtype))
    new_conv = xp[:, 1:, :]

    xs, Bm, Cm = _split_xbc(cfg, xbc)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])     # (B,H)
    xh = xs[:, 0].reshape(B, H, s.head_dim)
    Bh = Bm[:, 0].reshape(B, s.n_groups, s.d_state)
    Ch = Cm[:, 0].reshape(B, s.n_groups, s.d_state)
    y_t, new_ssd = kops.ssd_decode_step(xh, dt, p["A_log"], Bh, Ch, p["D"],
                                        state["ssd"])
    y_t = y_t.reshape(B, 1, di)
    y_t = rms_norm(y_t * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = y_t @ p["out_proj"].astype(x_new.dtype)
    return out, {"conv": new_conv, "ssd": new_ssd}
