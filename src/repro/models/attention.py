"""GQA attention layer: projections, RoPE, qk-norm, cache handling.

The attention math itself lives in repro.kernels.ops (naive oracle /
chunked flash twin / Pallas kernel); this module owns parameters and the
KV-cache insert-then-attend protocol shared by train, prefill and decode.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops as kops
from repro.models import kvcache
from repro.models.layers import dense_init, rms_norm, rope_angles, apply_rope


def init_attn(cfg: ModelConfig, key):
    H, Hkv, Dh, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_model
    ks = jax.random.split(key, 4)
    Hp, Hkvp = cfg.q_heads_eff, cfg.kv_heads_eff
    if Hp == H and Hkvp == Hkv:
        p = {
            "wq": dense_init(ks[0], (D, H, Dh)),
            "wk": dense_init(ks[1], (D, Hkv, Dh)),
            "wv": dense_init(ks[2], (D, Hkv, Dh)),
            "wo": dense_init(ks[3], (H, Dh, D), in_axis_size=H * Dh),
        }
    else:
        # head padding (function-preserving): real heads keep their (kv, j)
        # group layout inside the padded (kv_pad, g_pad) grid; pad q rows and
        # pad wo rows are ZERO, so pad heads contribute exactly 0 to the
        # output. Pad kv heads produce k=v=0 keys only pad q heads see.
        g, gp = H // Hkv, Hp // Hkvp
        assert Hkvp >= Hkv and gp >= g, (H, Hkv, Hp, Hkvp)
        wq = jnp.zeros((D, Hkvp, gp, Dh), jnp.float32)
        wq = wq.at[:, :Hkv, :g].set(
            dense_init(ks[0], (D, Hkv, g, Dh)))
        wo = jnp.zeros((Hkvp, gp, Dh, D), jnp.float32)
        wo = wo.at[:Hkv, :g].set(
            dense_init(ks[3], (Hkv, g, Dh, D), in_axis_size=H * Dh))
        wk = jnp.zeros((D, Hkvp, Dh), jnp.float32)
        wk = wk.at[:, :Hkv].set(dense_init(ks[1], (D, Hkv, Dh)))
        wv = jnp.zeros((D, Hkvp, Dh), jnp.float32)
        wv = wv.at[:, :Hkv].set(dense_init(ks[2], (D, Hkv, Dh)))
        p = {"wq": wq.reshape(D, Hp, Dh), "wk": wk, "wv": wv,
             "wo": wo.reshape(Hp, Dh, D)}
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((Dh,), jnp.float32)
        p["k_norm"] = jnp.ones((Dh,), jnp.float32)
    return p


def _project_qkv(cfg: ModelConfig, p, x, x_kv=None):
    """x (B,S,D) → q (B,S,H,Dh), k/v (B,Skv,Hkv,Dh). x_kv for cross-attn."""
    xk = x if x_kv is None else x_kv
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhe->bshe", xk, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhe->bshe", xk, p["wv"].astype(x.dtype))
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _rope_qk(cfg: ModelConfig, q, k, q_pos, kv_pos):
    cq, sq = rope_angles(q_pos, cfg.head_dim, cfg.rope_theta)
    ck, sk = rope_angles(kv_pos, cfg.head_dim, cfg.rope_theta)
    # positions (B,S) → angles (B,S,half) → broadcast over heads (B,S,1,half)
    q = apply_rope(q, cq[:, :, None], sq[:, :, None])
    k = apply_rope(k, ck[:, :, None], sk[:, :, None])
    return q, k


def _out_proj(p, o):
    return jnp.einsum("bshe,hed->bsd", o, p["wo"].astype(o.dtype))


def apply_attn(cfg: ModelConfig, p, x, *, positions, causal=True,
               use_rope=True, impl="chunked", q_chunk=128, kv_chunk=128):
    """Full-sequence self-attention (train / prefill)."""
    q, k, v = _project_qkv(cfg, p, x)
    if use_rope:
        q, k = _rope_qk(cfg, q, k, positions, positions)
    o = kops.attention(q, k, v, positions, positions, causal=causal,
                       window=cfg.swa_window, impl=impl,
                       q_chunk=q_chunk, kv_chunk=kv_chunk)
    return _out_proj(p, o)


def apply_cross_attn(cfg: ModelConfig, p, x, enc_out, enc_pos, *,
                     impl="chunked", q_chunk=128, kv_chunk=128):
    """Decoder → encoder cross-attention (non-causal, no rope, no window)."""
    q, k, v = _project_qkv(cfg, p, x, x_kv=enc_out)
    B, S = x.shape[:2]
    q_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    o = kops.attention(q, k, v, q_pos, enc_pos, causal=False, window=None,
                       impl=impl, q_chunk=q_chunk, kv_chunk=kv_chunk)
    return _out_proj(p, o)


def prefill_attn(cfg: ModelConfig, p, x, cache, *, positions, use_rope=True,
                 impl="chunked", q_chunk=128, kv_chunk=128):
    """Self-attention that also fills a dense cache starting at position 0."""
    q, k, v = _project_qkv(cfg, p, x)
    if use_rope:
        q, k = _rope_qk(cfg, q, k, positions, positions)
    cache = kvcache.dense_cache_insert(cache, k, v, jnp.int32(0))
    o = kops.attention(q, k, v, positions, positions, causal=True,
                       window=cfg.swa_window, impl=impl,
                       q_chunk=q_chunk, kv_chunk=kv_chunk)
    return _out_proj(p, o), cache


def decode_attn(cfg: ModelConfig, p, x_new, cache, pos, *, use_rope=True,
                impl="naive", cross=False, kv_chunk=1024):
    """Single-token decode. x_new (B,1,D); ``pos`` = index of the new token —
    scalar int32 (uniform batch: the dry-run/serve_step fast path) or (B,)
    per-slot positions (continuous batching). Dense cache → insert then
    attend over valid slots; ring cache → insert at pos % W with absolute
    slot positions doing the masking (scalar pos only).
    ``cross=True`` skips insertion (static encoder KV)."""
    B = x_new.shape[0]
    per_slot = getattr(pos, "ndim", 0) == 1
    q, k, v = _project_qkv(cfg, p, x_new)
    if per_slot:
        q_pos = pos.astype(jnp.int32)[:, None]
    else:
        q_pos = jnp.broadcast_to(pos.astype(jnp.int32)[None, None], (B, 1))

    if cross:
        enc_len = cache["k"].shape[1]
        kv_pos = jnp.broadcast_to(jnp.arange(enc_len, dtype=jnp.int32)[None], (B, enc_len))
        o = kops.attention(q, cache["k"].astype(q.dtype), cache["v"].astype(q.dtype),
                           q_pos, kv_pos, causal=False, window=None, impl=impl,
                           kv_chunk=kv_chunk)
        return _out_proj(p, o), cache

    if use_rope:
        q, k = _rope_qk(cfg, q, k, q_pos, q_pos)

    if "slot_pos" in cache:                       # SWA ring buffer
        assert not per_slot, "ring caches require uniform decode positions"
        cache = kvcache.ring_cache_insert(cache, k, v, pos)
        kv_pos = jnp.broadcast_to(cache["slot_pos"][None], (B, cache["k"].shape[1]))
    elif per_slot:                                # dense, continuous batching
        cache = kvcache.dense_cache_insert_rows(cache, k, v, pos)
        kv_pos = kvcache.dense_cache_positions_rows(cache, pos + 1)
    else:                                         # dense, uniform
        cache = kvcache.dense_cache_insert(cache, k, v, pos)
        kv_pos = jnp.broadcast_to(
            kvcache.dense_cache_positions(cache, pos + 1)[None],
            (B, cache["k"].shape[1]))

    o = kops.attention(q, cache["k"].astype(q.dtype), cache["v"].astype(q.dtype),
                       q_pos, kv_pos, causal=True, window=cfg.swa_window,
                       impl=impl, kv_chunk=kv_chunk)
    return _out_proj(p, o), cache
