"""Core neural-net layers in pure JAX: norms, MLPs, embeddings, RoPE.

Parameters are plain pytrees (nested dicts of jnp arrays). Every function is
pure: ``apply_*(params, x, cfg)``. Layer stacks are stacked on a leading
``L`` axis and consumed with ``lax.scan`` (keeps HLO size O(1) in depth —
essential both for TPU compile times and for this CPU container).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, in_axis_size: Optional[int] = None, dtype=jnp.float32):
    """Truncated-normal fan-in init (LeCun-ish, matches common LM inits)."""
    fan_in = in_axis_size if in_axis_size is not None else shape[0]
    scale = 1.0 / max(1, fan_in) ** 0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x, weight, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    if weight is not None:
        y = y * weight.astype(jnp.float32)
    return y.astype(dt)


def np_layernorm(x, eps: float):
    """OLMo-style non-parametric LayerNorm (no scale, no bias)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps)).astype(dt)


def layer_norm(x, weight, bias, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    if weight is not None:
        y = y * weight.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(dt)


def init_norm(cfg: ModelConfig, key):
    if cfg.norm_type == "np_layernorm":
        return {}
    p = {"scale": jnp.ones((cfg.d_model,), jnp.float32)}
    if cfg.norm_type == "layernorm":
        p["bias"] = jnp.zeros((cfg.d_model,), jnp.float32)
    return p


def apply_norm(cfg: ModelConfig, params, x):
    if cfg.norm_type == "rmsnorm":
        return rms_norm(x, params["scale"], cfg.norm_eps)
    if cfg.norm_type == "np_layernorm":
        return np_layernorm(x, cfg.norm_eps)
    return layer_norm(x, params["scale"], params.get("bias"), cfg.norm_eps)


# ---------------------------------------------------------------------------
# activations / MLP
# ---------------------------------------------------------------------------

def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def init_mlp(cfg: ModelConfig, key, d_ff: Optional[int] = None):
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "up": dense_init(ks[0], (cfg.d_model, d_ff)),
        "down": dense_init(ks[1], (d_ff, cfg.d_model), in_axis_size=d_ff),
    }
    if cfg.mlp_type == "glu":
        p["gate"] = dense_init(ks[2], (cfg.d_model, d_ff))
    return p


def apply_mlp(cfg: ModelConfig, params, x):
    act = activation(cfg.act)
    up = x @ params["up"].astype(x.dtype)
    if cfg.mlp_type == "glu":
        h = act(x @ params["gate"].astype(x.dtype)) * up
    else:
        h = act(up)
    return h @ params["down"].astype(x.dtype)


# ---------------------------------------------------------------------------
# embeddings / head
# ---------------------------------------------------------------------------

VOCAB_PAD = 128   # pad vocab to a multiple (Megatron-style) so the vocab dim
                  # always tiles the 16-way model axis; pad logits are masked
                  # to -1e30 so loss/sampling are bit-equivalent to unpadded.


def padded_vocab(vocab_size: int) -> int:
    return ((vocab_size + VOCAB_PAD - 1) // VOCAB_PAD) * VOCAB_PAD


def init_embed(cfg: ModelConfig, key):
    ks = jax.random.split(key, 2)
    vp = padded_vocab(cfg.vocab_size)
    p = {"tok": embed_init(ks[0], (vp, cfg.d_model))}
    if not cfg.tie_embeddings:
        p["head"] = dense_init(ks[1], (cfg.d_model, vp))
    return p


def embed_tokens(params, tokens, dtype):
    return params["tok"].astype(dtype)[tokens]


def lm_logits(cfg: ModelConfig, params, x):
    w = params["tok"].T if cfg.tie_embeddings else params["head"]
    # logits accumulate in f32: vocab reductions in bf16 lose ~2 bits of logit
    logits = jnp.einsum("...d,dv->...v", x, w.astype(x.dtype),
                        preferred_element_type=jnp.float32)
    vp = logits.shape[-1]
    if vp != cfg.vocab_size:
        pad_mask = jnp.arange(vp) >= cfg.vocab_size
        logits = jnp.where(pad_mask, jnp.float32(-1e30), logits)
    return logits


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_angles(positions, head_dim: int, theta: float):
    """positions (...,) int32 → (cos, sin) of shape (..., head_dim//2), f32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (..., n_heads, head_dim); cos/sin broadcastable (..., 1, head_dim//2)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
