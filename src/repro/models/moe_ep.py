"""Expert-parallel MoE over an MPKLink all_to_all channel.

The dense-dispatch MoE (models/moe.py) computes every expert's FFN on every
device with TP-sharded weights. Expert parallelism instead places experts on
devices and moves TOKENS between them — the exchange the paper would call a
microservice interaction: token batches leave one "service" (device group),
cross the fabric through a pre-established protected channel, and return.

Layout (inside shard_map over the expert axis, size ep, E % ep == 0,
le = E/ep local experts):

  route locally → per-expert send slots (E, C, D)
    → all_to_all (split E over devices)   [guarded channel]
    → local experts run their FFN on (ep·C) received rows
    → all_to_all back
    → combine locally

Numerically identical to dense dispatch at equal capacity
(tests/test_moe_ep.py asserts parity on an 8-device mesh).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.domains import DomainKey
from repro.core.fabric import FabricChannel, MPKLinkFabric, all_to_all
from repro.models.layers import activation
from repro.utils import axis_size
from repro.models.moe import _route


def apply_moe_ep(cfg: ModelConfig, local_weights, x_local, *,
                 fabric: MPKLinkFabric, chan: FabricChannel, key: DomainKey,
                 min_capacity: int = 1) -> Tuple[jnp.ndarray, dict]:
    """Call inside shard_map over chan.axis.

    local_weights: {"router" (D,E) replicated, "gate"/"up" (le,D,F),
    "down" (le,F,D)} — expert dims pre-split by shard_map in_specs.
    x_local (B_loc, S, D) → (out (B_loc, S, D), aux)."""
    fabric.check(chan, key)
    ep = axis_size(chan.axis)
    m = cfg.moe
    E = m.num_experts
    assert E % ep == 0, (E, ep)
    le = E // ep

    B, S, D = x_local.shape
    act = activation(cfg.act)
    xf = x_local.reshape(B * S, D)

    disp, comb, aux = _route(cfg, local_weights, xf, min_capacity)
    C = disp.shape[-1]

    # (E, C, D) send slots → all_to_all moves slot-groups to expert owners
    send = jnp.einsum("tec,td->ecd", disp.astype(x_local.dtype), xf)
    recv = all_to_all(fabric, chan, key, send, split_axis=0, concat_axis=1)
    # recv (le, ep·C, D): rows destined for MY experts, grouped by source
    h = act(jnp.einsum("ecd,edf->ecf", recv, local_weights["gate"].astype(x_local.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", recv, local_weights["up"].astype(x_local.dtype))
    out_e = jnp.einsum("ecf,efd->ecd", h, local_weights["down"].astype(x_local.dtype))
    # return trip: back to the token owners
    back = all_to_all(fabric, chan, key, out_e, split_axis=1, concat_axis=0)
    # back (E, C, D) in the original slot layout
    y = jnp.einsum("tec,ecd->td", comb.astype(x_local.dtype), back)
    return y.reshape(B, S, D), aux


def split_expert_weights(weights, ep: int):
    """Host helper: dense MoE weights → per-device EP slices (for shard_map
    in_specs: P("ep") on the expert dim; router replicated)."""
    return {
        "router": weights["router"],
        "gate": weights["gate"], "up": weights["up"], "down": weights["down"],
    }
