"""End-to-end training driver: a smollm-family model trained for a few
hundred steps with the full production loop — microbatched steps, cosine
schedule, async checkpointing, an injected mid-run failure with automatic
restart, and straggler telemetry.

Model size scales with --width (CPU default ≈ 2M params so 300 steps finish
in minutes on one core; --width 960 --layers 32 is the real smollm-360m,
which is what the 512-device dry-run lowers).

PYTHONPATH=src python examples/train_e2e.py --steps 300
"""
import argparse
import tempfile

import numpy as np

from repro.configs import OptimizerConfig, TrainConfig
from repro.configs.base import ModelConfig
from repro.models.transformer import Impl
from repro.runtime import FailureInjector, Trainer


def build_config(width: int, layers: int) -> ModelConfig:
    heads = max(2, width // 64)
    return ModelConfig(
        name=f"smollm-e2e-{width}x{layers}",
        family="dense",
        num_layers=layers,
        d_model=width,
        num_heads=heads,
        num_kv_heads=max(1, heads // 3),
        head_dim=width // heads,
        d_ff=width * 8 // 3 // 16 * 16 or 64,
        vocab_size=2048,
        tie_embeddings=True,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--width", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--fail-at", type=int, default=0,
                    help="inject a worker failure at this step (0 = off)")
    args = ap.parse_args()

    cfg = build_config(args.width, args.layers)
    n_params = cfg.param_count()
    print(f"model: {cfg.name}  ({n_params/1e6:.1f}M params)")

    tcfg = TrainConfig(
        microbatch_size=max(1, args.batch // 2),
        dtype="float32",
        optimizer=OptimizerConfig(lr=3e-3, warmup_steps=20,
                                  total_steps=args.steps, weight_decay=0.01),
        log_every=10, checkpoint_every=50, keep_checkpoints=2)

    injector = FailureInjector(
        {args.fail_at: ["host1"]} if args.fail_at else {})

    with tempfile.TemporaryDirectory() as ckpt_dir:
        trainer = Trainer(cfg, tcfg, global_batch=args.batch, seq_len=args.seq,
                          checkpoint_dir=ckpt_dir,
                          impl=Impl(attention="chunked", q_chunk=64,
                                    kv_chunk=64, remat=False),
                          workers=[f"host{i}" for i in range(4)],
                          injector=injector)
        report = trainer.run(args.steps)

    first = np.mean(report.losses[:10])
    last = np.mean(report.losses[-10:])
    print(f"\nsteps run          : {report.steps_run}")
    print(f"restarts           : {report.restarts}")
    print(f"stragglers flagged : {report.stragglers}")
    print(f"loss               : {first:.4f} → {last:.4f} "
          f"({'IMPROVED' if last < first else 'NO IMPROVEMENT'})")
    for e in report.events:
        print("event:", e)
    assert last < first, "training failed to reduce loss"
    print("train_e2e OK")


if __name__ == "__main__":
    main()
