"""MPKLink service gateway end-to-end: many clients, many services, one
protected transport.

Walks the gateway lifecycle on top of the paper's §V machinery:
  1. three named services register (CA enrollment + one protection domain
     each): wordcount, reverse, and a restricted "billing" service
  2. concurrent clients enroll, open per-service channels (CA-verified key
     issue on the service's domain) and hammer the services in parallel
  3. isolation: a client without a billing key is refused by the CA, and a
     forged frame under the wrong channel seed is rejected by the guard
  4. revocation: one key revoked → domain epoch bump → stale keys fail the
     PKRU check until their holders re-open

PYTHONPATH=src python examples/gateway_demo.py
"""
import threading
import time

import numpy as np

from repro.core import AccessViolation, ServiceGateway
from repro.core.wordcount import make_text, parse_count, wordcount_handler


def reverse(req):
    return np.ascontiguousarray(np.asarray(req)[::-1])


def main():
    print("=== gateway: 3 services on one mpklink_opt transport ===")
    gw = ServiceGateway("mpklink_opt")
    gw.register_service("wordcount", wordcount_handler)
    gw.register_service("reverse", reverse)
    gw.register_service("billing", lambda r: r, allow={"accounting"})
    gw.start()

    n_clients, reps = 8, 5
    errors = []

    def worker(i):
        try:
            c = gw.connect(f"svc-client-{i}")
            for j in range(reps):
                n = 100 * (i + 1) + j
                got = parse_count(c.call("wordcount", make_text(n, seed=j)))
                assert got == n, (got, n)
                arr = np.arange(i, i + 16, dtype=np.int32)
                assert list(c.call("reverse", arr)) == list(arr[::-1])
            c.close()
        except Exception as e:
            errors.append(repr(e))

    t0 = time.perf_counter()
    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(n_clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    dt = time.perf_counter() - t0
    total = n_clients * reps * 2
    print(f"  {n_clients} concurrent clients × {reps} calls × 2 services: "
          f"{total} requests in {dt*1e3:.0f} ms "
          f"({total/dt:.0f} req/s), errors={errors}")
    print(f"  gateway stats: {gw.stats}")

    print("\n=== isolation ===")
    outsider = gw.connect("outsider")
    try:
        outsider.call("billing", np.arange(4, dtype=np.int32))
        print("  FAIL: unauthorized client served")
    except AccessViolation as e:
        print(f"  CA refused foreign client: {e}")

    acct = gw.connect("accounting")
    assert list(acct.call("billing", np.arange(4, dtype=np.int32))) == [0, 1, 2, 3]
    print("  allow-listed client served")

    print("\n=== revocation (epoch bump) ===")
    alice, bob = gw.connect("alice"), gw.connect("bob")
    alice.call("wordcount", make_text(10, seed=0))
    bob.call("wordcount", make_text(10, seed=0))
    old_key = bob._channels["wordcount"].client_key
    gw.revoke(alice, "wordcount")
    # bob's cached key is now stale (epoch bumped); his next call re-keys
    # through the CA transparently — a banned client could not
    bob.call("wordcount", make_text(10, seed=0))
    assert bob._channels["wordcount"].client_key is not old_key
    print("  epoch bump staled bob's key; CA re-keyed him transparently")
    gw.ca.revoke_service("alice")
    try:
        alice.call("wordcount", make_text(10, seed=0))
        print("  FAIL: banned client served")
    except AccessViolation as e:
        print(f"  banned client refused re-key: {e}")

    gw.close()
    print("\ngateway_demo OK")


if __name__ == "__main__":
    main()
