"""MPKLink fabric parallelism showcase — 8 simulated devices.

Every distributed pattern in the framework running over guarded MPKLink
channels instead of compiler-inserted collectives:

  1. SP  — ring attention: sequence-sharded Q/K/V, K/V rotating through a
           protected neighbor channel (vs full-attention oracle)
  2. EP  — expert-parallel MoE: tokens dispatched between expert-owning
           devices via a guarded all_to_all (vs dense dispatch)
  3. PP  — GPipe pipeline: 8 stages handing activations through the
           channel per tick (vs the single-device layer stack)
  4. DP  — int8+error-feedback compressed gradient reduce across the
           "pod" axis (vs exact all-reduce)

This script re-execs itself with XLA_FLAGS for 8 host devices.
PYTHONPATH=src python examples/fabric_parallel_demo.py
"""
import os
import sys

if os.environ.get("XLA_FLAGS", "").find("device_count=8") < 0:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.execv(sys.executable, [sys.executable] + sys.argv)

import jax
import jax.numpy as jnp
import numpy as np
from repro.utils import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs import get_reduced, replace
from repro.configs.base import MoEConfig
from repro.core.fabric import MPKLinkFabric
from repro.core.ring_attention import ring_attention
from repro.kernels.ref import attention_ref
from repro.models import moe as moe_mod
from repro.models import transformer as tf
from repro.models.moe_ep import apply_moe_ep
from repro.models.transformer import Impl
from repro.optim import compressed_reduce
from repro.runtime.pipeline import pipeline_apply, stage_split

mesh = jax.make_mesh((8,), ("x",))
fab = MPKLinkFabric(mesh, guard=True)
impl = Impl(attention="naive", remat=False)


def demo_ring_attention():
    chan, key = fab.establish("sp-kv", "x")
    B, S, H, Hkv, Dh = 2, 64, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, Dh))
    k = jax.random.normal(ks[1], (B, S, Hkv, Dh))
    v = jax.random.normal(ks[2], (B, S, Hkv, Dh))
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def f(ql, kl, vl, pl):
        out, ok = ring_attention(fab, chan, key, ql, kl, vl, pl, pl,
                                 causal=True, q_chunk=8, kv_chunk=8)
        return out, (jax.lax.psum(1 - ok, "x") == 0).astype(jnp.int32)

    out, ok = jax.jit(shard_map(f, mesh=mesh,
                                in_specs=(P(None, "x"),) * 4,
                                out_specs=(P(None, "x"), P())))(q, k, v, pos)
    ref = attention_ref(q, k, v, pos, pos, causal=True)
    err = float(jnp.max(jnp.abs(out - ref)))
    print(f"1. SP ring attention : 8-way seq-sharded, max|Δ| vs oracle = "
          f"{err:.2e}, guard ok={int(ok)}")


def demo_moe_ep():
    cfg = replace(get_reduced("mixtral-8x7b"),
                  moe=MoEConfig(num_experts=8, top_k=2, capacity_factor=16.0))
    p = moe_mod.init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg.d_model))
    cfg_g = replace(cfg, moe=replace(cfg.moe, group_size=16))
    y_ref, _ = moe_mod.apply_moe(cfg_g, p, x)
    chan, key = fab.establish("ep-dispatch", "x")

    def f(xl, router, gate, up, down):
        w = {"router": router, "gate": gate, "up": up, "down": down}
        y, _ = apply_moe_ep(cfg, w, xl, fabric=fab, chan=chan, key=key)
        return y

    y = jax.jit(shard_map(f, mesh=mesh,
                          in_specs=(P("x"), P(), P("x"), P("x"), P("x")),
                          out_specs=P("x")))(x, p["router"], p["gate"],
                                             p["up"], p["down"])
    err = float(jnp.max(jnp.abs(y - y_ref)))
    print(f"2. EP MoE dispatch   : 8 experts on 8 devices, max|Δ| vs dense = "
          f"{err:.2e}")


def demo_pipeline():
    cfg = replace(get_reduced("llama3.2-1b"), num_layers=8)
    stacked = tf.init_stack(cfg, jax.random.PRNGKey(0), cfg.num_layers)
    n_micro, mb, S = 4, 2, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, mb, S, cfg.d_model))
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (mb, S))
    ref = jnp.stack([tf.apply_stack(cfg, stacked, x[i], positions=positions,
                                    impl=impl)[0] for i in range(n_micro)])
    chan, key = fab.establish("pp-handoff", "x")
    staged = stage_split(stacked, 8)
    specs = jax.tree.map(lambda a: P("x"), staged)

    def f(sp, xm):
        out, ok = pipeline_apply(cfg, sp, xm, fabric=fab, chan=chan, key=key,
                                 impl=impl)
        return out, (jax.lax.psum(1 - ok, "x") == 0).astype(jnp.int32)

    out, ok = jax.jit(shard_map(f, mesh=mesh, in_specs=(specs, P()),
                                out_specs=(P(), P())))(staged, x)
    err = float(jnp.max(jnp.abs(out - ref)))
    print(f"3. PP GPipe          : 8 stages × {n_micro} microbatches "
          f"({8 + n_micro - 1} ticks), max|Δ| vs stack = {err:.2e}, "
          f"guard ok={int(ok)}")


def demo_compressed_dp():
    g = jax.random.normal(jax.random.PRNGKey(2), (8, 64, 16))
    ef0 = jnp.zeros((8, 8, 16))

    def f(gl, ef):
        out, new_ef = compressed_reduce(gl[0], ef[0], "x")
        return out[None], new_ef[None]

    out, ef = jax.jit(shard_map(f, mesh=mesh, in_specs=(P("x"), P("x")),
                                out_specs=(P("x"), P("x"))))(g, ef0)
    exact = np.asarray(g).mean(0)
    err = np.abs(np.asarray(out[0]) - exact).max()
    print(f"4. DP int8+EF reduce : cross-pod gradient mean, max|Δ| vs exact = "
          f"{err:.2e} (int8 leg = 4× fewer bytes)")


if __name__ == "__main__":
    print(f"devices: {jax.device_count()}  mesh: 8×('x')  guard: MAC on\n")
    demo_ring_attention()
    demo_moe_ep()
    demo_pipeline()
    demo_compressed_dp()
    print("\nfabric_parallel_demo OK")
