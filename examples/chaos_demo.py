"""Chaos walkthrough: a self-healing gateway under a seeded fault storm.

Demonstrates the deterministic fault-injection fabric end to end:
  1. a gateway with a restartable service runs a seeded FaultPlan mixing
     all eight fault kinds — every injected security fault is rejected
     with its exact typed error, liveness faults stay bounded
  2. the SAME seed is replayed: outcome-for-outcome identical run
  3. a healing client (bounded retries + idempotency tokens) rides out
     crashes and dropped responses with zero wrong answers and zero
     double-executions (dropped responses are answered from the dedup
     window)
  4. a factory-less flaky service trips the circuit breaker: requests are
     shed with typed ServiceUnavailable instead of hanging, then a probe
     closes the circuit once the service recovers

PYTHONPATH=src python examples/chaos_demo.py
"""
import time

import numpy as np

from repro.core import ServiceGateway
from repro.core.faultwire import FaultFabric, FaultPlan, FaultyClient
from repro.core.transports import ServiceUnavailable
from repro.core.wordcount import make_text, parse_count, wordcount_handler

TIMEOUT = 0.3


def run_storm(seed: int, retries: int = 0):
    gw = ServiceGateway("mpklink_opt", transport_kwargs={"timeout": TIMEOUT})
    gw.register_service("wordcount", wordcount_handler,
                        factory=lambda: wordcount_handler)
    gw.start()
    plan = FaultPlan(seed=seed, n_requests=48, rate=0.25)
    fab = FaultFabric(plan).attach(gw)
    fc = FaultyClient(gw.connect("storm-rider", retries=retries), fab,
                      "wordcount")
    try:
        for i in range(plan.n_requests):
            fc.step(make_text(6 + i % 9, seed=i))
    finally:
        gw.close()
    sig = [(o.index, o.status, o.kind, type(o.value).__name__)
           for o in fc.outcomes]
    return plan, sig, fc.counts(), dict(gw.stats)


def main():
    print("=== 1. seeded fault storm (strict client) ===")
    t0 = time.perf_counter()
    plan, sig, counts, stats = run_storm(seed=42)
    dt = time.perf_counter() - t0
    print(f"  {plan.describe()}")
    for idx, status, kind, vtype in sig:
        if kind is not None:
            print(f"    req {idx:>2}: {kind:<15} -> {status:<9} {vtype}")
    print(f"  outcomes: {counts} in {dt*1e3:.0f} ms "
          f"(every fault typed + bounded, zero collateral errors)")
    print(f"  gateway stats: {stats}")

    print("\n=== 2. replay: identical seed, identical run ===")
    _, sig2, _, _ = run_storm(seed=42)
    print(f"  outcome sequences identical: {sig == sig2}")

    print("\n=== 3. healing client (retries=3 + idempotency tokens) ===")
    plan, sig, counts, stats = run_storm(seed=42, retries=3)
    recovered = [s for s in sig if s[1] == "recovered"]
    print(f"  liveness faults transparently healed: {len(recovered)} "
          f"(crash/drop/delay), deduped replies: {stats['deduped']}, "
          f"restarts: {stats['restarts']}")
    print(f"  outcomes: {counts}")

    print("\n=== 4. circuit breaker on a factory-less flaky service ===")
    state = {"n": 0}

    def flaky(req):
        state["n"] += 1
        if state["n"] <= 3:
            raise ValueError("flaky dependency")
        return wordcount_handler(req)

    gw = ServiceGateway("uds")
    gw.register_service("flaky", flaky, failure_threshold=3, probe_after=2)
    gw.start()
    c = gw.connect("ops")
    for i in range(8):
        try:
            n = parse_count(c.call("flaky", make_text(5, seed=i)))
            print(f"    call {i}: ok ({n} words) "
                  f"[health: {gw.health()['flaky']['state']}]")
        except ServiceUnavailable as e:
            print(f"    call {i}: SHED  ({e})")
        except Exception as e:
            print(f"    call {i}: fail  ({type(e).__name__})")
    print(f"  final health: {gw.health()['flaky']}")
    gw.close()
    print("\nchaos_demo OK")


if __name__ == "__main__":
    main()
