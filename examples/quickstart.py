"""Quickstart: the whole system in two minutes on CPU.

1. MPKLink (the paper): CA enrollment → protected channel → word-count
   round trip, with the tamper/forged-key failure modes demonstrated.
2. The LM stack: init a tiny llama-family model, train a few steps,
   decode a few tokens.

PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import OptimizerConfig, TrainConfig, get_reduced
from repro.core import framing
from repro.core.transports import MPKLinkTransport
from repro.core.wordcount import make_text, parse_count, wordcount_handler
from repro.models import decode_step, init_decode_state, init_params
from repro.models.transformer import Impl
from repro.runtime import Trainer


def demo_mpklink():
    print("=== 1. MPKLink: protected shared-memory IPC (the paper) ===")
    tr = MPKLinkTransport(wordcount_handler)
    tr.start()
    try:
        text = make_text(10_000, seed=0)
        count = parse_count(np.asarray(tr.request(text)))
        print(f"word count over MPKLink channel: {count}  "
              f"(key syncs so far: {tr.sync_count})")

        # the security envelope: a frame built under the wrong session seed
        # fails the receive-side guard
        frame = framing.build_frame(np.arange(8, dtype=np.int32),
                                    seed=tr.seed ^ 0xDEAD, seq=0)
        try:
            framing.parse_frame(frame, seed=tr.seed)
        except framing.FrameError as e:
            print(f"forged frame rejected: {e}")
    finally:
        tr.close()


def demo_lm():
    print("\n=== 2. LM stack: train a tiny model, then decode ===")
    cfg = get_reduced("llama3.2-1b")
    tcfg = TrainConfig(microbatch_size=2, dtype="float32",
                       optimizer=OptimizerConfig(lr=2e-3, warmup_steps=5,
                                                 total_steps=100),
                       log_every=5)
    trainer = Trainer(cfg, tcfg, global_batch=4, seq_len=64,
                      impl=Impl(attention="chunked", q_chunk=16, kv_chunk=16,
                                remat=False))
    report = trainer.run(20)
    print(f"loss: {report.losses[0]:.3f} → {report.losses[-1]:.3f}")

    _, state = trainer.restore_or_init() if trainer.ckpt else (0, None)
    params = init_params(cfg, jax.random.PRNGKey(0))
    impl = Impl(attention="naive", remat=False)
    st = init_decode_state(cfg, params, 1, 32, dtype=jnp.float32, impl=impl)
    tok = jnp.asarray([[1]], jnp.int32)
    toks = []
    for _ in range(8):
        logits, st = decode_step(cfg, params, st, tok, impl=impl,
                                 dtype=jnp.float32)
        tok = jnp.argmax(logits[:, -1:, :cfg.vocab_size], -1).astype(jnp.int32)
        toks.append(int(tok[0, 0]))
    print("greedy decode:", toks)


if __name__ == "__main__":
    demo_mpklink()
    demo_lm()
    print("\nquickstart OK")
