"""The paper's system end-to-end: MPKLink vs the IPC alternatives.

Walks the full MPKLink lifecycle from §V of the paper:
  1. two microservices enroll with the CA (key pairs, proof of possession)
  2. the CA verifies certificates and grants a protected channel
     (protection domain + capability keys)
  3. word-count requests flow through the guarded shared region
     (per-chunk PKRU sync + per-message MAC)
  4. the same workload runs over pipes / UDS / raw shm / simulated gRPC
     for the paper's comparison
  5. threat-model checks: forged seed, revoked key, tampered frame
  6. the on-device data plane: the mpk_guard Pallas kernel verifying a
     tensor's MAC (interpret mode on CPU; compiled on TPU)

PYTHONPATH=src python examples/mpklink_demo.py
"""
import time

import numpy as np
import jax.numpy as jnp

from repro.core import TRANSPORTS, framing
from repro.core.domains import AccessViolation, READ
from repro.core.transports import CapacityError, MPKLinkTransport
from repro.core.wordcount import make_text, parse_count, wordcount_handler
from repro.kernels.ops import guard_copy, mac


def lifecycle():
    print("=== MPKLink lifecycle (paper §V) ===")
    tr = MPKLinkTransport(wordcount_handler)
    print(f"CA enrolled services: svc-client, svc-server")
    print(f"channel domain: {tr.domain.name!r} (pkey {tr.domain.did}, "
          f"tag {tr.domain.tag:#010x})")
    print(f"session-derived MAC seed: {tr.seed:#010x}")
    tr.start()
    try:
        for n in (100, 10_000, 200_000):
            t0 = time.perf_counter()
            count = parse_count(np.asarray(tr.request(make_text(n, seed=n))))
            dt = time.perf_counter() - t0
            print(f"  {n:>8} words → count={count:<8} {dt*1e3:8.2f} ms  "
                  f"(cumulative key syncs: {tr.sync_count})")
        # threat model: revoked key
        tr.registry.revoke(tr.key_client)
        try:
            tr.registry.check(tr.key_client, READ)
        except AccessViolation as e:
            print(f"  revoked key rejected at staging time: {e}")
    finally:
        tr.close()


def comparison():
    print("\n=== transport comparison (paper Fig. 3 region) ===")
    text = make_text(10_000, seed=1)
    for name in ("pipe", "uds", "shm", "grpc_sim", "mpklink", "mpklink_opt"):
        tr = TRANSPORTS[name](wordcount_handler)
        tr.start()
        try:
            tr.request(text)                      # warm
            t0 = time.perf_counter()
            tr.request(text)
            dt = time.perf_counter() - t0
            print(f"  {name:<12} {dt*1e6:9.0f} µs")
        except CapacityError as e:
            print(f"  {name:<12} FAILED ({e})")
        finally:
            tr.close()


def data_plane():
    print("\n=== on-device data plane: mpk_guard kernel ===")
    payload = jnp.asarray(
        np.random.default_rng(0).integers(0, 2**32, (256, 128),
                                          dtype=np.uint64).astype(np.uint32))
    tag = jnp.uint32(0xBEEF)
    m = mac(payload, tag)
    out, macv, ok = guard_copy(payload, tag, m)
    print(f"  authenticated copy: mac={int(macv[0]):#010x} ok={int(ok[0])}")
    tampered = payload.at[100, 7].add(jnp.uint32(1))
    _, _, ok2 = guard_copy(tampered, tag, m)
    print(f"  tampered payload:   ok={int(ok2[0])} (rejected)")
    assert int(ok[0]) == 1 and int(ok2[0]) == 0


if __name__ == "__main__":
    lifecycle()
    comparison()
    data_plane()
    print("\nmpklink_demo OK")
