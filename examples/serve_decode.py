"""Serving example: continuous batching over a fixed slot grid.

Submits a burst of requests with different prompt/generation lengths and
drains them through the batched decode engine, printing per-request latency
and aggregate throughput. Uses the SSM arch (mamba2 family) to show O(1)
state serving; switch --arch for dense.

PYTHONPATH=src python examples/serve_decode.py
"""
import argparse
import time

import jax

from repro.configs import get_reduced
from repro.models import init_params
from repro.models.transformer import Impl
from repro.runtime import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-1.3b",
                    choices=["mamba2-1.3b", "llama3.2-1b", "olmo-1b",
                             "smollm-360m", "qwen3-14b"])
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, max_batch=args.max_batch, max_seq=96,
                        impl=Impl(attention="naive", ssd="chunked", remat=False))

    t0 = time.perf_counter()
    for i in range(args.requests):
        prompt = [(7 * i + j) % cfg.vocab_size for j in range(3 + i % 5)]
        eng.submit(Request(rid=i, prompt=prompt, max_new=6 + (i % 4)))
    done = eng.run_until_drained()
    wall = time.perf_counter() - t0

    total_new = sum(len(r.generated) for r in done)
    print(f"arch={cfg.name} slots={args.max_batch}")
    for r in sorted(done, key=lambda r: r.rid):
        lat = r.finished_at - r.submitted_at
        print(f"req {r.rid:2d}: prompt={len(r.prompt):2d} "
              f"generated={len(r.generated):2d} latency={lat*1e3:7.1f} ms "
              f"tokens={r.generated}")
    print(f"\n{len(done)} requests, {total_new} new tokens, "
          f"{eng.ticks} engine ticks, {wall:.2f}s wall "
          f"({total_new/wall:.1f} tok/s)")
    assert len(done) == args.requests
    print("serve_decode OK")


if __name__ == "__main__":
    main()
