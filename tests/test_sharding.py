"""Sharding specs: every (arch, policy) produces specs whose sharded dims
tile the production mesh — the invariant the 512-device dry-run relies on."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.models import init_params
from repro.sharding.specs import batch_specs, opt_state_specs, param_specs

AXIS_SIZES = {"pod": 2, "data": 16, "model": 16}


def _check_divisible(sds_tree, spec_tree, arch, policy):
    flat_s, _ = jax.tree_util.tree_flatten(sds_tree)
    flat_p = jax.tree_util.tree_flatten(
        spec_tree, is_leaf=lambda x: isinstance(x, P))[0]
    assert len(flat_s) == len(flat_p)
    for leaf, spec in zip(flat_s, flat_p):
        for dim, entry in zip(leaf.shape, tuple(spec)):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            n = 1
            for a in axes:
                n *= AXIS_SIZES[a]
            assert dim % n == 0, (arch, policy, leaf.shape, tuple(spec))


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("policy", ["tp", "fsdp_tp"])
@pytest.mark.parametrize("dp", [("data",), ("pod", "data")])
def test_param_specs_divisible(arch, policy, dp):
    cfg = get_config(arch)
    sds = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    specs = param_specs(cfg, sds, policy=policy, dp=dp, axis_sizes=AXIS_SIZES)
    _check_divisible(sds, specs, arch, policy)


@pytest.mark.parametrize("arch", ["qwen3-14b", "mixtral-8x7b", "mamba2-1.3b"])
def test_opt_specs_structure(arch):
    cfg = get_config(arch)
    sds = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    ospec = opt_state_specs(cfg, sds, axis_sizes=AXIS_SIZES)
    assert set(ospec) == {"m", "v", "step"}
    assert ospec["step"] == P()
    _check_divisible(sds, ospec["m"], arch, "zero1")


def test_tp_shards_model_axis_where_it_matters():
    """The big matmul weights must actually be TP-sharded, not replicated."""
    cfg = get_config("llama3.2-1b")
    sds = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    specs = param_specs(cfg, sds, policy="tp", axis_sizes=AXIS_SIZES)
    assert "model" in tuple(specs["blocks"]["ffn"]["up"])
    assert "model" in tuple(specs["blocks"]["ffn"]["down"])
    assert "model" in tuple(specs["blocks"]["attn"]["wq"])
    assert "model" in tuple(specs["embed"]["tok"])


def test_nondivisible_heads_replicated_not_split():
    cfg = get_config("qwen3-14b")                 # 40 heads % 16 != 0
    sds = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    specs = param_specs(cfg, sds, policy="tp", axis_sizes=AXIS_SIZES)
    assert "model" not in tuple(specs["blocks"]["attn"]["wq"])
    assert "model" in tuple(specs["blocks"]["ffn"]["up"])     # ffn still TP


def test_batch_specs_fields():
    cfg = get_config("llava-next-mistral-7b")
    bs = batch_specs(cfg, dp=("pod", "data"))
    assert set(bs) == {"tokens", "labels", "vision_embeds"}
    assert tuple(bs["tokens"])[0] == ("pod", "data")
