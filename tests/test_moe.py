"""MoE routing invariants and the grouped (GShard) dispatch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced, replace
from repro.configs.base import MoEConfig
from repro.models import moe as moe_mod


def _setup(group_size=None, T=64, seed=0):
    cfg = get_reduced("mixtral-8x7b")
    cfg = replace(cfg, moe=replace(cfg.moe, group_size=group_size))
    p = moe_mod.init_moe(cfg, jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, T // 2, cfg.d_model))
    return cfg, p, x


def test_routing_capacity_and_mass():
    cfg, p, x = _setup()
    xf = x.reshape(-1, x.shape[-1])
    disp, comb, aux = moe_mod._route(cfg, p, xf)
    T = xf.shape[0]
    E, k = cfg.moe.num_experts, cfg.moe.top_k
    C = max(1, int(cfg.moe.capacity_factor * T * k / E))
    # every (e, c) slot holds at most one token
    assert float(jnp.max(jnp.sum(disp, axis=0))) <= 1.0 + 1e-6
    # each token dispatched at most k times
    assert float(jnp.max(jnp.sum(disp, axis=(1, 2)))) <= k + 1e-6
    # combine weights of kept tokens sum to ≤ 1 (renormalized top-k probs)
    mass = jnp.sum(comb, axis=(1, 2))
    assert float(jnp.max(mass)) <= 1.0 + 1e-5
    assert 0.0 <= float(aux["moe_drop_frac"]) <= 1.0


def test_aux_losses_finite_and_positive():
    cfg, p, x = _setup()
    y, aux = moe_mod.apply_moe(cfg, p, x)
    assert np.isfinite(float(aux["moe_lb_loss"])) and float(aux["moe_lb_loss"]) > 0
    assert np.isfinite(float(aux["moe_z_loss"]))
    assert y.shape == x.shape


def test_grouped_equals_ungrouped_when_capacity_loose():
    """With capacity_factor high enough that nothing drops, grouped routing
    computes the same function (groups only change slot assignment)."""
    cfg0 = get_reduced("mixtral-8x7b")
    loose = replace(cfg0.moe, capacity_factor=8.0)
    cfgu = replace(cfg0, moe=loose)
    cfgg = replace(cfg0, moe=replace(loose, group_size=16))
    p = moe_mod.init_moe(cfgu, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg0.d_model))
    yu, _ = moe_mod.apply_moe(cfgu, p, x)
    yg, _ = moe_mod.apply_moe(cfgg, p, x)
    np.testing.assert_allclose(np.asarray(yu), np.asarray(yg), rtol=2e-5, atol=2e-5)


def test_dropped_tokens_fall_to_residual():
    """capacity_factor → 0 forces drops; output ≈ 0 for dropped tokens (the
    residual path continues in the block)."""
    cfg0 = get_reduced("mixtral-8x7b")
    cfgt = replace(cfg0, moe=replace(cfg0.moe, capacity_factor=0.01))
    p = moe_mod.init_moe(cfgt, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, cfg0.d_model))
    y, aux = moe_mod.apply_moe(cfgt, p, x)
    assert float(aux["moe_drop_frac"]) > 0.5
    # most rows are zeros
    row_norms = jnp.linalg.norm(y[0], axis=-1)
    assert float(jnp.median(row_norms)) == 0.0


def test_grads_flow_through_router():
    cfg, p, x = _setup()
    g = jax.grad(lambda p: moe_mod.apply_moe(cfg, p, x)[0].sum()
                 + moe_mod.apply_moe(cfg, p, x)[1]["moe_lb_loss"])(p)
    assert float(jnp.sum(jnp.abs(g["router"]))) > 0
