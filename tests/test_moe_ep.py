"""Expert-parallel MoE (fabric all_to_all) parity with dense dispatch —
8-device subprocess, 8 experts, 1 per device."""
import os
import subprocess
import sys

_ROOT = os.path.join(os.path.dirname(__file__), "..")

CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.utils import shard_map
from repro.configs import get_reduced, replace
from repro.configs.base import MoEConfig
from repro.core.fabric import MPKLinkFabric
from repro.models import moe as moe_mod
from repro.models.moe_ep import apply_moe_ep

cfg = get_reduced("mixtral-8x7b")
# 8 experts (one per device), loose capacity so nothing drops on either path
cfg = replace(cfg, moe=MoEConfig(num_experts=8, top_k=2, capacity_factor=16.0))
p = moe_mod.init_moe(cfg, jax.random.PRNGKey(0))
B, S = 8, 16                                      # one batch row per device
x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))

# dense reference (per-row groups == per-device routing in the EP path)
cfg_g = replace(cfg, moe=replace(cfg.moe, group_size=S))
y_ref, aux_ref = moe_mod.apply_moe(cfg_g, p, x)

mesh = jax.make_mesh((8,), ("ep",))
fab = MPKLinkFabric(mesh, guard=False)
chan, key = fab.establish("moe-dispatch", "ep")

def ep_fn(xl, router, gate, up, down):
    w = {"router": router, "gate": gate, "up": up, "down": down}
    y, aux = apply_moe_ep(cfg, w, xl, fabric=fab, chan=chan, key=key)
    return y, jax.tree.map(lambda a: jax.lax.pmean(a, "ep"), aux)

y_ep, aux_ep = jax.jit(shard_map(
    ep_fn, mesh=mesh,
    in_specs=(P("ep"), P(), P("ep"), P("ep"), P("ep")),
    out_specs=(P("ep"), P())))(x, p["router"], p["gate"], p["up"], p["down"])

np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref),
                           rtol=2e-4, atol=2e-4)
print("OK")
"""


def test_moe_ep_parity():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", CODE], capture_output=True,
                       text=True, cwd=_ROOT, env=env, timeout=480)
    assert "OK" in r.stdout, r.stdout + r.stderr
