"""Decode-attention Pallas kernel vs the naive oracle: GQA, SWA, ring-style
position vectors, unfilled slots, dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.ref import attention_ref

CASES = [
    # B, S, H, Hkv, Dh, causal, window, kc
    (2, 64, 4, 2, 16, True, None, 16),
    (1, 128, 6, 3, 8, True, 32, 32),
    (3, 32, 4, 4, 32, True, None, 8),
    (1, 64, 8, 1, 16, True, None, 64),     # MQA
]


def _inputs(case, dtype=jnp.float32, seed=0):
    B, S, H, Hkv, Dh, causal, win, kc = case
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (B, 1, H, Dh), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, Dh), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, Dh), dtype)
    length = S - 5                                    # some unfilled slots
    qp = jnp.full((B, 1), length - 1, jnp.int32)
    kp = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    kp = jnp.where(kp < length, kp, -1)
    return q, k, v, qp, kp, causal, win, kc


@pytest.mark.parametrize("case", CASES)
def test_matches_oracle(case):
    q, k, v, qp, kp, causal, win, kc = _inputs(case)
    ref = attention_ref(q, k, v, qp, kp, causal=causal, window=win)
    got = decode_attention_pallas(q, k, v, qp, kp, causal=causal, window=win,
                                  kv_chunk=kc)
    np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5)


def test_ring_style_positions():
    """Out-of-order absolute positions (ring buffer slots) mask correctly."""
    B, S, H, Hkv, Dh = 1, 16, 2, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, 1, H, Dh))
    k = jax.random.normal(ks[1], (B, S, Hkv, Dh))
    v = jax.random.normal(ks[2], (B, S, Hkv, Dh))
    # slots hold positions 16..31 wrapped: slot i ↔ pos 16 + (i + 5) % 16
    kp = ((jnp.arange(S) + 5) % S + 16)[None].astype(jnp.int32)
    qp = jnp.full((B, 1), 31, jnp.int32)
    ref = attention_ref(q, k, v, qp, kp, causal=True, window=8)
    got = decode_attention_pallas(q, k, v, qp, kp, causal=True, window=8,
                                  kv_chunk=8)
    np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 2e-2)])
def test_dtypes(dtype, tol):
    case = (2, 64, 4, 2, 16, True, None, 16)
    q, k, v, qp, kp, causal, win, kc = _inputs(case, dtype=dtype)
    ref = attention_ref(q, k, v, qp, kp, causal=causal, window=win)
    got = decode_attention_pallas(q, k, v, qp, kp, causal=causal, window=win,
                                  kv_chunk=kc)
    np.testing.assert_allclose(got.astype(jnp.float32), ref.astype(jnp.float32),
                               rtol=tol, atol=tol)
