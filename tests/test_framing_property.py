"""Seeded property-based round-trip tests for core/framing + fast_mac.

No hypothesis dependency: trials are driven by a fixed-seed generator so
every run (and every CI failure) is exactly reproducible — each assertion
message carries the (seed, trial) pair that rebuilds the failing case.

Properties:
  * build → parse round-trips payloads of every boundary size (0, 1,
    row-capacity-1, row-capacity, +1) and dtype;
  * flipping ANY single bit anywhere in the frame — header metadata,
    reserved lanes, MAC word, payload, padding — must raise FrameError
    (the header-hardening property: metadata is folded into the MAC);
  * fast_mac is bit-identical to the scan reference _mac_np for random
    shapes/seeds, including the empty payload.
"""
import numpy as np
import pytest

from repro.core import framing
from repro.core.transports import fast_mac

ROW = framing.LANES * 4                 # payload bytes per frame row
BOUNDARY_SIZES = [0, 1, 2, ROW - 1, ROW, ROW + 1, 3 * ROW - 1, 3 * ROW]
DTYPES = [np.uint8, np.int32, np.uint32, np.float32, np.float64, np.int64,
          np.uint16]

MASTER_SEED = 0xC0FFEE
N_TRIALS = 40


def _random_payload(rng: np.random.Generator, nbytes: int, dtype) -> np.ndarray:
    itemsize = np.dtype(dtype).itemsize
    n = max(0, nbytes // itemsize)
    raw = rng.integers(0, 256, size=n * itemsize, dtype=np.uint8)
    return raw.view(dtype).reshape(-1)


def _trial_params(trial: int):
    rng = np.random.default_rng(MASTER_SEED + trial)
    if trial < len(BOUNDARY_SIZES) * 2:
        nbytes = BOUNDARY_SIZES[trial % len(BOUNDARY_SIZES)]
    else:
        nbytes = int(rng.integers(0, 4 * ROW))
    dtype = DTYPES[trial % len(DTYPES)]
    seed = int(rng.integers(0, 2 ** 32))
    seq = int(rng.integers(0, 2 ** 31))
    return rng, nbytes, dtype, seed, seq


@pytest.mark.parametrize("trial", range(N_TRIALS))
def test_roundtrip_then_any_single_bit_flip_fails(trial):
    rng, nbytes, dtype, seed, seq = _trial_params(trial)
    arr = _random_payload(rng, nbytes, dtype)
    ctx = f"(master_seed={MASTER_SEED:#x}, trial={trial}, " \
          f"nbytes={arr.nbytes}, dtype={np.dtype(dtype).name}, " \
          f"seed={seed:#x}, seq={seq})"

    frame = framing.build_frame(arr, seed=seed, seq=seq)
    out = framing.parse_frame(frame, seed=seed, expect_seq=seq)
    np.testing.assert_array_equal(out, arr, err_msg=f"roundtrip {ctx}")
    assert out.dtype == arr.dtype, ctx
    assert frame.shape[0] == framing.frame_rows(arr.nbytes), ctx

    # one random single-BIT flip anywhere in the frame must be detected
    flat = frame.reshape(-1)
    for _ in range(8):
        word = int(rng.integers(0, flat.size))
        bit = int(rng.integers(0, 32))
        mutated = frame.copy()
        mutated.reshape(-1)[word] ^= np.uint32(1 << bit)
        try:
            framing.parse_frame(mutated, seed=seed, expect_seq=seq)
        except framing.FrameError:
            continue
        pytest.fail(f"undetected flip word={word} bit={bit} {ctx}")


@pytest.mark.parametrize("trial", range(N_TRIALS))
def test_fast_mac_bit_identical_to_reference(trial):
    rng = np.random.default_rng(MASTER_SEED ^ trial)
    rows = int(rng.integers(0, 70))
    seed = int(rng.integers(0, 2 ** 32))
    p = rng.integers(0, 2 ** 32, (rows, framing.LANES),
                     dtype=np.uint64).astype(np.uint32)
    block = int(rng.integers(1, 80))
    assert fast_mac(p, seed, block_rows=block) == framing._mac_np(p, seed), \
        f"(master_seed={MASTER_SEED:#x}^{trial}, rows={rows}, " \
        f"seed={seed:#x}, block_rows={block})"


def test_empty_payload_mac_and_frame():
    empty = np.zeros((0, framing.LANES), np.uint32)
    assert fast_mac(empty, 7) == framing._mac_np(empty, 7)
    arr = np.zeros(0, np.uint8)
    frame = framing.build_frame(arr, seed=3, seq=0)
    assert frame.shape[0] == 1                    # header only
    out = framing.parse_frame(frame, seed=3, expect_seq=0)
    assert out.size == 0 and out.dtype == np.uint8
    # even an empty frame rejects header tampering (dtype_code flip)
    bad = frame.copy()
    bad[0, 4] ^= 1
    with pytest.raises(framing.FrameError):
        framing.parse_frame(bad, seed=3, expect_seq=0)


def test_wrong_dtype_header_is_detected_not_misparsed():
    """The classic silent-corruption case the meta-mix closes: float32 vs
    int32 differ by one header bit and identical sizes — a flip must be a
    FrameError, never a silently wrong-typed array."""
    arr = np.arange(64, dtype=np.float32)
    frame = framing.build_frame(arr, seed=9, seq=1)
    flipped = frame.copy()
    flipped[0, 4] ^= 1                             # dtype_code 0 ↔ 1
    with pytest.raises(framing.FrameError, match="MAC|header"):
        framing.parse_frame(flipped, seed=9, expect_seq=1)


def test_truncated_and_padded_frames_rejected():
    arr = np.arange(700, dtype=np.uint8)
    frame = framing.build_frame(arr, seed=4, seq=2)
    with pytest.raises(framing.FrameError):        # dropped payload row
        framing.parse_frame(frame[:-1], seed=4, expect_seq=2)
    with pytest.raises(framing.FrameError):        # header-only stub
        framing.parse_frame(frame[:1], seed=4, expect_seq=2)
    with pytest.raises(framing.FrameError):        # empty
        framing.parse_frame(frame[:0], seed=4, expect_seq=2)
    extra = np.concatenate([frame, np.zeros((1, framing.LANES), np.uint32)])
    with pytest.raises(framing.FrameError):        # appended row
        framing.parse_frame(extra, seed=4, expect_seq=2)
