"""mpklint rule + engine coverage, and the repo's own invariant gate.

Every rule family has fixture-backed true-positive, true-negative and
suppressed cases (tests/fixtures/analysis/), the engine's suppression/
baseline machinery is exercised directly, and — the part tier-1 exists
for — the analyzer must report ZERO new findings on the committed tree
while still firing on freshly seeded bugs of each class.
"""
import json
import shutil
from pathlib import Path

import pytest

from repro.analysis import Baseline, analyze_paths
from repro.analysis.engine import run
from repro.analysis.rules_spec import (SpecConstantSyncRule,
                                       SpecTaxonomySyncRule)

ROOT = Path(__file__).resolve().parent.parent
FIXTURES = ROOT / "tests" / "fixtures" / "analysis"
BASELINE = ROOT / "analysis" / "baseline.json"

FILE_RULES = ["MPK001", "MPK002", "MPK003", "MPK101", "MPK102", "MPK103",
              "MPK104", "MPK105", "MPK106", "MPK107"]
DIR_RULES = ["MPK201", "MPK202"]


def _findings(path, rule):
    report = analyze_paths([path])
    return [f for f in report.findings if f.rule == rule]


# ---------------------------------------------------------------- fixtures

@pytest.mark.parametrize("rule", FILE_RULES + DIR_RULES)
def test_rule_true_positive(rule):
    path = FIXTURES / rule.lower() / ("bad.py" if rule in FILE_RULES
                                      else "bad")
    hits = _findings(path, rule)
    assert hits, f"{rule} did not fire on its bad fixture"
    assert all(not f.suppressed and not f.baselined for f in hits)
    assert all(f.message and f.hint for f in hits)


@pytest.mark.parametrize("rule", FILE_RULES + DIR_RULES)
def test_rule_true_negative(rule):
    path = FIXTURES / rule.lower() / ("good.py" if rule in FILE_RULES
                                      else "good")
    assert _findings(path, rule) == [], \
        f"{rule} false-positived on its good fixture"


@pytest.mark.parametrize("rule", FILE_RULES + DIR_RULES)
def test_rule_suppressed(rule):
    path = FIXTURES / rule.lower() / ("suppressed.py" if rule in FILE_RULES
                                      else "suppressed")
    hits = _findings(path, rule)
    assert hits, f"{rule} produced nothing to suppress"
    assert all(f.suppressed for f in hits), \
        f"{rule} suppression comment did not take"
    report = analyze_paths([path])
    assert [f for f in report.new if f.rule == rule] == []


def test_unreasoned_disable_is_a_finding_and_does_not_suppress():
    bad = FIXTURES / "mpk000" / "bad.py"
    report = analyze_paths([bad])
    rules = {f.rule for f in report.new}
    assert "MPK000" in rules          # the reasonless disable is reported
    assert "MPK103" in rules          # ... and it silenced nothing
    good = FIXTURES / "mpk000" / "good.py"
    report = analyze_paths([good])
    assert {f.rule for f in report.new} == set()
    assert any(f.rule == "MPK103" and f.suppressed for f in report.findings)


# ------------------------------------------------------------------ engine

def test_baseline_roundtrip(tmp_path):
    bad = FIXTURES / "mpk001" / "bad.py"
    first = analyze_paths([bad])
    assert first.new
    bl_file = tmp_path / "baseline.json"
    bl_file.write_text(Baseline.dump(first.findings))
    again = analyze_paths([bad], baseline=Baseline.load(bl_file))
    assert again.new == []
    assert sum(f.baselined for f in again.findings) == len(first.new)


def test_baseline_survives_line_drift(tmp_path):
    src = (FIXTURES / "mpk001" / "bad.py").read_text()
    f = tmp_path / "drift.py"
    f.write_text(src)
    bl_file = tmp_path / "baseline.json"
    bl_file.write_text(Baseline.dump(analyze_paths([f]).findings))
    f.write_text("# a new comment shifts every line\n" + src)
    report = analyze_paths([f], baseline=Baseline.load(bl_file))
    assert report.new == [], "baseline keyed on line numbers, not content"


def test_cli_exit_codes(tmp_path, capsys):
    assert run([str(FIXTURES / "mpk001" / "good.py")]) == 0
    assert run([str(FIXTURES / "mpk001" / "bad.py")]) == 1
    assert run([str(tmp_path / "nope.py")]) == 2
    capsys.readouterr()


def test_cli_json_report(capsys):
    rc = run(["--json", str(FIXTURES / "mpk001" / "bad.py")])
    out = capsys.readouterr().out
    assert rc == 1
    data = json.loads(out)
    assert data["counts"]["new"] >= 1
    assert all({"rule", "path", "line", "message"} <= set(f)
               for f in data["findings"])


def test_cli_write_baseline(tmp_path, capsys):
    bl = tmp_path / "bl.json"
    assert run(["--write-baseline", str(bl),
                str(FIXTURES / "mpk001" / "bad.py")]) == 0
    assert run(["--baseline", str(bl),
                str(FIXTURES / "mpk001" / "bad.py")]) == 0
    capsys.readouterr()


# ------------------------------------------- the repo's own invariant gate

def test_repo_tree_is_clean():
    """The committed tree carries zero unbaselined, unsuppressed findings
    — the CI analysis job's exact contract."""
    report = analyze_paths([ROOT / "src" / "repro"],
                           baseline=Baseline.load(BASELINE))
    assert report.parse_errors == []
    assert [f.render() for f in report.new] == []


def test_seeded_framestats_counter_fails_mpk001(tmp_path):
    src = (ROOT / "src" / "repro" / "core" / "framing.py").read_text()
    old = "    def bump(self, **deltas: int) -> None:"
    assert old in src
    seeded = tmp_path / "framing.py"
    seeded.write_text(src.replace(old, old + "\n        self._count += 1", 1))
    report = analyze_paths([seeded])
    assert any(f.rule == "MPK001" and "_count" in f.message
               for f in report.new)


def test_seeded_wallclock_deadline_fails_mpk103(tmp_path):
    src = (ROOT / "src" / "repro" / "core" / "transports.py").read_text()
    old = "        slot = ring.slots[self._tickets % ring.capacity]"
    assert old in src
    seeded = tmp_path / "transports.py"
    seeded.write_text(src.replace(
        old, "        deadline = time.time() + 1.0\n" + old, 1))
    report = analyze_paths([seeded])
    assert any(f.rule == "MPK103" for f in report.new)


def test_seeded_spec_drift_fails_mpk201(tmp_path):
    proj = tmp_path / "proj"
    (proj / "docs").mkdir(parents=True)
    (proj / "src").mkdir()
    shutil.copy(ROOT / "src" / "repro" / "core" / "framing.py",
                proj / "src" / "framing.py")
    spec = (ROOT / "docs" / "protocol.md").read_text()
    (proj / "docs" / "protocol.md").write_text(
        spec.replace("0x4D504B4C", "0x4D504BFF"))
    report = analyze_paths([proj / "src"])
    assert any(f.rule == "MPK201" and "MAGIC" in f.message
               for f in report.new)


def test_spec_rules_cover_test_docs_contract():
    """The rules that replaced test_docs.py's hand-written asserts still
    check the same ground truth: every wire constant and typed error the
    code defines is quoted by docs/protocol.md."""
    report = analyze_paths(
        [ROOT / "src" / "repro" / "core", ROOT / "src" / "repro" / "kernels"],
        rules=[SpecConstantSyncRule(), SpecTaxonomySyncRule()], root=ROOT)
    assert [f.render() for f in report.findings if not f.suppressed] == []
