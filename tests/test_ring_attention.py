"""Ring attention (sequence parallelism over MPKLink channels) vs the
full-attention oracle — 8-device subprocess."""
import os
import subprocess
import sys

_ROOT = os.path.join(os.path.dirname(__file__), "..")

CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.utils import shard_map
from repro.core.fabric import MPKLinkFabric
from repro.core.ring_attention import ring_attention
from repro.kernels.ref import attention_ref

mesh = jax.make_mesh((8,), ("sp",))
fab = MPKLinkFabric(mesh, guard=True)
chan, key = fab.establish("ring-kv", "sp")

B, S, H, Hkv, Dh = 2, 64, 4, 2, 16          # 8 tokens per device
ks = jax.random.split(jax.random.PRNGKey(0), 3)
q = jax.random.normal(ks[0], (B, S, H, Dh))
k = jax.random.normal(ks[1], (B, S, Hkv, Dh))
v = jax.random.normal(ks[2], (B, S, Hkv, Dh))
pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

for causal, window in [(True, None), (True, 24), (False, None)]:
    def ring(ql, kl, vl, qpl, kpl):
        out, ok = ring_attention(fab, chan, key, ql, kl, vl, qpl, kpl,
                                 causal=causal, window=window,
                                 q_chunk=8, kv_chunk=8)
        return out, (jax.lax.psum(1 - ok, "sp") == 0).astype(jnp.int32)

    out, ok = jax.jit(shard_map(
        ring, mesh=mesh,
        in_specs=(P(None, "sp"), P(None, "sp"), P(None, "sp"),
                  P(None, "sp"), P(None, "sp")),
        out_specs=(P(None, "sp"), P())))(q, k, v, pos, pos)
    ref = attention_ref(q, k, v, pos, pos, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)
    assert int(ok) == 1, (causal, window)
print("OK")
"""


def test_ring_attention_matches_oracle():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", CODE], capture_output=True,
                       text=True, cwd=_ROOT, env=env, timeout=480)
    assert "OK" in r.stdout, r.stdout + r.stderr
