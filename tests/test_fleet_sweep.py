"""Slow 1024-client open-loop sweep through the fleet_bench cell path.

One test, marked ``slow`` (NOT ``proc`` — mpklink_opt in-proc replicas,
no forked children): the point is that open-loop admission at 4x the
gated client count neither loses requests nor wedges, using the exact
``run_cell`` machinery that produced the committed
``benchmarks/results/fleet_bench.json`` sweep. Excluded from the tier-1
CI job (``-m "not proc and not slow"``) and from the fleet job's
explicit file list; ``pytest tests/test_fleet_sweep.py`` runs it.
"""
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "benchmarks"))

import fleet_bench  # noqa: E402


@pytest.mark.slow
def test_1024_client_poisson_cell_no_lost():
    clients = 1024
    n = 2 * clients                  # the bench's sweep floor for a count
    cell = fleet_bench.run_cell(4, clients, n, "poisson")
    assert not cell["lost"], cell["lost"]
    assert cell["wrong_answers"] == 0
    assert cell["completed"] + cell["typed_error_count"] == n
    # open-loop throughput should be replica-bound, not client-bound:
    # 4 replicas x ~1/SERVICE_MS each, with generous scheduling slack
    floor = 0.5 * 4 * (1000.0 / fleet_bench.SERVICE_MS)
    assert cell["throughput_rps"] >= floor, cell["throughput_rps"]
