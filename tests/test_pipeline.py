"""GPipe pipeline over MPKLink stage channels vs the single-device layer
stack — 8-device subprocess (8 stages, 1 layer each), fwd and grad."""
import os
import subprocess
import sys

_ROOT = os.path.join(os.path.dirname(__file__), "..")

CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.utils import shard_map
from repro.configs import get_reduced, replace
from repro.core.fabric import MPKLinkFabric
from repro.models import transformer as tf
from repro.models.transformer import Impl
from repro.runtime.pipeline import pipeline_apply, stage_split

cfg = replace(get_reduced("llama3.2-1b"), num_layers=8)
impl = Impl(attention="naive", remat=False)
key0 = jax.random.PRNGKey(0)
stacked = tf.init_stack(cfg, key0, cfg.num_layers)

n_micro, mb, S = 4, 2, 16
x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, mb, S, cfg.d_model))
positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (mb, S))

# single-device reference over each microbatch
ref = jnp.stack([tf.apply_stack(cfg, stacked, x[i], positions=positions,
                                impl=impl)[0] for i in range(n_micro)])

mesh = jax.make_mesh((8,), ("stage",))
fab = MPKLinkFabric(mesh, guard=True)
chan, key = fab.establish("stage-handoff", "stage")
staged = stage_split(stacked, 8)
specs = jax.tree.map(lambda a: P("stage"), staged)

def pipe(sp, xm):
    out, ok = pipeline_apply(cfg, sp, xm, fabric=fab, chan=chan, key=key,
                             impl=impl)
    return out, (jax.lax.psum(1 - ok, "stage") == 0).astype(jnp.int32)

out, ok = jax.jit(shard_map(pipe, mesh=mesh, in_specs=(specs, P()),
                            out_specs=(P(), P())))(staged, x)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)
assert int(ok) == 1

# gradients flow through the pipeline (GPipe backward via AD)
def loss_pipe(sp, xm):
    out, _ = pipeline_apply(cfg, sp, xm, fabric=fab, chan=chan, key=key,
                            impl=impl)
    return (out ** 2).sum()

def loss_ref(params, xm):
    outs = [tf.apply_stack(cfg, params, xm[i], positions=positions,
                           impl=impl)[0] for i in range(n_micro)]
    return sum((o ** 2).sum() for o in outs)

g_pipe = jax.jit(shard_map(jax.grad(loss_pipe), mesh=mesh,
                           in_specs=(specs, P()), out_specs=specs))(staged, x)
g_ref = jax.grad(loss_ref)(stacked, x)
g_ref_staged = stage_split(g_ref, 8)
for a, b in zip(jax.tree.leaves(g_pipe), jax.tree.leaves(g_ref_staged)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-4, atol=5e-4)
print("OK")
"""


def test_pipeline_matches_stack():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", CODE], capture_output=True,
                       text=True, cwd=_ROOT, env=env, timeout=560)
    assert "OK" in r.stdout, r.stdout + r.stderr
