"""Data determinism + checkpoint atomicity/retention/reshard."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.configs import get_reduced
from repro.data import Prefetcher, SyntheticDataset


def test_batch_equals_samples():
    cfg = get_reduced("llava-next-mistral-7b")
    ds = SyntheticDataset(cfg, seq_len=16, seed=1)
    b = ds.batch(5, 3)
    for r in range(3):
        srow = ds.sample(5, r)
        for k in srow:
            np.testing.assert_array_equal(b[k][r], srow[k], err_msg=k)


def test_restart_equivalence():
    """The stream is a pure function of (seed, step): two loaders at the
    same step produce identical batches regardless of history."""
    cfg = get_reduced("llama3.2-1b")
    a = SyntheticDataset(cfg, 32, seed=7)
    b = SyntheticDataset(cfg, 32, seed=7)
    _ = a.batch(0, 4), a.batch(1, 4)              # a has consumed history
    np.testing.assert_array_equal(a.batch(2, 4)["tokens"],
                                  b.batch(2, 4)["tokens"])


def test_seed_changes_stream():
    cfg = get_reduced("llama3.2-1b")
    a = SyntheticDataset(cfg, 32, seed=1).batch(0, 2)["tokens"]
    b = SyntheticDataset(cfg, 32, seed=2).batch(0, 2)["tokens"]
    assert not np.array_equal(a, b)


def test_prefetcher_orders_steps():
    cfg = get_reduced("llama3.2-1b")
    ds = SyntheticDataset(cfg, 8, seed=0)
    pf = Prefetcher(ds, global_batch=2, start_step=3, prefetch=2)
    try:
        steps = [next(pf)[0] for _ in range(4)]
        assert steps == [3, 4, 5, 6]
    finally:
        pf.close()


# -- checkpointing -------------------------------------------------------------

def _tree():
    return {"a": jnp.arange(6.0).reshape(2, 3),
            "nest": {"b": jnp.ones(4, jnp.int32), "s": jnp.int32(7)}}


def test_roundtrip_and_retention():
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=2)
        for step in (1, 2, 3):
            ck.save(step, _tree(), blocking=True)
        assert ck.list_steps() == [2, 3]
        s, restored = ck.restore(_tree())
        assert s == 3
        for a, b in zip(jax.tree.leaves(_tree()), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_no_partial_checkpoint_visible():
    """A crash mid-write leaves only .tmp dirs; restore never sees them."""
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=3)
        ck.save(1, _tree(), blocking=True)
        os.makedirs(os.path.join(d, ".tmp_2"))    # simulated dead partial
        with open(os.path.join(d, ".tmp_2", "arrays.npz"), "w") as f:
            f.write("garbage")
        assert ck.latest_step() == 1
        s, _ = ck.restore(_tree())
        assert s == 1


def test_tree_mismatch_rejected():
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ck.save(1, _tree(), blocking=True)
        with pytest.raises(ValueError, match="mismatch"):
            ck.restore({"different": jnp.zeros(1)})


def test_async_save_then_wait():
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=5)
        futs = [ck.save(s, _tree()) for s in range(3)]
        ck.wait()
        assert all(f.done() for f in futs)
        assert ck.list_steps() == [0, 1, 2]


def test_elastic_reshard_restore():
    """Save on a 4×2 mesh, restore onto 2×4 — subprocess with 8 devices."""
    import subprocess, sys
    code = """
import os, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import Checkpointer
from repro.runtime import elastic_restore, plan_remesh

with tempfile.TemporaryDirectory() as d:
    mesh_a = jax.make_mesh((4, 2), ("data", "model"))
    tree = {"w": jnp.arange(64.0).reshape(8, 8)}
    sh_a = NamedSharding(mesh_a, P("data", "model"))
    placed = jax.device_put(tree["w"], sh_a)
    ck = Checkpointer(d)
    ck.save(5, {"w": placed}, blocking=True)

    # lose half the chips: 8 → 4 → new mesh 2x2
    plan = plan_remesh(4, tp=2)
    assert plan == ((2, 2), ("data", "model")), plan
    mesh_b = jax.make_mesh((2, 2), ("data", "model"))
    step, restored = elastic_restore(ck, tree, mesh_b, {"w": P("data", "model")})
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
    assert restored["w"].sharding.mesh.shape["data"] == 2
print("OK")
"""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=os.path.join(os.path.dirname(__file__), ".."),
                       env=env, timeout=300)
    assert "OK" in r.stdout, r.stdout + r.stderr
