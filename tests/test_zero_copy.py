"""Zero-copy data plane + sharded executor: the PR-4 surface end to end.

Framing: ``seal_into``/``verify_view`` are bit-identical to
``build_frame``/``parse_frame`` for every dtype, into dirty recycled
buffers, with the pad tail MAC-covered; arena slots recycle without
aliasing live views; a mutated buffer is caught by the MAC and a view is
immutable. Streaming MAC (host + pallas + jnp) agrees with the scalar
reference for arbitrary block splits. Gateway: ``call_many`` scatter
envelopes across the worker shards keep per-channel order, per-item typed
errors, breaker semantics, and stay typed + bounded under
crash/corrupt/drop faults.
"""
import gc
import time

import numpy as np
import pytest

from repro.core import ServiceGateway, framing
from repro.core.domains import AccessViolation
from repro.core.gateway import (GW_MAGIC, _ERR, _OK, _SOK, _ROUTE_BYTES,
                                _scatter_route)
from repro.core.transports import (DropResponse, HandlerCrash,
                                   MPKLinkOptTransport, ResponseTimeout,
                                   ServiceCrashed, ServiceUnavailable,
                                   ShmTransport, TransportError, fast_mac)
from repro.core.wordcount import make_text, parse_count, wordcount_handler

TIME_BUDGET = 10.0                  # bounded-failure wall-clock ceiling
SEED = 0x5EED1234


@pytest.fixture(autouse=True)
def _restore_zero_copy():
    before = framing.ZERO_COPY
    yield
    framing.ZERO_COPY = before


def _sample(dtype, shape):
    n = int(np.prod(shape, dtype=np.int64))
    base = np.arange(max(n, 1), dtype=np.int64) % 251
    return base[:n].astype(dtype).reshape(shape)


# ---------------------------------------------------------------------------
# seal_into / verify_view: bit-identical, dirty-buffer-safe, zero copy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("code", sorted(framing._DTYPES))
def test_seal_into_bit_identical_every_dtype(code):
    dtype = framing._DTYPES[code]
    for shape in [(0,), (1,), (13,), (128,), (3, 4), (2, 3, 4), (513,)]:
        arr = _sample(dtype, shape)
        rows = framing.frame_rows(arr.nbytes)
        # dirty oversized buffer: stale garbage from a recycled slot must
        # never leak into the frame (pad tail + reserved lanes rewritten)
        buf = np.full((rows + 3, framing.LANES), 0xDEADBEEF, np.uint32)
        used = framing.seal_into(buf, arr, seed=SEED, seq=7)
        assert used == rows
        frame = framing.build_frame(arr, seed=SEED, seq=7)
        np.testing.assert_array_equal(buf[:rows], frame)
        # the PR 3 legacy concat path produces the same bytes
        framing.ZERO_COPY = False
        legacy = framing.build_frame(arr, seed=SEED, seq=7)
        framing.ZERO_COPY = True
        np.testing.assert_array_equal(legacy, frame)
        # verify_view: guard passes, payload aliases the buffer, read-only
        out = framing.verify_view(buf[:rows], seed=SEED, expect_seq=7)
        np.testing.assert_array_equal(out, arr)
        assert not out.flags.writeable
        if arr.nbytes:
            assert out.base is not None          # a view, not a copy
        np.testing.assert_array_equal(
            framing.parse_frame(buf[:rows], seed=SEED, expect_seq=7), arr)


def test_seal_into_batch_matches_seal_batch():
    arrays = [_sample(np.uint8, (n,)) for n in (1, 511, 512, 4096)] \
        + [_sample(np.int32, (3, 4)), np.zeros(0, np.uint8)]
    seqs = [3, 9, 12, 40, 41, 42]
    scalar = framing.seal_batch(arrays, seed=SEED, seqs=seqs)
    bufs = [np.full((framing.frame_rows(a.nbytes), framing.LANES),
                    0xA5A5A5A5, np.uint32) for a in arrays]
    rows = framing.seal_into_batch(bufs, arrays, seed=SEED, seqs=seqs)
    for b, r, s in zip(bufs, rows, scalar):
        np.testing.assert_array_equal(b[:r], s)
    # forced scalar MAC impl agrees with the fused pass
    bufs2 = [np.empty_like(b) for b in bufs]
    framing.seal_into_batch(bufs2, arrays, seed=SEED, seqs=seqs,
                            mac_impl=framing._mac_np)
    for a, b in zip(bufs, bufs2):
        np.testing.assert_array_equal(a, b)


def test_verify_view_catches_mutated_buffer_and_views_are_immutable():
    arr = _sample(np.int32, (300,))
    buf = np.empty((framing.frame_rows(arr.nbytes), framing.LANES),
                   np.uint32)
    rows = framing.seal_into(buf, arr, seed=SEED, seq=0)
    out = framing.verify_view(buf[:rows], seed=SEED, expect_seq=0)
    with pytest.raises(ValueError):     # read-only view
        out[0] = 1
    # a single payload bit flipped THROUGH THE BUFFER after sealing fails
    # the MAC — in-place sealing does not weaken the guard
    buf[2, 17] ^= np.uint32(1 << 4)
    with pytest.raises(framing.FrameError, match="MAC"):
        framing.verify_view(buf[:rows], seed=SEED, expect_seq=0)
    # pad-tail tampering is caught too (the pad is MAC-covered)
    buf[2, 17] ^= np.uint32(1 << 4)
    framing.verify_view(buf[:rows], seed=SEED, expect_seq=0)
    buf[rows - 1, framing.LANES - 1] ^= np.uint32(1)
    with pytest.raises(framing.FrameError):
        framing.verify_view(buf[:rows], seed=SEED, expect_seq=0)


def test_seal_into_rejects_bad_buffers():
    arr = _sample(np.uint8, (4096,))
    small = np.empty((2, framing.LANES), np.uint32)
    with pytest.raises(framing.FrameError, match="too small"):
        framing.seal_into(small, arr, seed=SEED, seq=0)
    wrong = np.empty((9, 64), np.uint32)
    with pytest.raises(framing.FrameError):
        framing.seal_into(wrong, arr, seed=SEED, seq=0)
    ro = np.empty((9, framing.LANES), np.uint32)
    ro.flags.writeable = False
    with pytest.raises(framing.FrameError):
        framing.seal_into(ro, arr, seed=SEED, seq=0)


def test_arena_recycles_without_aliasing_live_views():
    import weakref

    arena = framing.FrameArena(min_rows=4)
    arr = _sample(np.uint8, (700,))
    buf = arena.acquire(framing.frame_rows(arr.nbytes))
    rows = framing.seal_into(buf, arr, seed=SEED, seq=0)
    view = framing.verify_view(buf[:rows], seed=SEED, expect_seq=0)
    arena.release_on_collect(view, buf)
    wr = weakref.ref(buf)
    del buf                 # like a ring slot: only the view + pool remain
    expected = np.asarray(view).copy()
    # while the view is alive its slot is NOT in the free list: every new
    # acquisition hands out a different buffer
    others = [arena.acquire(rows) for _ in range(8)]
    assert all(o is not wr() for o in others)
    for o in others:
        arena.release(o)
    np.testing.assert_array_equal(view, expected)   # nobody scribbled on it
    del view
    gc.collect()
    # the slot recycles only after the LAST alias died
    assert wr() is not None                         # pooled, not GC'd
    got = [arena.acquire(rows) for _ in range(9)]
    assert any(g is wr() for g in got)


def test_arena_never_recycles_under_a_derived_view():
    """numpy collapses view base chains, so a DERIVED sub-view of a polled
    response references the arena buffer directly; dropping the parent
    view must NOT recycle the slot under the sub-view."""
    tr = MPKLinkOptTransport(lambda r: np.asarray(r), ring_slots=4)
    s = tr.connect("alias")
    try:
        t = s.submit(np.arange(64, dtype=np.uint8))
        s.flush()
        resp = s.poll(t)
        derived = resp[:16]                 # .base is the arena buffer
        expected = derived.copy()
        del resp
        gc.collect()
        for _ in range(10):                 # churn that would reuse the slot
            t2 = s.submit(np.full(64, 255, np.uint8))
            s.flush()
            s.poll(t2)
        gc.collect()
        np.testing.assert_array_equal(derived, expected)
    finally:
        tr.close()


def test_pack_payload_pad_path_has_no_concat(monkeypatch):
    arr = _sample(np.uint8, (13,))              # needs padding
    def boom(*a, **k):                           # noqa: E306
        raise AssertionError("np.concatenate on the pack path")
    monkeypatch.setattr(np, "concatenate", boom)
    u32, meta = framing.pack_payload(arr)
    monkeypatch.undo()
    assert u32.shape == (1, framing.LANES)
    np.testing.assert_array_equal(framing.unpack_payload(u32, meta), arr)
    # aligned inputs stay zero-copy views
    aligned = _sample(np.uint8, (1024,))
    u32a, _ = framing.pack_payload(aligned)
    assert u32a.base is not None


def test_frame_stats_hook_counts_copies():
    stats0 = framing.STATS.snapshot()
    arr = _sample(np.uint8, (2048,))
    buf = np.empty((framing.frame_rows(arr.nbytes), framing.LANES),
                   np.uint32)
    rows = framing.seal_into(buf, arr, seed=SEED, seq=0)
    framing.verify_view(buf[:rows], seed=SEED, expect_seq=0)
    d = {k: v - stats0[k] for k, v in framing.STATS.snapshot().items()}
    assert d["frames_sealed"] == 1 and d["frames_sealed_inplace"] == 1
    assert d["bytes_copied"] == arr.nbytes      # exactly ONE payload write
    assert d["concat_calls"] == 0
    assert d["views_returned"] == 1
    # the legacy path is measurably copy-heavier — that's the bench baseline
    framing.ZERO_COPY = False
    stats1 = framing.STATS.snapshot()
    framing.build_frame(arr, seed=SEED, seq=0)
    d2 = {k: v - stats1[k] for k, v in framing.STATS.snapshot().items()}
    assert d2["concat_calls"] >= 1 and d2["bytes_copied"] > arr.nbytes


# ---------------------------------------------------------------------------
# streaming MAC: host + device twins, arbitrary splits
# ---------------------------------------------------------------------------

def test_streaming_mac_matches_scalar_for_any_split():
    rng = np.random.default_rng(11)
    p = rng.integers(0, 1 << 32, size=(37, framing.LANES),
                     dtype=np.int64).astype(np.uint32)
    ref = framing._mac_np(p, SEED)
    assert fast_mac(p, SEED) == ref
    for cuts in [(37,), (1, 36), (5, 1, 14, 17), (36, 1)]:
        h = framing.mac_init_np(SEED)
        s = 0
        for c in cuts:
            h = framing.mac_update_np(h, p[s:s + c])
            s += c
        assert framing.mac_finalize_np(h) == ref, cuts
    # empty update is the identity
    h = framing.mac_init_np(SEED)
    h = framing.mac_update_np(h, p[:0])
    h = framing.mac_update_np(h, p)
    assert framing.mac_finalize_np(h) == ref


def test_streaming_mac_kernels_agree_with_host():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    from repro.kernels.ops import (guard_mac_finalize, guard_mac_init,
                                   guard_mac_update)
    from repro.kernels.ref import mac_ref

    stack = np.asarray(jax.random.bits(jax.random.PRNGKey(3), (24, 128),
                                       dtype=jnp.uint32))
    tag = 0x77
    ref = int(mac_ref(jnp.asarray(stack), jnp.uint32(tag)))
    assert framing._mac_np(stack, tag) == ref
    for impl in ("pallas", "jnp"):
        h = guard_mac_init(jnp.uint32(tag))
        for s, e in ((0, 8), (8, 9), (9, 24)):
            h = guard_mac_update(h, jnp.asarray(stack[s:e]), impl=impl,
                                 rows_per_tile=4)
        assert int(guard_mac_finalize(h)) == ref, impl


def test_mac_batch_block_loop_matches_scalar():
    """The hoisted power tables (one per block size, cached) leave the
    fused block loop bit-identical to the scalar MAC."""
    rng = np.random.default_rng(5)
    stack = rng.integers(0, 1 << 32, size=(3, 23, framing.LANES),
                         dtype=np.int64).astype(np.uint32)
    small_blocks = framing._mac_batch_np(stack, SEED, block_rows=4)
    one_block = framing._mac_batch_np(stack, SEED)
    scalar = [framing._mac_np(s, SEED) for s in stack]
    assert list(small_blocks) == list(one_block) == scalar


# ---------------------------------------------------------------------------
# transport ring: arena staging, view lifetime
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cls", [ShmTransport, MPKLinkOptTransport])
def test_ring_poll_views_survive_slot_recycling(cls):
    """poll() hands back a read-only view; traffic that recycles the arena
    slots must never scribble on a view the caller still holds."""
    tr = cls(lambda r: np.asarray(r), ring_slots=4)
    s = tr.connect("viewer")
    try:
        t0 = s.submit(make_text(100, seed=1))
        s.flush()
        held = s.poll(t0)
        assert not held.flags.writeable
        expected = np.asarray(held).copy()
        for i in range(12):                 # recycle slots many times over
            t = s.submit(make_text(50 + i, seed=i))
            s.flush()
            s.poll(t)
        np.testing.assert_array_equal(held, expected)
    finally:
        tr.close()


def test_ring_arena_recycles_buffers():
    tr = MPKLinkOptTransport(wordcount_handler, ring_slots=4)
    s = tr.connect("recycler")
    try:
        for i in range(8):
            outs = s.call_batch([make_text(20 + j, seed=j)
                                 for j in range(3)])
            assert [parse_count(np.asarray(o)) for o in outs] \
                == [20, 21, 22]
            del outs
        gc.collect()
        assert tr.arena.free_slots() > 0    # slots actually recycle
    finally:
        tr.close()


def test_legacy_mode_interoperates_on_the_wire():
    """A legacy-built (PR 3 copy pattern) exchange and a zero-copy exchange
    share one session/sequence — both sides accept either, proving the
    flag changes allocation strategy, not the protocol."""
    tr = MPKLinkOptTransport(wordcount_handler)
    s = tr.connect("mixed")
    try:
        framing.ZERO_COPY = False
        assert parse_count(np.asarray(s.request(make_text(5, seed=0)))) == 5
        framing.ZERO_COPY = True
        assert parse_count(np.asarray(s.request(make_text(6, seed=0)))) == 6
        assert s._seq == 2
    finally:
        tr.close()


# ---------------------------------------------------------------------------
# gateway scatter envelope + sharded executor
# ---------------------------------------------------------------------------

def _scatter_gw(workers, **svc_kw):
    gw = ServiceGateway("mpklink_opt", workers=workers)
    gw.register_service("wordcount", wordcount_handler, **svc_kw)
    gw.register_service("reverse",
                        lambda r: np.ascontiguousarray(np.asarray(r)[::-1]),
                        **svc_kw)
    gw.register_service("double",
                        lambda r: (np.asarray(r).astype(np.int64) * 2)
                        .astype(np.int32), **svc_kw)
    gw.register_service("sum",
                        lambda r: np.asarray(
                            [int(np.asarray(r).astype(np.int64).sum())],
                            np.int64), **svc_kw)
    return gw.start()


@pytest.mark.parametrize("workers", [0, 1, 4])
def test_call_many_roundtrip_across_services(workers):
    gw = _scatter_gw(workers)
    try:
        c = gw.connect("scat")
        arr = np.arange(9, dtype=np.int32)
        items = [("wordcount", make_text(31, seed=0)), ("reverse", arr),
                 ("double", arr), ("sum", arr),
                 ("reverse", arr + 100)]        # same channel twice, ordered
        outs = c.call_many(items)
        assert parse_count(outs[0]) == 31
        np.testing.assert_array_equal(np.asarray(outs[1]), arr[::-1])
        np.testing.assert_array_equal(np.asarray(outs[2]), arr * 2)
        assert int(np.asarray(outs[3]).view(np.int64)[0]) == int(arr.sum())
        np.testing.assert_array_equal(np.asarray(outs[4]), (arr + 100)[::-1])
        # sequences aligned: single calls interleave on the same channels
        np.testing.assert_array_equal(
            np.asarray(c.call("reverse", arr)), arr[::-1])
        assert parse_count(c.call("wordcount", make_text(8, seed=1))) == 8
        outs2 = c.call_many([("sum", arr), ("wordcount", make_text(4, seed=2))])
        assert parse_count(outs2[1]) == 4
        assert gw.stats["scatter_envelopes"] == 2
        assert gw.stats["rejected"] == 0
        if workers:
            assert sum(s["executed"] for s in gw.shard_stats()) >= 2
    finally:
        gw.close()


def test_call_many_per_item_typed_errors():
    def picky(req):
        if np.asarray(req).size == 1:
            raise ValueError("bad apple")
        return np.asarray(req)

    gw = ServiceGateway("mpklink_opt", workers=2)
    gw.register_service("picky", picky, failure_threshold=100)
    gw.register_service("wordcount", wordcount_handler)
    gw.start()
    try:
        c = gw.connect("x")
        res = c.call_many(
            [("picky", np.arange(4, dtype=np.int32)),
             ("picky", np.zeros(1, np.int32)),
             ("wordcount", make_text(6, seed=0))], return_exceptions=True)
        np.testing.assert_array_equal(
            np.asarray(res[0]).view(np.int32), np.arange(4, dtype=np.int32))
        assert isinstance(res[1], TransportError)
        assert "bad apple" in str(res[1])
        assert parse_count(res[2]) == 6
        # without return_exceptions: first error raised after the drain,
        # and every item consumed a sequence — the channels stay aligned
        with pytest.raises(TransportError, match="bad apple"):
            c.call_many([("picky", np.zeros(1, np.int32))])
        out = c.call_many([("picky", np.arange(2, dtype=np.int32))])
        np.testing.assert_array_equal(
            np.asarray(out[0]).view(np.int32), np.arange(2, dtype=np.int32))
    finally:
        gw.close()


def test_call_many_token_replay_dedups():
    """A manual retry that replays the SAME pre-minted tokens is answered
    from the dedup window — executed items never run twice; a bare
    re-issue (fresh tokens) re-executes."""
    calls = []

    def counting(req):
        calls.append(1)
        return np.asarray(req)

    gw = ServiceGateway("mpklink_opt", workers=2)
    gw.register_service("counting", counting)
    gw.start()
    try:
        c = gw.connect("r")
        items = [("counting", np.arange(3, dtype=np.int32)),
                 ("counting", np.arange(4, dtype=np.int32))]
        tokens = c.mint_tokens(len(items))
        outs = c.call_many(items, tokens=tokens)
        assert len(calls) == 2
        replay = c.call_many(items, tokens=tokens)      # idempotent retry
        assert len(calls) == 2 and gw.stats["deduped"] == 2
        for a, b in zip(outs, replay):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        c.call_many(items)                  # fresh tokens → re-executes
        assert len(calls) == 4
        with pytest.raises(ValueError, match="tokens"):
            c.call_many(items, tokens=[1])
    finally:
        gw.close()


def test_scatter_wire_replay_answered_from_dedup_window():
    """A replay of the exact scatter envelope (lost response, same tokens,
    same sequences) is answered item-by-item from the dedup window — the
    dedup check runs BEFORE the sequence check, handlers never re-execute,
    and the forward-only advance leaves the channel aligned."""
    calls = []

    def counting(req):
        calls.append(1)
        return np.asarray(req)

    gw = ServiceGateway("mpklink_opt", workers=2)
    gw.register_service("counting", counting)
    gw.start()
    try:
        c = gw.connect("rp")
        items = [("counting", np.arange(3, dtype=np.int32)),
                 ("counting", np.arange(5, dtype=np.int32))]
        tokens = c.mint_tokens(len(items))
        # capture the exact bytes the envelope puts on the wire
        captured = {}
        orig_ri = c._session.request_into

        def capture(nbytes, fill, **kw):
            env = np.empty(nbytes, np.uint8)
            fill(env)
            captured["env"] = env.copy()
            return c._session.request(env, **kw)

        c._session.request_into = capture
        c.call_many(items, tokens=tokens)
        c._session.request_into = orig_ri
        assert len(calls) == 2
        chan = c._channels["counting"]
        seq_after = chan.server_seq
        # replay the identical envelope (as if the response had been lost
        # and the client resent): every item OK from the window, nothing
        # re-executes, the server sequence does not move
        resp = np.ascontiguousarray(
            np.asarray(c._session.request(captured["env"]))) \
            .view(np.uint8).reshape(-1)
        route = resp[:_ROUTE_BYTES].view("<u4")
        assert int(route[1]) == _SOK
        statuses, ofs = [], _ROUTE_BYTES
        for _ in range(2):
            ih = resp[ofs: ofs + _ROUTE_BYTES].view("<u4")
            statuses.append(int(ih[1]))
            nb = int(ih[2])
            ofs += _ROUTE_BYTES + nb + ((-nb) % 4)
        assert statuses == [_OK, _OK]
        assert len(calls) == 2 and gw.stats["deduped"] == 2
        assert chan.server_seq == seq_after
        # channel still aligned for fresh traffic
        outs = c.call_many(items)
        assert len(calls) == 4 and len(outs) == 2
    finally:
        gw.close()


@pytest.mark.parametrize("corrupt_idx", [1, 2])
def test_scatter_corrupt_frame_is_per_item_frame_error(corrupt_idx):
    """Hand-rolled scatter envelope with one tampered frame (middle OR
    tail): that item's status is ERR, its neighbours verify, and the
    channel stays aligned — a fresh envelope consumes one slot per item
    even when the FAILING item is the last one (no rescuer behind it)."""
    gw = _scatter_gw(2)
    try:
        c = gw.connect("m")
        chan = c.open("wordcount")
        frames = [framing.build_frame(make_text(n, seed=n), seed=chan.seed,
                                      seq=chan.seq + i)
                  for i, n in enumerate((3, 4, 5))]
        frames[corrupt_idx] = frames[corrupt_idx].copy()
        frames[corrupt_idx][0, 11] ^= np.uint32(1 << 3)
        parts = [_scatter_route(c.cid, 3)]
        for f in frames:
            parts.append(np.array([GW_MAGIC, chan.sid, 0, 0], "<u4")
                         .view(np.uint8))
            parts.append(f.reshape(-1).view(np.uint8))
        resp = np.ascontiguousarray(
            np.asarray(c._session.request(np.concatenate(parts)))) \
            .view(np.uint8).reshape(-1)
        route = resp[:_ROUTE_BYTES].view("<u4")
        assert int(route[0]) == GW_MAGIC and int(route[1]) == _SOK
        statuses, ofs = [], _ROUTE_BYTES
        for _ in range(3):
            ih = resp[ofs: ofs + _ROUTE_BYTES].view("<u4")
            statuses.append(int(ih[1]))
            nb = int(ih[2])
            ofs += _ROUTE_BYTES + nb + ((-nb) % 4)
        expected = [_OK, _OK, _OK]
        expected[corrupt_idx] = _ERR
        assert statuses == expected
        assert gw.stats["macs_verified"] == 2
        assert gw.stats["rejected"] == 1
        chan.seq += 3                       # our hand-rolled envelope's seqs
        assert parse_count(c.call("wordcount", make_text(6, seed=0))) == 6
        outs = c.call_many([("wordcount", make_text(9, seed=1))])
        assert parse_count(outs[0]) == 9
    finally:
        gw.close()


def test_call_many_corrupt_response_item_stays_per_item():
    """A response item corrupted on the wire surfaces as ITS typed
    FrameError (verify_batch strict=False) while the other items verify —
    and the channels stay aligned (every item consumed a sequence), so
    the next scatter works without a reopen."""
    gw = _scatter_gw(2)
    flip = {"armed": False}
    try:
        c = gw.connect("w")
        items = [("wordcount", make_text(5, seed=0)),
                 ("reverse", np.arange(6, dtype=np.int32))]
        c.call_many(items)                  # channels open, seqs advanced

        orig_ri = c._session.request_into

        def tamper(nbytes, fill, **kw):
            resp = orig_ri(nbytes, fill, **kw)
            if not flip["armed"]:
                return resp
            flip["armed"] = False
            raw = np.ascontiguousarray(np.asarray(resp)) \
                .view(np.uint8).copy()
            # corrupt the FIRST OK item's frame payload (scatter route +
            # per-item route + header row, then payload bytes)
            raw[_ROUTE_BYTES + _ROUTE_BYTES + 512 + 4] ^= 0x40
            return raw

        c._session.request_into = tamper
        flip["armed"] = True
        res = c.call_many(items, return_exceptions=True)
        assert isinstance(res[0], framing.FrameError)
        np.testing.assert_array_equal(
            np.asarray(res[1]), np.arange(6, dtype=np.int32)[::-1])
        # channels aligned: the next scatter (and single call) both work
        outs = c.call_many(items)
        assert parse_count(outs[0]) == 5
        assert parse_count(c.call("wordcount", make_text(7, seed=1))) == 7
    finally:
        gw.close()


def test_scatter_crash_under_workers_typed_and_bounded():
    """HandlerCrash fired on a shard worker mid-scatter: the client gets an
    immediate typed ServiceCrashed (the crash is relayed to the session
    thread — never a deadline stall), the shard itself survives, and a
    healed client resumes scattering."""
    calls = []

    def crashy(req):
        calls.append(1)
        if len(calls) == 2:
            raise HandlerCrash("boom on a shard")
        return np.asarray(req)

    gw = ServiceGateway("mpklink_opt", workers=2,
                        transport_kwargs={"timeout": TIME_BUDGET * 3})
    gw.register_service("crashy", crashy)
    gw.register_service("wordcount", wordcount_handler)
    gw.start()
    t0 = time.monotonic()
    try:
        c = gw.connect("b")
        items = [("crashy", np.arange(3, dtype=np.int32)),
                 ("wordcount", make_text(5, seed=0))]
        outs = c.call_many(items)
        assert parse_count(outs[1]) == 5
        with pytest.raises(ServiceCrashed):
            c.call_many(items)
        c.heal("crashy")
        c.heal("wordcount")
        outs = c.call_many(items)           # shard survived the crash
        assert parse_count(outs[1]) == 5
        assert gw.stats["crashes"] == 1
    finally:
        gw.close()
    assert time.monotonic() - t0 < TIME_BUDGET


def test_scatter_drop_under_workers_bounded():
    """DropResponse on a shard: the whole scatter response is dropped (the
    wire ate the reply) and the client's bounded wait expires typed."""
    def droppy(req):
        raise DropResponse("dropped")

    gw = ServiceGateway("mpklink_opt", workers=2,
                        transport_kwargs={"timeout": 0.4})
    gw.register_service("droppy", droppy)
    gw.register_service("wordcount", wordcount_handler)
    gw.start()
    t0 = time.monotonic()
    try:
        c = gw.connect("d")
        with pytest.raises(ResponseTimeout):
            c.call_many([("droppy", np.arange(2, dtype=np.int32)),
                         ("wordcount", make_text(4, seed=0))])
    finally:
        gw.close()
    assert time.monotonic() - t0 < TIME_BUDGET


def test_scatter_breaker_sheds_per_item_and_recovers():
    """A service whose failures trip its circuit sheds scatter items with
    typed ServiceUnavailable while co-scattered services keep answering —
    breaker semantics identical to the single-call path."""
    def boom(req):
        raise ValueError("kaput")

    gw = ServiceGateway("mpklink_opt", workers=2)
    gw.register_service("boom", boom, failure_threshold=2, probe_after=100)
    gw.register_service("wordcount", wordcount_handler)
    gw.start()
    try:
        c = gw.connect("s")
        for _ in range(2):                  # trip the breaker
            res = c.call_many([("boom", np.zeros(2, np.int32)),
                               ("wordcount", make_text(3, seed=0))],
                              return_exceptions=True)
            assert isinstance(res[0], TransportError)
            assert parse_count(res[1]) == 3
        res = c.call_many([("boom", np.zeros(2, np.int32)),
                           ("wordcount", make_text(7, seed=0))],
                          return_exceptions=True)
        assert isinstance(res[0], ServiceUnavailable)   # shed, not executed
        assert parse_count(res[1]) == 7
        assert gw.health()["boom"]["state"] == "open"
        assert gw.stats["sheds"] >= 1
    finally:
        gw.close()


def test_scatter_stale_epoch_is_per_item_and_recoverable():
    gw = _scatter_gw(2)
    try:
        a, b = gw.connect("alice"), gw.connect("bob")
        assert parse_count(a.call("wordcount", make_text(3, seed=0))) == 3
        assert parse_count(
            b.call_many([("wordcount", make_text(4, seed=0))])[0]) == 4
        gw.revoke(a, "wordcount")           # epoch bump stales bob's key
        res = b.call_many([("wordcount", make_text(5, seed=0))],
                          return_exceptions=True)
        assert isinstance(res[0], AccessViolation)
        b.reopen("wordcount")               # still certified: re-key works
        assert parse_count(
            b.call_many([("wordcount", make_text(6, seed=0))])[0]) == 6
    finally:
        gw.close()


def test_workers_mode_leaves_single_and_batch_paths_unchanged():
    gw = _scatter_gw(4)
    try:
        c = gw.connect("plain")
        assert parse_count(c.call("wordcount", make_text(12, seed=0))) == 12
        outs = c.call_batch("wordcount",
                            [make_text(n, seed=n) for n in (2, 30, 400)])
        assert [parse_count(o) for o in outs] == [2, 30, 400]
    finally:
        gw.close()


def test_scatter_smaller_than_route_rejected_typed():
    gw = _scatter_gw(0)
    try:
        c = gw.connect("t")
        c.open("wordcount")
        env = _scatter_route(c.cid, 2)      # declares 2 items, carries none
        resp = np.ascontiguousarray(np.asarray(c._session.request(env))) \
            .view(np.uint8).reshape(-1)
        route = resp[:_ROUTE_BYTES].view("<u4")
        assert int(route[1]) == _ERR
        from repro.core.transports import _raise_remote
        with pytest.raises(framing.FrameError):
            _raise_remote(resp[_ROUTE_BYTES:
                               _ROUTE_BYTES + int(route[3])].tobytes())
        # no sequence consumed: the channel still works
        assert parse_count(c.call("wordcount", make_text(9, seed=0))) == 9
    finally:
        gw.close()
