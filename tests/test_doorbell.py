"""Doorbell/credit data plane: hybrid spin/park wakeups, credit-based ring
flow control, per-poll timeouts, and thread-safe FrameStats accounting.

The contract under test (normative in docs/protocol.md §4.4):

* one doorbell ring covers a whole publish/drain pass — wakeups scale with
  round trips, not messages;
* ``submit()`` against a full ring backpressures (a concurrent ``poll()``
  grants the credit) and only raises typed ``CapacityError`` after the
  bounded ``credit_wait``;
* ``poll(ticket, timeout=...)`` honors a timeout tighter than the transport
  deadline — on the ring transports (through the doorbell wait) AND on the
  stream transports' lockstep fallback;
* ``framing.STATS`` counters are exact under concurrent writers.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import framing
from repro.core.transports import (CapacityError, Doorbell,
                                   MPKLinkOptTransport, PipeTransport,
                                   ResponseTimeout, ShmTransport,
                                   TransportError)
from repro.core.wordcount import make_text, parse_count, wordcount_handler


def _echo(req):
    return np.asarray(req)


# ---------------------------------------------------------------------------
# Doorbell primitive
# ---------------------------------------------------------------------------

def test_doorbell_ring_wakes_parked_waiter_and_counts():
    bell = Doorbell(threading.RLock(), spin=0)
    state = {"flag": False}
    woke = threading.Event()

    def waiter():
        assert bell.wait(lambda: state["flag"], timeout=10.0)
        woke.set()

    st0 = framing.STATS.snapshot()
    t = threading.Thread(target=waiter, daemon=True)
    t.start()
    time.sleep(0.05)                    # let it park
    with bell.cond:
        state["flag"] = True
    bell.ring()
    assert woke.wait(5.0), "parked waiter never woke on ring()"
    t.join(5.0)
    st1 = framing.STATS.snapshot()
    assert st1["wakeups"] - st0["wakeups"] == 1
    assert st1["doorbell_parks"] - st0["doorbell_parks"] >= 1


def test_doorbell_true_predicate_never_parks():
    bell = Doorbell(threading.RLock())
    st0 = framing.STATS.snapshot()
    assert bell.wait(lambda: True, timeout=0.0)
    st1 = framing.STATS.snapshot()
    assert st1["doorbell_parks"] == st0["doorbell_parks"]


def test_doorbell_wait_times_out_false():
    bell = Doorbell(threading.RLock(), spin=0)
    t0 = time.perf_counter()
    assert not bell.wait(lambda: False, timeout=0.05)
    assert time.perf_counter() - t0 < 2.0


# ---------------------------------------------------------------------------
# wakeups scale with round trips, not messages
# ---------------------------------------------------------------------------

def test_batch_wakeups_are_per_pass_not_per_message():
    """16 lockstep exchanges ring ~3 bells each; one 16-message call_batch
    rings a small constant for the whole cohort."""
    tr = MPKLinkOptTransport(wordcount_handler, ring_slots=16)
    lock = tr.connect("lockstep")
    lock.request(make_text(3, seed=0))          # warm the session
    st0 = framing.STATS.snapshot()
    for i in range(16):
        lock.request(make_text(i + 1, seed=i))
    lockstep_wakeups = framing.STATS.snapshot()["wakeups"] - st0["wakeups"]

    batch = tr.connect("batched")
    batch.request(make_text(3, seed=0))
    st0 = framing.STATS.snapshot()
    outs = batch.call_batch([make_text(i + 1, seed=i) for i in range(16)])
    batch_wakeups = framing.STATS.snapshot()["wakeups"] - st0["wakeups"]
    tr.close()
    assert [parse_count(np.asarray(o)) for o in outs] == list(range(1, 17))
    assert lockstep_wakeups >= 3 * 16
    assert batch_wakeups <= 8, \
        f"a 16-message batch rang {batch_wakeups} bells (want one per pass)"
    assert lockstep_wakeups >= 4 * batch_wakeups


def test_key_syncs_mirrored_into_frame_stats():
    tr = MPKLinkOptTransport(wordcount_handler)
    s = tr.connect("sync-stats")
    s.request(make_text(3, seed=0))
    st0 = framing.STATS.snapshot()
    base = tr.sync_count
    for i in range(4):
        s.request(make_text(i + 1, seed=i))
    delta_local = tr.sync_count - base
    delta_stats = framing.STATS.snapshot()["key_syncs"] - st0["key_syncs"]
    tr.close()
    assert delta_local == delta_stats == 8      # 2 per lockstep exchange


# ---------------------------------------------------------------------------
# credit-based flow control
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cls", [ShmTransport, MPKLinkOptTransport])
def test_full_ring_backpressures_with_concurrent_poller(cls):
    """A producer thread pushes 4x the ring depth while a consumer polls:
    submit() must block for credits and never raise CapacityError."""
    tr = cls(wordcount_handler, ring_slots=4, credit_wait=10.0)
    s = tr.connect("pc")
    total = 16
    tickets: list = []
    errs: list = []
    got: list = []
    tcv = threading.Condition()

    def producer():
        try:
            for i in range(total):
                t = s.submit(make_text(i + 1, seed=i))
                with tcv:
                    tickets.append(t)
                    tcv.notify_all()
                s.flush()
        except Exception as e:
            errs.append(e)
            with tcv:
                tcv.notify_all()

    def consumer():
        try:
            for i in range(total):
                with tcv:
                    while len(tickets) <= i and not errs:
                        tcv.wait(5.0)
                    if errs:
                        return
                    t = tickets[i]
                got.append(parse_count(np.asarray(s.poll(t, timeout=10.0))))
        except Exception as e:
            errs.append(e)

    tp = threading.Thread(target=producer, daemon=True)
    tc = threading.Thread(target=consumer, daemon=True)
    tp.start()
    tc.start()
    tp.join(30.0)
    tc.join(30.0)
    tr.close()
    assert not errs, errs
    assert got == list(range(1, total + 1))


def test_full_ring_without_poller_raises_typed_after_bounded_wait():
    tr = ShmTransport(wordcount_handler, ring_slots=2, credit_wait=0.1)
    s = tr.connect("serial-overflow")
    try:
        t0 = s.submit(make_text(1, seed=0))
        t1 = s.submit(make_text(2, seed=0))
        start = time.perf_counter()
        with pytest.raises(CapacityError, match="ring full"):
            s.submit(make_text(3, seed=0))
        elapsed = time.perf_counter() - start
        assert 0.05 <= elapsed < 5.0, \
            f"credit wait not bounded by credit_wait: {elapsed}s"
        # the credit wait published the staged slots — they still redeem
        assert parse_count(np.asarray(s.poll(t0))) == 1
        assert parse_count(np.asarray(s.poll(t1))) == 2
    finally:
        tr.close()


@pytest.mark.parametrize("cls", [ShmTransport, MPKLinkOptTransport])
def test_submit_timeout_clamps_credit_wait_to_caller_budget(cls):
    """Regression: ``submit(timeout=...)`` against a full ring must clamp
    the credit wait to the caller's remaining budget. Pre-fix, the wait
    always ran the full ``credit_wait`` (here 30s) and the per-call
    deadline was silently ignored — this test then stalls past its bound.
    The caller-budget expiry raises ResponseTimeout and does NOT poison
    the session (nothing was staged); a tighter credit window still
    raises the classic CapacityError."""
    tr = cls(wordcount_handler, ring_slots=2, credit_wait=30.0)
    s = tr.connect("clamped-overflow")
    try:
        t0 = s.submit(make_text(1, seed=0))
        t1 = s.submit(make_text(2, seed=0))
        start = time.perf_counter()
        with pytest.raises(ResponseTimeout, match="call budget"):
            s.submit(make_text(3, seed=0), timeout=0.05)
        assert time.perf_counter() - start < 5.0, \
            "caller budget did not clamp the 30s credit_wait"
        # not poisoned: the in-flight tickets still redeem
        assert parse_count(np.asarray(s.poll(t0))) == 1
        assert parse_count(np.asarray(s.poll(t1))) == 2
    finally:
        tr.close()
    tr2 = cls(wordcount_handler, ring_slots=2, credit_wait=0.08)
    s2 = tr2.connect("credit-overflow")
    try:
        u0 = s2.submit(make_text(1, seed=1))
        u1 = s2.submit(make_text(2, seed=1))
        # credit window tighter than the generous caller budget → the
        # credit bound is the one that expires, typed CapacityError
        with pytest.raises(CapacityError, match="ring full"):
            s2.submit(make_text(3, seed=1), timeout=30.0)
        assert parse_count(np.asarray(s2.poll(u0))) == 1
        assert parse_count(np.asarray(s2.poll(u1))) == 2
    finally:
        tr2.close()


# ---------------------------------------------------------------------------
# per-poll / per-request timeouts
# ---------------------------------------------------------------------------

def _slow_handler(req):
    time.sleep(1.0)
    return np.asarray(req)


@pytest.mark.parametrize("cls", [MPKLinkOptTransport, ShmTransport])
def test_ring_poll_honors_tighter_timeout(cls):
    """Transport deadline is 30s; poll(timeout=0.15) must expire in well
    under a second — plumbed through the doorbell wait."""
    tr = cls(_slow_handler, timeout=30.0)
    s = tr.connect("tight")
    try:
        t = s.submit(np.arange(8, dtype=np.uint8))
        s.flush()
        t0 = time.perf_counter()
        with pytest.raises(ResponseTimeout):
            s.poll(t, timeout=0.15)
        assert time.perf_counter() - t0 < 5.0
        assert s._poisoned                  # same poisoning as a full expiry
    finally:
        tr.close()


@pytest.mark.parametrize("name", ["pipe", "uds", "grpc_sim"])
def test_lockstep_fallback_poll_honors_tighter_timeout(name):
    """The stream transports' lazy poll() runs the buffered exchange under
    the per-poll deadline (the old fallback ignored it)."""
    from repro.core import TRANSPORTS
    tr = TRANSPORTS[name](_slow_handler, timeout=30.0)
    s = tr.connect("tight-fallback")
    try:
        t = s.submit(np.arange(8, dtype=np.uint8))
        s.flush()
        t0 = time.perf_counter()
        with pytest.raises(ResponseTimeout):
            s.poll(t, timeout=0.15)
        assert time.perf_counter() - t0 < 5.0
    finally:
        tr.close()


def test_request_timeout_param_overrides_transport_deadline():
    tr = ShmTransport(_slow_handler, timeout=30.0)
    s = tr.connect("req-tight")
    try:
        t0 = time.perf_counter()
        with pytest.raises(ResponseTimeout):
            s.request(np.arange(8, dtype=np.uint8), timeout=0.15)
        assert time.perf_counter() - t0 < 5.0
    finally:
        tr.close()


def test_poll_default_timeout_still_transport_deadline():
    """No per-poll timeout → the transport deadline still applies (the
    plumbing must not tighten the default)."""
    tr = MPKLinkOptTransport(lambda req: (time.sleep(0.3), np.asarray(req))[1],
                             timeout=10.0)
    s = tr.connect("default-deadline")
    try:
        t = s.submit(np.arange(8, dtype=np.uint8))
        s.flush()
        out = s.poll(t)                     # 0.3s handler < 10s deadline
        assert np.array_equal(np.asarray(out), np.arange(8, dtype=np.uint8))
    finally:
        tr.close()


# ---------------------------------------------------------------------------
# FrameStats: exact under concurrency
# ---------------------------------------------------------------------------

def test_frame_stats_bump_is_exact_under_threads():
    st0 = framing.STATS.snapshot()
    n_threads, per_thread = 8, 2000

    def bumper():
        for _ in range(per_thread):
            framing.STATS.bump(wakeups=1, bytes_copied=3)

    ts = [threading.Thread(target=bumper) for _ in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    st1 = framing.STATS.snapshot()
    assert st1["wakeups"] - st0["wakeups"] == n_threads * per_thread
    assert st1["bytes_copied"] - st0["bytes_copied"] == 3 * n_threads * per_thread


def test_frame_stats_exact_for_concurrent_sealers():
    """N threads sealing M frames each through the real seal path — the
    sharded-counter design must not drop a single increment (the old
    unguarded += did)."""
    st0 = framing.STATS.snapshot()
    n_threads, per_thread = 6, 300
    payload = np.arange(256, dtype=np.uint8)

    def sealer(i):
        buf = np.empty((framing.frame_rows(payload.nbytes), framing.LANES),
                       np.uint32)
        for j in range(per_thread):
            framing.seal_into(buf, payload, seed=i, seq=j)

    ts = [threading.Thread(target=sealer, args=(i,)) for i in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    st1 = framing.STATS.snapshot()
    total = n_threads * per_thread
    assert st1["frames_sealed"] - st0["frames_sealed"] == total
    assert st1["frames_sealed_inplace"] - st0["frames_sealed_inplace"] == total
    assert st1["bytes_copied"] - st0["bytes_copied"] == total * payload.nbytes


def test_frame_stats_unknown_field_raises():
    with pytest.raises(KeyError):
        framing.STATS.bump(no_such_counter=1)


def test_frame_stats_attribute_reads_sum_shards():
    framing.STATS.bump(concat_calls=2)
    snap = framing.STATS.snapshot()
    assert framing.STATS.concat_calls == snap["concat_calls"]


def test_frame_stats_prunes_dead_thread_shards():
    """A process cycling many short-lived threads must not accumulate one
    counter shard per dead thread — dead shards fold into the retired
    base and totals stay exact."""
    st0 = framing.STATS.snapshot()

    def bump_once():
        framing.STATS.bump(wakeups=1)

    for _ in range(30):
        t = threading.Thread(target=bump_once)
        t.start()
        t.join()
    st1 = framing.STATS.snapshot()      # snapshot folds the dead shards
    assert st1["wakeups"] - st0["wakeups"] == 30
    with framing.STATS._rlock:
        dead = sum(1 for th, _ in framing.STATS._shards
                   if not th.is_alive())
    assert dead == 0, f"{dead} dead shards survived the fold"
