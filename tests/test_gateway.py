"""Service gateway: concurrent multi-service routing, per-service domain
isolation, revocation — plus regression tests for the seed-suite bugfixes
(zlib-fallback checkpoints, shard_map import on this jax pin, oversized shm
responses raising instead of hanging)."""
import tempfile
import threading

import numpy as np
import pytest

from repro.core import TRANSPORTS, AccessViolation, ServiceGateway, framing
from repro.core.gateway import GW_MAGIC, _ROUTE_BYTES
from repro.core.transports import (CapacityError, ShmTransport, TransportError,
                                   _raise_remote)
from repro.core.wordcount import make_text, parse_count, wordcount_handler


def _reverse(req: np.ndarray) -> np.ndarray:
    return np.ascontiguousarray(np.asarray(req)[::-1])


def _make_gateway(transport: str) -> ServiceGateway:
    gw = ServiceGateway(transport)
    gw.register_service("wordcount", wordcount_handler)
    gw.register_service("reverse", _reverse)
    return gw.start()


@pytest.mark.parametrize("name", sorted(TRANSPORTS))
def test_gateway_concurrent_two_services(name):
    """N client threads hammer two services at once over each transport;
    every response is cross-checked against its own request."""
    gw = _make_gateway(name)
    n_clients, reps = 6, 3
    errors = []

    def worker(i):
        try:
            c = gw.connect(f"client-{i}")
            for j in range(reps):
                n = 40 * (i + 1) + j
                assert parse_count(c.call("wordcount", make_text(n, seed=j))) == n
                arr = np.arange(i * 10, i * 10 + 9, dtype=np.int32)
                rev = c.call("reverse", arr)
                np.testing.assert_array_equal(np.asarray(rev), arr[::-1])
            c.close()
        except Exception as e:          # pragma: no cover - surfaced below
            errors.append((i, repr(e)))

    try:
        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(n_clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
        assert not errors, errors
        assert gw.stats["responses"] == n_clients * reps * 2
        assert gw.stats["macs_verified"] == n_clients * reps * 2
        assert gw.stats["rejected"] == 0
    finally:
        gw.close()


def test_transport_sessions_are_independent():
    """Raw transport layer: concurrent sessions each keep their own framing
    sequence and never see each other's traffic."""
    tr = TRANSPORTS["mpklink_opt"](wordcount_handler, max_keys=16)
    tr.start()
    errors = []

    seeds = []

    def worker(i):
        try:
            s = tr.connect(f"peer-{i}")
            for j in range(3):
                n = 25 * (i + 1) + j
                assert parse_count(s.request(make_text(n, seed=i))) == n
            assert s._seq == 3
            seeds.append(s.seed)
            s.close()
        except Exception as e:          # pragma: no cover
            errors.append((i, repr(e)))

    try:
        threads = [threading.Thread(target=worker, args=(i,)) for i in range(5)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
        assert not errors, errors
        # every session got its own domain-derived MAC seed
        assert len(set(seeds)) == 5 and tr.seed not in seeds
    finally:
        tr.close()


def test_gateway_foreign_key_rejected():
    """A client holding a key for service A gets AccessViolation/guard
    rejection from service B — never B's (or anyone's) data."""
    gw = _make_gateway("mpklink_opt")
    gw.register_service("secret", lambda r: r, allow={"vip"})
    try:
        vip = gw.connect("vip")
        vip.open("secret")
        intruder = gw.connect("intruder")

        # control plane: the CA refuses to issue the key at all
        with pytest.raises(AccessViolation):
            intruder.call("secret", np.arange(4, dtype=np.int32))

        # data plane: forge an envelope addressed to 'secret' using the
        # intruder's wordcount channel key/seed (the foreign-key attack)
        chan_wc = intruder.open("wordcount")
        sid_secret = vip._channels["secret"].sid
        frame = framing.build_frame(np.arange(4, dtype=np.int32),
                                    seed=chan_wc.seed, seq=0)
        env = np.concatenate([
            np.array([GW_MAGIC, sid_secret, intruder.cid, 0], "<u4")
            .view(np.uint8),
            frame.reshape(-1).view(np.uint8)])
        resp = np.ascontiguousarray(np.asarray(intruder._session.request(env)))
        route = resp[:_ROUTE_BYTES].view("<u4")
        assert int(route[1]) == 1                  # error status, no data
        with pytest.raises((AccessViolation, framing.FrameError)):
            _raise_remote(resp[_ROUTE_BYTES:
                               _ROUTE_BYTES + int(route[3])].tobytes())

        # data plane: right service id, wrong MAC seed → guard rejection
        chan = vip._channels["secret"]
        bad = framing.build_frame(np.arange(4, dtype=np.int32),
                                  seed=chan.seed ^ 0xDEAD, seq=chan.seq)
        env2 = np.concatenate([
            np.array([GW_MAGIC, chan.sid, vip.cid, 0], "<u4").view(np.uint8),
            bad.reshape(-1).view(np.uint8)])
        resp2 = np.ascontiguousarray(np.asarray(vip._session.request(env2)))
        route2 = resp2[:_ROUTE_BYTES].view("<u4")
        assert int(route2[1]) == 1
        with pytest.raises(framing.FrameError):
            _raise_remote(resp2[_ROUTE_BYTES:
                                _ROUTE_BYTES + int(route2[3])].tobytes())
        # the ACL denial happens at the CA (control plane); the two forged
        # envelopes are the server-side rejects
        assert gw.stats["rejected"] == 2
    finally:
        gw.close()


def test_gateway_revocation():
    gw = _make_gateway("mpklink_opt")
    try:
        a, b = gw.connect("alice"), gw.connect("bob")
        assert parse_count(a.call("wordcount", make_text(10, seed=0))) == 10
        assert parse_count(b.call("wordcount", make_text(11, seed=0))) == 11
        gw.revoke(a, "wordcount")
        # epoch bumped: bob's cached key is stale, but he is still certified
        # — call() re-keys through the CA transparently and succeeds
        epoch_key = b._channels["wordcount"].client_key
        assert parse_count(b.call("wordcount", make_text(12, seed=0))) == 12
        assert b._channels["wordcount"].client_key is not epoch_key
        # a BANNED client cannot re-key: the CA refuses the certificate
        # (alice's channel is gone after the revoke, so her next call must
        # go through the CA again)
        gw.ca.revoke_service("alice")
        with pytest.raises(AccessViolation):
            a.call("wordcount", make_text(13, seed=0))
    finally:
        gw.close()


def test_gateway_handler_errors_propagate():
    def boom(req):
        raise ValueError("handler exploded")

    gw = ServiceGateway("uds")
    gw.register_service("boom", boom)
    gw.start()
    try:
        c = gw.connect("c")
        with pytest.raises(TransportError):
            c.call("boom", np.arange(3, dtype=np.int32))
        # the session survives the error — next call works
        gw.register_service("ok", lambda r: r)
        np.testing.assert_array_equal(
            np.asarray(c.call("ok", np.arange(3, dtype=np.int32))),
            np.arange(3, dtype=np.int32))
    finally:
        gw.close()


# ---------------------------------------------------------------------------
# regression: the three seed-suite bugfixes
# ---------------------------------------------------------------------------

def test_checkpoint_codec_fallback_roundtrip():
    """Checkpoints save/restore without the optional zstandard package
    (stdlib zlib fallback) and record their codec in the manifest."""
    import repro.checkpoint.checkpointer as cp

    tree = {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
            "b": np.ones(4, np.float32)}
    with tempfile.TemporaryDirectory() as d:
        ck = cp.Checkpointer(d, keep=2)
        ck.save(3, tree, blocking=True)
        path, codec = cp._find_meta(f"{d}/step_3")
        expected = "zstd" if cp.zstd is not None else "zlib"
        assert codec == expected, (path, codec)
        step, restored = ck.restore(tree)
        assert step == 3
        np.testing.assert_array_equal(restored["w"], tree["w"])
        np.testing.assert_array_equal(restored["b"], tree["b"])


def test_shard_map_importable_on_this_jax():
    from repro.utils import axis_size, shard_map
    assert callable(shard_map) and callable(axis_size)


def test_shm_oversized_response_raises_not_hangs():
    """A handler response larger than the region used to strand the client
    in resp_ready.wait() forever; now it raises CapacityError promptly."""
    big = np.zeros(4096, np.uint8)
    tr = ShmTransport(lambda req: big, capacity=1024, timeout=5.0)
    tr.start()
    try:
        with pytest.raises(CapacityError):
            tr.request(np.zeros(8, np.uint8))
        # request-side capacity check still intact
        with pytest.raises(CapacityError):
            tr.request(np.zeros(2048, np.uint8))
    finally:
        tr.close()


def test_shm_handler_exception_propagates():
    def boom(req):
        raise ValueError("nope")

    tr = ShmTransport(boom, capacity=1024, timeout=5.0)
    tr.start()
    try:
        with pytest.raises(ValueError, match="nope"):
            tr.request(np.zeros(8, np.uint8))
    finally:
        tr.close()


def test_shm_timeout_poisons_session_and_transport_recovers():
    """A timed-out session must never hand a late (stale) response to the
    NEXT request; the legacy transport-level request() recovers by opening
    a fresh session."""
    import time

    slow_once = []

    def handler(req):
        if not slow_once:
            slow_once.append(1)
            time.sleep(0.5)
        return np.asarray(req)

    tr = ShmTransport(handler, capacity=1024, timeout=0.05)
    tr.start()
    try:
        with pytest.raises(TransportError, match="timed out"):
            tr.request(np.arange(4, dtype=np.uint8))
        time.sleep(0.6)                   # let the stale response land
        # direct reuse of the poisoned session fails loudly...
        with pytest.raises(TransportError, match="poisoned"):
            tr._sessions[0].request(np.arange(4, dtype=np.uint8))
        # ...but the transport transparently reconnects
        out = tr.request(np.asarray([9, 8, 7], np.uint8))
        assert list(out) == [9, 8, 7]
    finally:
        tr.close()


def test_ca_refuses_reregistration_of_revoked_identity():
    """A ban survives reconnects: gw.connect() under a revoked name raises
    instead of minting a fresh verified certificate."""
    gw = _make_gateway("uds")
    try:
        mallory = gw.connect("mallory")
        assert parse_count(mallory.call("wordcount", make_text(5, seed=0))) == 5
        gw.ca.revoke_service("mallory")
        with pytest.raises(AccessViolation, match="revoked"):
            gw.connect("mallory")
    finally:
        gw.close()


def test_client_results_are_owned_snapshots():
    """GatewayClient results must not alias transport region storage: on
    the zero-copy mpklink plane, an aliased r1 would silently flip to
    r2's bytes when the next call reuses the response region."""
    gw = ServiceGateway("mpklink_opt")
    gw.register_service("echo", lambda req: np.asarray(req))
    gw.start()
    try:
        c = gw.connect("snap")
        a = np.arange(64, dtype=np.uint8)
        b = np.full(64, 7, np.uint8)
        r1 = np.asarray(c.call("echo", a))
        expect = r1.copy()
        r2 = c.call("echo", b)                      # reuses the region
        np.testing.assert_array_equal(r1, expect)   # r1 must not mutate
        np.testing.assert_array_equal(np.asarray(r2), b)
        # batch and scatter results carry the same ownership guarantee
        rb = c.call_batch("echo", [a, b])
        rm = c.call_many([("echo", a), ("echo", b)])
        snaps = [np.asarray(r).copy() for r in rb + rm]
        c.call("echo", np.full(64, 99, np.uint8))
        for got, r in zip(snaps, rb + rm):
            np.testing.assert_array_equal(np.asarray(r), got)
    finally:
        gw.close()
